#!/usr/bin/env python3
"""Render the README results tables from the BENCH_*.json artifacts.

  python scripts/gen_results_table.py           # markdown to stdout
  PYTHONPATH=src python scripts/gen_results_table.py dryrun \
      > results/tables.md                       # EXPERIMENTS.md dry-run tables

Paste the default output into README.md's "Results" section after re-running
`PYTHONPATH=src python -m benchmarks.run dispatch fused pipeline adaptive`.
The ``dryrun`` mode regenerates the roofline tables from results/dryrun
(formerly the root-level scripts_tables.py).
"""

from __future__ import annotations

import glob
import json
import os
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _load(name: str):
    path = REPO / name
    return json.loads(path.read_text()) if path.exists() else None


def dispatch_table() -> list[str]:
    d = _load("BENCH_dispatch.json")
    if not d:
        return ["(BENCH_dispatch.json missing — run `benchmarks.run dispatch`)"]
    out = ["| chunks | two-sort ms | single-sort ms | speedup |",
           "|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['chunks']} | {r['two_sort']:.2f} "
                   f"| {r['single_sort']:.2f} "
                   f"| {r['speedup_single_vs_two']:.2f}x |")
    return out


def fused_table() -> list[str]:
    d = _load("BENCH_fused.json")
    if not d:
        return ["(BENCH_fused.json missing — run `benchmarks.run fused`)"]
    out = ["| chunks | tokens/chunk | three-launch ms | fused ms | speedup "
           "| modeled HBM ratio |",
           "|---|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['chunks']} | {r['tokens_per_chunk']} "
                   f"| {r['three_launch_ms']:.3f} "
                   f"| **{r['fused_ms']:.3f}** | {r['speedup']:.2f}x "
                   f"| {r['hbm_model_ratio']:.0f}x |")
    out.append("")
    out.append("| tokens | heuristic ms | autotuned ms | winner bk "
               "| speedup |")
    out.append("|---|---|---|---|---|")
    for r in d["autotune"]:
        out.append(f"| {r['shape'][0]} | {r['heuristic_ms']:.3f} "
                   f"| **{r['autotuned_ms']:.3f}** | {r['winner']['bk']} "
                   f"| {r['speedup_vs_heuristic']:.2f}x |")
    m = d["mact"]
    sched = "; ".join(
        f"seq {r['seq_len']}: {tuple(r['schedule_three_launch'])} -> "
        f"{tuple(r['schedule_fused'])}" for r in m["rows"])
    ratio = m["rows"][0]["s_prime_max_ratio"]
    out += ["", f"MACT schedules ({m['arch']}, {m['parallelism']}, "
            f"measured M_sta {m['static_gb']:.0f} GB), (bin, depth) "
            f"three-launch -> fused: {sched}.  Fused s'_max x{ratio:.2f}."]
    return out


def pipeline_table() -> list[str]:
    d = _load("BENCH_pipeline.json")
    if not d:
        return ["(BENCH_pipeline.json missing — run `benchmarks.run pipeline`)"]
    out = ["| chunks | sequential ms | pipelined ms | best depth | speedup |",
           "|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['chunks']} | {r['sequential_ms']:.1f} "
                   f"| {r['pipelined_ms']:.1f} | {r['pipeline_depth']} "
                   f"| {r['speedup']:.3f}x |")
    return out


def placement_table() -> list[str]:
    d = _load("BENCH_placement.json")
    if not d:
        return ["(BENCH_placement.json missing — run "
                "`benchmarks.run placement`)"]
    r = d["row"]
    spec = r["placement"]
    replicated = len(spec[2]) - spec[0]
    out = ["| layout | bottleneck-peer FFN ms | vs balanced |",
           "|---|---|---|",
           f"| balanced routing | {r['balanced_ms']:.3f} | 1.00x |",
           f"| identity, skewed | {r['identity_ms']:.3f} "
           f"| {r['identity_over_balanced']:.2f}x |",
           f"| **placed + replicated, skewed** | **{r['placed_ms']:.3f}** "
           f"| **{r['placed_over_balanced']:.2f}x** |",
           "",
           f"All tokens routed to 2 of {d['experts']} experts; the solved "
           f"placement ({replicated} replica slot(s)) restores the balanced "
           f"per-peer load on {d['devices']} EP peers.  Placed output parity "
           f"vs identity: {r['parity']}, drops {r['drops']:.0f}."]
    return out


def adaptive_table() -> list[str]:
    d = _load("BENCH_adaptive.json")
    if not d:
        return ["(BENCH_adaptive.json missing — run `benchmarks.run adaptive`)"]
    m, t = d["model"], d["throughput"]
    sched = ", ".join(f"({b},{dep})" for b, dep in m["final_layer_schedules"])
    out = ["| metric | adaptive per-layer | best static | offline static |",
           "|---|---|---|---|",
           f"| modeled peak memory (GB) | **{m['adaptive_peak_gb']}** "
           f"| {m['best_static']['peak_gb']} "
           f"(b{m['best_static']['schedule'][0]}"
           f"d{m['best_static']['schedule'][1]}) "
           f"| {m['offline_static']['peak_gb']} |",
           f"| measured step time (ms) | **{t['adaptive_ms']:.0f}** "
           f"| {t['static_ms']:.0f} | — |",
           f"| distinct layer schedules | {m['distinct_layer_schedules']} "
           f"| 1 | 1 |",
           f"| recompiles (bound {m['schedule_key_bound']}) "
           f"| {m['recompiles']} | 1 | 1 |",
           "",
           f"Final per-layer schedule vector (bin, depth): {sched}; "
           f"throughput vs best-memory static: "
           f"{t['throughput_cost_pct']:+.1f}%."]
    return out


def serving_table() -> list[str]:
    d = _load("BENCH_serving.json")
    if not d:
        return ["(BENCH_serving.json missing — run `benchmarks.run serving`)"]
    out = ["| arch | continuous tok/s | static tok/s | speedup "
           "| p50 / p99 latency (s) | modeled peak <= budget |",
           "|---|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['arch']} | **{r['continuous_tok_s']:.0f}** "
                   f"| {r['static_tok_s']:.0f} | {r['speedup']:.2f}x "
                   f"| {r['latency_p50_s']:.2f} / {r['latency_p99_s']:.2f} "
                   f"| {r['modeled_peak_gb']:.3f} / {r['budget_gb']:.0f} GB "
                   f"({'yes' if r['within_budget'] else 'NO'}) |")
    out += ["",
            f"{d['requests']} requests/arch, {d['slots']} slots, "
            f"prefill chunk {d['prefill_chunk']}, long-tailed generation "
            f"lengths {tuple(d['gen_short'])} (3/4) / {tuple(d['gen_long'])} "
            f"(1/4)."]
    return out


def paging_table() -> list[str]:
    d = _load("BENCH_paging.json")
    if not d:
        return ["(BENCH_paging.json missing — run `benchmarks.run paging`)"]
    c = d["concurrency"]
    out = ["| scheme | admitted concurrency | tok/s | modeled peak (GB) "
           "| page HWM (GB) |",
           "|---|---|---|---|---|",
           f"| monolithic slot map | {c['mono_occupancy']} "
           f"| {c['mono_tok_s']:.0f} | {c['mono_peak_gb']:.3f} | — |",
           f"| paged (page={d['page']}) | **{c['paged_occupancy']}** "
           f"| {c['paged_tok_s']:.0f} | {c['paged_peak_gb']:.3f} "
           f"| {c['page_hwm_gb']:.4f} |",
           "",
           f"{c['concurrency_x']:.2f}x admitted concurrency at an equal "
           f"budget of {c['budget_gb']:.3f} GB "
           f"(target >= 1.3x: {'met' if c['target_1_3x_met'] else 'NOT met'}; "
           f"both within budget: {c['within_budget']}).  "
           f"{d['requests']} requests on {d['arch']}, cache_len "
           f"{d['cache_len']}.",
           "",
           "| shared stem | prefix hit rate | tokens reused "
           "| prefill chunks |",
           "|---|---|---|---|"]
    for r in d["prefix_sweep"]:
        out.append(f"| {r['stem']} | {r['hit_rate']:.2f} "
                   f"| {r['tokens_reused']} | {r['prefill_chunks']} |")
    return out


def residency_table() -> list[str]:
    d = _load("BENCH_residency.json")
    if not d:
        return ["(BENCH_residency.json missing — run "
                "`benchmarks.run residency`)"]
    w, r = d["wave_grouping"], d["residency"]
    out = ["| wave policy | mean distinct experts / wave | waves |",
           "|---|---|---|",
           f"| FIFO age order | {w['fifo_mean_distinct_experts']:.2f} "
           f"| {w['fifo_waves']} |",
           f"| **expert-grouped** | **{w['grouped_mean_distinct_experts']:.2f}** "
           f"| {w['grouped_waves']} |",
           "",
           f"{w['reduction_pct']:.1f}% fewer distinct activated experts per "
           f"wave (wave size {d['wave']}, {d['experts']} experts, skewed "
           f"2-family trace on {d['arch']}), outputs bitwise-identical to "
           f"FIFO; {w['forced_includes']} starvation force-includes.",
           "",
           "| weight tier | admitted concurrency | modeled peak (GB) |",
           "|---|---|---|",
           f"| all experts resident | {r['full_occupancy']} "
           f"| {r['full_peak_gb']:.3f} |",
           f"| **resident tier** | **{r['resident_occupancy']}** "
           f"| {r['resident_peak_gb']:.3f} |",
           "",
           f"{r['admitted_ratio']:.2f}x admitted concurrency at an equal "
           f"budget of {r['budget_gb']:.3f} GB "
           f"(target >= 1.3x: {'met' if r['target_1_3x_met'] else 'NOT met'}; "
           f"within budget: {r['within_budget']}).  Outputs bitwise equal to "
           f"the never-offloaded scheduler: {r['bitwise_identical']}, "
           f"{r['accepted_lost']} accepted requests lost; prefetch "
           f"{r['prefetch_hits']} hits / {r['prefetch_misses']} misses, "
           f"{r['demand_reruns']} demand re-runs."]
    return out


def chaos_table() -> list[str]:
    d = _load("BENCH_chaos.json")
    if not d:
        return ["(BENCH_chaos.json missing — run `benchmarks.run chaos`)"]
    t, s = d["training"], d["serving"]
    f, o = s["faulted"], s["overload"]
    ok = (t["retries_bounded"] and t["bit_identical"]
          and f["accepted_lost"] == 0 and f["outputs_match_baseline"])
    out = ["| scenario | outcome |",
           "|---|---|",
           f"| training: burst + injected OOM ({d['train_arch']}) "
           f"| completed, {t['escalations']} ladder escalation(s), "
           f"max {t['max_step_retries']} retries/step, headroom "
           f"{'widened' if t['headroom_widened'] else 'unchanged'} |",
           f"| training: crash + truncated checkpoint "
           f"| auto-resumed from step {t['resumed_from']} (corrupt save "
           f"skipped), final state bit-identical: "
           f"**{t['bit_identical']}** |",
           f"| serving: {f['faults']} faulted decode waves "
           f"({d['serve_arch']}) | {f['requeues']} requeues, "
           f"**{f['accepted_lost']} accepted requests lost**, outputs match "
           f"unfaulted run: {f['outputs_match_baseline']}; p99 "
           f"{s['baseline']['p99_s']:.2f}s -> {f['p99_s']:.2f}s |",
           f"| serving: overload (1 slot, deadline) | {o['finished']} served, "
           f"{o['shed']} shed with retry-after p50 "
           f"{o['retry_after_p50_s']:.0f}s — shed, not crashed |",
           "",
           f"All resilience invariants hold: {ok}."]
    return out


# ---------------------------------------------------------------------------
# dry-run roofline tables (results/dryrun -> EXPERIMENTS.md), formerly the
# root-level scripts_tables.py; needs PYTHONPATH=src for the repro imports
# ---------------------------------------------------------------------------

DRYRUN_RESULTS = "results/dryrun"
DRYRUN_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
DRYRUN_HEADER = (
    "| arch | shape | mesh | chunks | compute s | memory s | collective s "
    "| dominant | useful-FLOPs ratio | peak GB/dev | coll GB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|---|")


def _model_flops(arch, shape_name):
    from repro.configs import SHAPES, get_config
    from repro.core.memory_model import active_params, total_params
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_params(cfg) if cfg.moe else total_params(cfg)
    if shape.mode == "train":
        return 6 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2 * n * shape.global_batch * shape.seq_len
    return 2 * n * shape.global_batch


def _dryrun_row(r):
    arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
    if r["status"] == "skipped":
        return (f"| {arch} | {shape} | {mesh} | — | "
                f"skipped: sub-quadratic rule |||||||")
    if r["status"] != "ok":
        return (f"| {arch} | {shape} | {mesh} | — | "
                f"ERROR {r.get('error', '')[:40]} |||||||")
    ro, m, c = r["roofline"], r["memory"], r["cost"]
    chips = 512 if mesh == "2x16x16" else 256
    useful = _model_flops(arch, shape) / max(c["flops_per_device"] * chips, 1)
    return (f"| {arch} | {shape} | {mesh} | c={r.get('chunks', '')} "
            f"| {ro['t_compute_s']:.3f} | {ro['t_memory_s']:.3f} "
            f"| {ro['t_collective_s']:.3f} | **{ro['dominant']}** "
            f"| {min(useful, 99):.2f} | {m['peak_device_gb']:.1f} "
            f"| {r['collectives']['total_bytes'] / 1e9:.0f} |")


def dryrun_tables() -> None:
    recs = {}
    for p in sorted(glob.glob(os.path.join(DRYRUN_RESULTS, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        recs[(r.get("arch"), r.get("shape"), r.get("mesh"),
              r.get("tag", ""))] = r
    archs = sorted({k[0] for k in recs if k[0]})
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh} ({256 if mesh == '16x16' else 512} chips)\n")
        print(DRYRUN_HEADER)
        for arch in archs:
            for shape in DRYRUN_SHAPES:
                r = recs.get((arch, shape, mesh, ""))
                if r:
                    print(_dryrun_row(r))
    print("\n### Optimized-variant records (tags)\n")
    print(DRYRUN_HEADER.replace("| chunks |", "| tag/chunks |"))
    for key in sorted(recs):
        if key[3]:
            r = recs[key]
            row = _dryrun_row(r)
            row = row.replace(f"| c={r.get('chunks', '')} ",
                              f"| {key[3]} c={r.get('chunks', '')} ", 1)
            print(row)


def _section(title: str, table, first: bool = False) -> None:
    """Emit one table; a stale/partial BENCH_*.json (e.g. a schema from an
    older benchmark revision) skips the section instead of crashing the
    whole render."""
    print(f"{'' if first else chr(10)}### {title}\n")
    try:
        print("\n".join(table()))
    except Exception as e:  # noqa: BLE001 — render what we can
        print(f"(skipped: {type(e).__name__}: {e} — re-run the benchmark)")


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "dryrun":
        dryrun_tables()
        return
    _section("Dispatch planning (single-sort vs two-sort, CPU)",
             dispatch_table, first=True)
    _section("Fused MoE leg (single launch vs three, interpret)", fused_table)
    _section("Pipelined FCDA (8-device host mesh)", pipeline_table)
    _section("Expert placement + replication (skewed routing, 4 EP peers)",
             placement_table)
    _section("Adaptive per-layer MACT (drifting skewed load)", adaptive_table)
    _section("Continuous-batching serving (mixed-length trace, CPU)",
             serving_table)
    _section("Paged KV cache (vs monolithic slot map, CPU)", paging_table)
    _section("Expert waves + weight residency (MoE decode, CPU)",
             residency_table)
    _section("Fault tolerance (chaos harness, injected faults)", chaos_table)


if __name__ == "__main__":
    main()
