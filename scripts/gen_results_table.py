#!/usr/bin/env python3
"""Render the README results tables from the BENCH_*.json artifacts.

  python scripts/gen_results_table.py        # markdown to stdout

Paste the output into README.md's "Results" section after re-running
`PYTHONPATH=src python -m benchmarks.run dispatch pipeline adaptive`.
"""

from __future__ import annotations

import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent


def _load(name: str):
    path = REPO / name
    return json.loads(path.read_text()) if path.exists() else None


def dispatch_table() -> list[str]:
    d = _load("BENCH_dispatch.json")
    if not d:
        return ["(BENCH_dispatch.json missing — run `benchmarks.run dispatch`)"]
    out = ["| chunks | two-sort ms | single-sort ms | speedup |",
           "|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['chunks']} | {r['two_sort']:.2f} "
                   f"| {r['single_sort']:.2f} "
                   f"| {r['speedup_single_vs_two']:.2f}x |")
    return out


def pipeline_table() -> list[str]:
    d = _load("BENCH_pipeline.json")
    if not d:
        return ["(BENCH_pipeline.json missing — run `benchmarks.run pipeline`)"]
    out = ["| chunks | sequential ms | pipelined ms | best depth | speedup |",
           "|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['chunks']} | {r['sequential_ms']:.1f} "
                   f"| {r['pipelined_ms']:.1f} | {r['pipeline_depth']} "
                   f"| {r['speedup']:.3f}x |")
    return out


def adaptive_table() -> list[str]:
    d = _load("BENCH_adaptive.json")
    if not d:
        return ["(BENCH_adaptive.json missing — run `benchmarks.run adaptive`)"]
    m, t = d["model"], d["throughput"]
    sched = ", ".join(f"({b},{dep})" for b, dep in m["final_layer_schedules"])
    out = ["| metric | adaptive per-layer | best static | offline static |",
           "|---|---|---|---|",
           f"| modeled peak memory (GB) | **{m['adaptive_peak_gb']}** "
           f"| {m['best_static']['peak_gb']} "
           f"(b{m['best_static']['schedule'][0]}"
           f"d{m['best_static']['schedule'][1]}) "
           f"| {m['offline_static']['peak_gb']} |",
           f"| measured step time (ms) | **{t['adaptive_ms']:.0f}** "
           f"| {t['static_ms']:.0f} | — |",
           f"| distinct layer schedules | {m['distinct_layer_schedules']} "
           f"| 1 | 1 |",
           f"| recompiles (bound {m['schedule_key_bound']}) "
           f"| {m['recompiles']} | 1 | 1 |",
           "",
           f"Final per-layer schedule vector (bin, depth): {sched}; "
           f"throughput vs best-memory static: "
           f"{t['throughput_cost_pct']:+.1f}%."]
    return out


def serving_table() -> list[str]:
    d = _load("BENCH_serving.json")
    if not d:
        return ["(BENCH_serving.json missing — run `benchmarks.run serving`)"]
    out = ["| arch | continuous tok/s | static tok/s | speedup "
           "| p50 / p99 latency (s) | modeled peak <= budget |",
           "|---|---|---|---|---|---|"]
    for r in d["rows"]:
        out.append(f"| {r['arch']} | **{r['continuous_tok_s']:.0f}** "
                   f"| {r['static_tok_s']:.0f} | {r['speedup']:.2f}x "
                   f"| {r['latency_p50_s']:.2f} / {r['latency_p99_s']:.2f} "
                   f"| {r['modeled_peak_gb']:.3f} / {r['budget_gb']:.0f} GB "
                   f"({'yes' if r['within_budget'] else 'NO'}) |")
    out += ["",
            f"{d['requests']} requests/arch, {d['slots']} slots, "
            f"prefill chunk {d['prefill_chunk']}, long-tailed generation "
            f"lengths {tuple(d['gen_short'])} (3/4) / {tuple(d['gen_long'])} "
            f"(1/4)."]
    return out


def chaos_table() -> list[str]:
    d = _load("BENCH_chaos.json")
    if not d:
        return ["(BENCH_chaos.json missing — run `benchmarks.run chaos`)"]
    t, s = d["training"], d["serving"]
    f, o = s["faulted"], s["overload"]
    ok = (t["retries_bounded"] and t["bit_identical"]
          and f["accepted_lost"] == 0 and f["outputs_match_baseline"])
    out = ["| scenario | outcome |",
           "|---|---|",
           f"| training: burst + injected OOM ({d['train_arch']}) "
           f"| completed, {t['escalations']} ladder escalation(s), "
           f"max {t['max_step_retries']} retries/step, headroom "
           f"{'widened' if t['headroom_widened'] else 'unchanged'} |",
           f"| training: crash + truncated checkpoint "
           f"| auto-resumed from step {t['resumed_from']} (corrupt save "
           f"skipped), final state bit-identical: "
           f"**{t['bit_identical']}** |",
           f"| serving: {f['faults']} faulted decode waves "
           f"({d['serve_arch']}) | {f['requeues']} requeues, "
           f"**{f['accepted_lost']} accepted requests lost**, outputs match "
           f"unfaulted run: {f['outputs_match_baseline']}; p99 "
           f"{s['baseline']['p99_s']:.2f}s -> {f['p99_s']:.2f}s |",
           f"| serving: overload (1 slot, deadline) | {o['finished']} served, "
           f"{o['shed']} shed with retry-after p50 "
           f"{o['retry_after_p50_s']:.0f}s — shed, not crashed |",
           "",
           f"All resilience invariants hold: {ok}."]
    return out


def main() -> None:
    print("### Dispatch planning (single-sort vs two-sort, CPU)\n")
    print("\n".join(dispatch_table()))
    print("\n### Pipelined FCDA (8-device host mesh)\n")
    print("\n".join(pipeline_table()))
    print("\n### Adaptive per-layer MACT (drifting skewed load)\n")
    print("\n".join(adaptive_table()))
    print("\n### Continuous-batching serving (mixed-length trace, CPU)\n")
    print("\n".join(serving_table()))
    print("\n### Fault tolerance (chaos harness, injected faults)\n")
    print("\n".join(chaos_table()))


if __name__ == "__main__":
    main()
