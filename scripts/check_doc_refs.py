#!/usr/bin/env python3
"""Fail if any `docs/DESIGN.md §X` reference in src/ has no matching section.

The code docstrings cite the design doc by section token (`docs/DESIGN.md
§2`, `§Pipeline`, `§Adaptive`, ...) and DESIGN.md promises to keep those
tokens stable.  PR 1 repointed every reference; this check is what enforces
the contract from then on (wired into .github/workflows/ci.yml).

  python scripts/check_doc_refs.py            # from the repo root
  python scripts/check_doc_refs.py --list     # show the reference map

Exit code 0 when every referenced section exists, 1 otherwise.
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DESIGN = REPO / "docs" / "DESIGN.md"
SRC = REPO / "src"

# a reference is the literal doc path followed by one or more section
# tokens, "/"- or ","-separated: "docs/DESIGN.md §2", "docs/DESIGN.md
# §Dry-run / §Roofline", "docs/DESIGN.md §2, §Adaptive" ("§N" is the doc's
# own placeholder convention, skipped below)
REF_RE = re.compile(r"docs/DESIGN\.md\s+((?:§[\w.-]+(?:\s*[,/]\s*)?)+)")
TOKEN_RE = re.compile(r"§([\w-]+(?:\.\d+)*)")
HEADING_RE = re.compile(r"^##\s+(.*)$", re.MULTILINE)
PLACEHOLDERS = {"N", "X"}          # generic tokens in prose, not references


def design_sections() -> set[str]:
    """Every §-token declared by a DESIGN.md heading (a heading may declare
    several: '## §Dry-run / §Roofline')."""
    text = DESIGN.read_text()
    tokens: set[str] = set()
    for heading in HEADING_RE.findall(text):
        tokens.update(TOKEN_RE.findall(heading))
    return tokens


def source_refs() -> dict[str, list[str]]:
    """section token -> ['path:line', ...] for every reference under src/.

    Matches against the whole file text (REF_RE's ``\\s+`` crosses
    newlines), so a reference wrapped over two lines by docstring reflow
    still registers."""
    refs: dict[str, list[str]] = {}
    for path in sorted(SRC.rglob("*.py")):
        text = path.read_text()
        for m in REF_RE.finditer(text):
            lineno = text.count("\n", 0, m.start()) + 1
            for tok in TOKEN_RE.findall(m.group(1)):
                tok = tok.rstrip(".")
                if tok in PLACEHOLDERS:
                    continue
                where = f"{path.relative_to(REPO)}:{lineno}"
                refs.setdefault(tok, []).append(where)
    return refs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="print the full section -> references map")
    args = ap.parse_args()

    sections = design_sections()
    refs = source_refs()
    if args.list:
        for tok in sorted(refs):
            mark = "ok" if tok in sections else "MISSING"
            print(f"§{tok} [{mark}] <- {len(refs[tok])} refs")
            for w in refs[tok]:
                print(f"    {w}")

    missing = {tok: where for tok, where in refs.items()
               if tok not in sections}
    if missing:
        print(f"doc-ref check FAILED: {len(missing)} section token(s) "
              f"referenced from src/ but absent from docs/DESIGN.md:",
              file=sys.stderr)
        for tok, where in sorted(missing.items()):
            print(f"  §{tok}  referenced at: {', '.join(where)}",
                  file=sys.stderr)
        print(f"known sections: "
              f"{', '.join('§' + t for t in sorted(sections))}",
              file=sys.stderr)
        return 1
    n = sum(len(v) for v in refs.values())
    print(f"doc-ref check OK: {n} references to {len(refs)} sections, "
          f"all present in docs/DESIGN.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
