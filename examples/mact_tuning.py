"""MACT in isolation: how the schedule responds to hardware budget, observed
imbalance, pipeline depth, and per-layer drift — the paper's Eq. 8-9 made
tangible, plus the PR-2 depth axis and the adaptive per-layer controller
(docs/DESIGN.md §Pipeline, §Adaptive).

  PYTHONPATH=src python examples/mact_tuning.py
"""

import numpy as np

from repro.configs import GPU_64G, TPU_V5E, get_config
from repro.configs.base import HardwareProfile
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism, worst_case_s_prime

cfg = get_config("deepseek-mini-16l")
par = Parallelism(t=1, p=4, e=32, b=1)
S = 4096

print("=== schedule vs hardware (paper model I, static=43GB) ===")
for hw in (GPU_64G, TPU_V5E,
           HardwareProfile("gpu-24g", 24e9, 197e12, 819e9, 50e9)):
    mact = MACTController(cfg, par, hw, seq_len=S,
                          static_override=min(43e9, hw.hbm_bytes * 0.6))
    wc = worst_case_s_prime(S, par, cfg.moe.top_k)
    b, d = mact.choose_schedule()
    print(f"{hw.name:10s}: s'_max={mact.s_prime_max():>12.0f}  "
          f"worst-case c*={mact.optimal_c(wc):>6}  bin={b} depth={d}")

print("\n=== schedule vs observed imbalance (64GB GPU) ===")
mact = MACTController(cfg, par, GPU_64G, seq_len=S, static_override=43e9)
E = cfg.moe.num_experts
for skew in (1.0, 2.0, 8.0, 32.0):
    # synthetic load: device 0's experts (E/e of them) take `skew`x the mean
    load = np.full(E, 1.0)
    load[: E // par.e] *= skew
    load = load / load.sum() * 4096 * 8 * par.e   # total slots in the EP group
    b, d = mact.choose_schedule(load, ep_size=par.e)
    print(f"skew {skew:5.1f}x -> s''={mact.history[-1]['s_pp']:>10.0f} "
          f"c*={mact.history[-1]['c_star']:>3} bin={b} depth={d}")

print("\n=== adaptive per-layer schedules under drifting skew ===")
# four layers: two idle, one mid-skew, one ramping hot — each gets its own
# (bin, depth) through the same memory model; hysteresis holds schedules
# still under +-4% load noise (the flapping test of tests/test_adaptive.py)
s_max = mact.s_prime_max()
cur = None
for t, hot in enumerate((0.8, 2.0, 4.0, 6.5)):
    s_pps = [0.8 * (1 + 0.04 * (-1) ** t), 0.8, 1.8, hot]
    loads = np.stack([np.full(E, s * s_max / E) for s in s_pps])
    cur = mact.choose_layer_schedules(loads, 4, ep_size=1, max_depth=2,
                                      current=cur, hysteresis=0.1)
    print(f"t={t}: hot={hot:.1f}x s'_max -> "
          f"{[tuple(s) for s in cur]}")

print("\n=== the paper's own operating point ===")
c = mact.snap(mact.optimal_c(5.97e5))
print(f"calibrated s''=5.97e5 -> bin={c} (paper Table 4 Method 3: c=2)")
