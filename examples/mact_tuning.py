"""MACT in isolation: how the chunk choice responds to hardware budget,
observed imbalance, and parallelism — the paper's Eq. 8-9 made tangible.

  PYTHONPATH=src python examples/mact_tuning.py
"""

import numpy as np

from repro.configs import GPU_64G, TPU_V5E, get_config
from repro.configs.base import HardwareProfile
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism, worst_case_s_prime

cfg = get_config("deepseek-mini-16l")
par = Parallelism(t=1, p=4, e=32, b=1)
S = 4096

print("=== chunk choice vs hardware (paper model I, static=43GB) ===")
for hw in (GPU_64G, TPU_V5E,
           HardwareProfile("gpu-24g", 24e9, 197e12, 819e9, 50e9)):
    mact = MACTController(cfg, par, hw, seq_len=S, static_override=min(43e9, hw.hbm_bytes * 0.6))
    wc = worst_case_s_prime(S, par, cfg.moe.top_k)
    print(f"{hw.name:10s}: s'_max={mact.s_prime_max():>12.0f}  "
          f"worst-case c*={mact.optimal_c(wc):>6}  bin={mact.choose()}")

print("\n=== chunk choice vs observed imbalance (64GB GPU) ===")
mact = MACTController(cfg, par, GPU_64G, seq_len=S, static_override=43e9)
E = cfg.moe.num_experts
for skew in (1.0, 2.0, 8.0, 32.0):
    # synthetic load: device 0's experts (E/e of them) take `skew`x the mean
    load = np.full(E, 1.0)
    load[: E // par.e] *= skew
    load = load / load.sum() * 4096 * 8 * par.e   # total slots in the EP group
    c = mact.choose(load, ep_size=par.e)
    print(f"skew {skew:5.1f}x -> s''={mact.history[-1]['s_pp']:>10.0f} "
          f"c*={mact.history[-1]['c_star']:>3} bin={c}")

print("\n=== the paper's own operating point ===")
c = mact.snap(mact.optimal_c(5.97e5))
print(f"calibrated s''=5.97e5 -> bin={c} (paper Table 4 Method 3: c=2)")
