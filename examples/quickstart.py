"""Quickstart: MemFine in ~50 lines.

Builds a small MoE transformer, shows FCDA chunk invariance, lets MACT pick
the (chunk bin, pipeline depth) schedule from the theoretical memory model,
and trains a few steps with the adaptive per-layer controller in the loop.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import TPU_V5E, get_config
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.models import transformer
from repro.training.trainer import Trainer

# 1. pick an architecture (any of the 12 registered configs) and shrink it
cfg = get_config("mixtral-8x7b").reduced()
print(f"arch: {cfg.name} — {cfg.num_layers}L d={cfg.d_model} "
      f"E={cfg.moe.num_experts} top-{cfg.moe.top_k}")

# 2. FCDA: chunked dispatch-compute-combine is bit-equivalent to unchunked
params = transformer.init_params(jax.random.PRNGKey(0), cfg)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                      cfg.vocab_size)}
y1, s1 = transformer.forward(params, cfg, DistContext(moe_chunks=1), batch)
y4, _ = transformer.forward(params, cfg, DistContext(moe_chunks=4), batch)
print(f"FCDA chunk invariance: max|y1-y4| = {np.abs(y1 - y4).max():.2e}")
# the stats contract also reports per-layer routed-token histograms — the
# adaptive controller's telemetry source (docs/DESIGN.md §Perf, §Adaptive)
print(f"per-layer load telemetry: {s1['load_per_layer'].shape} "
      f"(layers x experts)")

# 3. MACT: derive the FCDA schedule from the memory model (Eq. 8-9).  The
# joint choice picks chunk bin AND pipeline depth — depth 2 overlaps chunk
# all-to-alls with expert compute when the extra live chunk still fits.
mact = MACTController(get_config("deepseek-mini-16l"),
                      Parallelism(t=1, p=4, e=32, b=1), TPU_V5E, seq_len=4096)
b, d = mact.choose_schedule()
print(f"MACT on TPU v5e: s'_max={mact.s_prime_max():.0f} tokens, "
      f"cold-start schedule = (bin {b}, depth {d})")

# 4. train with the adaptive per-layer controller in the loop: every layer
# gets its own (bin, depth) from the telemetry EMA, with hysteresis
trainer = Trainer(cfg, DistContext(), seq_len=64, global_batch=4, lr=2e-3,
                  adaptive_mact=True, replan_interval=2)
trainer.fit(10, verbose=True)
print(f"loss {trainer.log[0]['loss']:.3f} -> {trainer.log[-1]['loss']:.3f}")
print(f"last per-layer schedules: "
      f"{[tuple(s) for s in trainer.schedule_trace[-1]]} "
      f"({trainer.compile_count} compiled step variants)")
