"""End-to-end training driver: a ~100M-parameter MoE model trained for a few
hundred steps with the full stack — synthetic data pipeline, AdamW + cosine
schedule, MACT dynamic chunking, loss-free router balancing, checkpointing.

  PYTHONPATH=src python examples/train_memfine.py --steps 300
  (use --steps 30 for a quick look; full run takes a while on 1 CPU core)
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.base import (AttentionSpec, LayerSpec, ModelConfig,
                                MoEConfig)
from repro.core.moe import DistContext
from repro.training.trainer import Trainer

# ~100M-parameter MemFine MoE: 8 layers, d=512, 8 experts top-2.
CFG = ModelConfig(
    name="memfine-100m",
    family="moe",
    source="examples/train_memfine.py",
    num_layers=8,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=8192,
    pattern=(LayerSpec(mixer="attn", ffn="moe", attn=AttentionSpec()),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=1024,
                  loss_free_bias=True),
    dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--checkpoint-dir", default="/tmp/memfine_100m")
    args = ap.parse_args()

    from repro.core.memory_model import total_params
    print(f"model: {total_params(CFG)/1e6:.0f}M params")
    trainer = Trainer(CFG, DistContext(), seq_len=args.seq_len,
                      global_batch=args.global_batch, lr=3e-4,
                      use_mact=True, mact_ep_view=CFG.moe.num_experts,
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every=100)
    state = trainer.fit(args.steps, verbose=True)
    ce = [r["ce"] for r in trainer.log]
    print(f"\nCE {ce[0]:.3f} -> {ce[-1]:.3f} over {args.steps} steps; "
          f"chunk trace tail: {trainer.chunk_trace[-10:]}")
    assert ce[-1] < ce[0], "loss should decrease"


if __name__ == "__main__":
    main()
