"""Batched serving example: prefill a batch of prompts into per-layer caches
(ring-bounded for window/chunked layers, constant-size SSM state) and decode
new tokens — the same ``serve_step`` the decode_32k / long_500k dry-run
shapes lower at production scale.

  PYTHONPATH=src python examples/serve_batched.py --arch gemma3-27b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.moe import DistContext
from repro.models import transformer
from repro.serving.engine import generate

ARCHS = ["gemma3-27b", "mixtral-8x7b", "jamba-1.5-large-398b", "mamba2-130m"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ctx = DistContext()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(params, cfg, ctx, {"tokens": prompts}, steps=args.gen,
                   cache_len=args.prompt_len + args.gen)
    dt = time.perf_counter() - t0
    print(f"{args.arch}: served batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} in {dt:.1f}s ({args.batch*args.gen/dt:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  seq {i}: {out[i].tolist()}")


if __name__ == "__main__":
    main()
