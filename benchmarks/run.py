"""Benchmark driver — one module per paper table/figure + the dry-run-derived
extensions.  Prints ``name,...`` CSV lines per the repo convention.

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run table4 fig5  # a subset
"""

from __future__ import annotations

import sys
import time

from benchmarks import (ablation_capacity, adaptive_microbench,
                        chaos_harness, compiled_memory, dispatch_microbench,
                        fig2_distribution, fig4_throughput, fig5_mact,
                        fused_microbench, paging_microbench,
                        pipeline_microbench, placement_microbench,
                        residency_microbench, roofline, serving_microbench,
                        table4_memory)

SUITES = {
    "dispatch": dispatch_microbench.run,  # single-sort planner vs old path
    "fused": fused_microbench.run,        # 1-launch fused leg + autotuner
    "pipeline": pipeline_microbench.run,  # sequential vs pipelined FCDA
    "placement": placement_microbench.run,  # expert placement vs identity
    "adaptive": adaptive_microbench.run,  # per-layer MACT vs static global
    "serving": serving_microbench.run,    # continuous vs static batching
    "paging": paging_microbench.run,      # paged vs monolithic KV cache
    "residency": residency_microbench.run,  # expert waves + weight residency
    "chaos": chaos_harness.run,           # injected faults: ladder/resume/shed
    "table4": table4_memory.run,       # Table 4 (memory model, Methods 1/2/3)
    "fig2": fig2_distribution.run,     # Fig. 2 (token distribution)
    "fig4": fig4_throughput.run,       # Fig. 4 (TGS Methods 1/2/3)
    "fig5": fig5_mact.run,             # Fig. 5 (MACT chunk trace)
    "ablation": ablation_capacity.run, # §2.2: capacity baseline drops tokens
    "compiled": compiled_memory.run,   # beyond-paper: XLA-measured Table 4
    "roofline": roofline.run,          # deliverable (g)
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    for name in names:
        fn = SUITES[name]
        t0 = time.perf_counter()
        try:
            lines = fn()
        except Exception as e:  # noqa: BLE001 — benches report, don't crash
            lines = [f"{name},ERROR,{type(e).__name__}: {e}"]
        dt = time.perf_counter() - t0
        for line in lines:
            print(line, flush=True)
        print(f"{name},elapsed_s={dt:.1f}", flush=True)


if __name__ == "__main__":
    main()
