"""Pipelined vs sequential FCDA schedule on a multi-device CPU mesh.

The EP MoE layer (core/ep.py) across chunk counts c ∈ {2, 4, 8}: the
sequential chunk loop (``pipeline_chunks=1``, ``lax.map``) against the wave
pipeline (``pipeline_chunks`` ∈ {2, c}, docs/DESIGN.md §Pipeline).  The
timing subprocess forces an 8-device host platform so the all-to-alls are
real collectives between device threads (the main process must keep the
single real device per the dry-run isolation rule — tests/test_distributed.py
uses the same pattern), pins XLA's CPU ops single-threaded and enables the
concurrency-optimized scheduler so the thunk runtime may actually execute
the schedule's independent work concurrently.

Methodology: variants are timed interleaved in blocks (min over repeats per
block), and the reported speedup is the MEDIAN of per-block paired ratios —
robust to the common-mode load drift of a shared CPU box.  CPU caveat: the
host backend's collectives are synchronous rendezvous, so the win here comes
from filling rendezvous/scheduling idle with the adjacent chunk's
independent work; on TPU the same schedule additionally hides dispatch/
combine ICI latency under the expert GEMMs.  Trajectory anchor, not the TPU
speedup.

Emits CSV lines per repo convention and writes ``BENCH_pipeline.json`` so
later PRs can track the trajectory.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

DEVICES = 8
CHUNKS = (2, 4, 8)
BLOCKS = 6
REPEATS = 8
B, S, D = 4, 1024, 128          # per-device tokens: B * S/DEVICES = 512
EXPERTS, TOP_K, D_FF = 8, 2, 256

_INNER = f"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={DEVICES} "
    "--xla_cpu_multi_thread_eigen=false "
    "--xla_cpu_enable_concurrency_optimized_scheduler=true")
import json, statistics, time
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.core import moe as M
from repro.configs.base import MoEConfig

cfg = MoEConfig(num_experts={EXPERTS}, top_k={TOP_K}, d_ff_expert={D_FF})
mesh = jax.make_mesh((1, {DEVICES}), ("data", "model"))
params = M.init_moe(jax.random.PRNGKey(0), {D}, cfg)
x = jax.random.normal(jax.random.PRNGKey(1), ({B}, {S}, {D}))

rows = []
with set_mesh(mesh):
    for chunks in {CHUNKS}:
        depths = sorted({{2, chunks}})
        ctxs = {{"seq": M.DistContext(mesh=mesh, moe_chunks=chunks,
                                      moe_strategy="ep_shardmap")}}
        for d in depths:
            ctxs[f"depth{{d}}"] = M.DistContext(
                mesh=mesh, moe_chunks=chunks, pipeline_chunks=d,
                moe_strategy="ep_shardmap")
        fns = {{k: jax.jit(lambda p, x, ctx=v: M.moe_ffn(p, x, cfg, ctx)[0])
               for k, v in ctxs.items()}}
        for f in fns.values():
            f(params, x).block_until_ready()                # compile
        blocks = {{k: [] for k in fns}}
        for _ in range({BLOCKS}):
            best = {{k: float("inf") for k in fns}}
            for _ in range({REPEATS}):                      # interleaved
                for k, f in fns.items():
                    t0 = time.perf_counter()
                    f(params, x).block_until_ready()
                    best[k] = min(best[k], time.perf_counter() - t0)
            for k in fns:
                blocks[k].append(best[k])
        row = {{"chunks": chunks,
               "sequential_ms": round(statistics.median(blocks["seq"]) * 1e3, 3)}}
        for d in depths:
            k = f"depth{{d}}"
            # paired per-block ratios: machine drift hits both variants alike
            sp = statistics.median(s / p for s, p in zip(blocks["seq"], blocks[k]))
            row[f"{{k}}_ms"] = round(statistics.median(blocks[k]) * 1e3, 3)
            row[f"{{k}}_speedup"] = round(sp, 3)
        best_d = max(depths, key=lambda d: row[f"depth{{d}}_speedup"])
        row["pipelined_ms"] = row[f"depth{{best_d}}_ms"]
        row["speedup"] = row[f"depth{{best_d}}_speedup"]
        row["pipeline_depth"] = best_d
        rows.append(row)
print(json.dumps(rows))
"""


def run() -> list[str]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "src")
    if os.environ.get("PYTHONPATH"):
        path = path + os.pathsep + os.environ["PYTHONPATH"]
    out = subprocess.run([sys.executable, "-c", _INNER], capture_output=True,
                         text=True, timeout=1800,
                         env={**os.environ, "PYTHONPATH": path})
    if out.returncode != 0:
        raise RuntimeError(f"pipeline microbench subprocess failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    lines = []
    for row in rows:
        lines.append(f"pipeline,chunks={row['chunks']},"
                     f"sequential_ms={row['sequential_ms']:.3f},"
                     f"pipelined_ms={row['pipelined_ms']:.3f},"
                     f"depth={row['pipeline_depth']},"
                     f"speedup={row['speedup']:.3f}")
    with open("BENCH_pipeline.json", "w") as f:
        json.dump({"devices": DEVICES, "tokens_per_device": B * S // DEVICES,
                   "experts": EXPERTS, "top_k": TOP_K, "d": D, "d_ff": D_FF,
                   "blocks": BLOCKS, "repeats": REPEATS, "rows": rows}, f,
                  indent=2)
    lines.append("pipeline,written=BENCH_pipeline.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
