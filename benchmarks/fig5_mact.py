"""Paper Fig. 5: the MACT-selected chunk value over training iterations.

We train the smoke DeepSeek-mini model and drive MACT from the *real* router
load statistics each step, against a deliberately tight memory profile so the
chunk choice is load-sensitive.  The paper's qualitative trace: chunks start
high while routing is chaotic, then settle as experts differentiate (their
Fig. 5 shows large chunks concentrated in early/middle iterations)."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_config
from repro.configs.base import HardwareProfile
from repro.core.moe import DistContext
from repro.training.trainer import Trainer

# a profile tight enough that imbalance forces chunking at smoke scale
TIGHT = HardwareProfile("tight", hbm_bytes=9e6, peak_flops=1, hbm_bw=1,
                        ici_bw=1, alpha=0.9)


def trace(steps: int = 12) -> list[int]:
    cfg = get_config("deepseek-mini-8l").reduced()
    tr = Trainer(cfg, DistContext(), seq_len=128, global_batch=4, lr=1e-3,
                 use_mact=True, hw=TIGHT, static_override=0.0,
                 mact_ep_view=cfg.moe.num_experts)   # every expert = one "GPU"
    tr.fit(steps)
    return tr.chunk_trace


def run() -> list[str]:
    t = trace()
    return [
        "fig5_mact,chunk_trace=" + "|".join(map(str, t)),
        f"fig5_mact,cold_start_c={t[0]},settled_c={t[-1]},"
        f"uses_multiple_bins={len(set(t)) > 1}",
    ]


if __name__ == "__main__":
    print("\n".join(run()))
