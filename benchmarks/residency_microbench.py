"""Expert-balanced decode waves + expert-weight residency at equal budget.

Two claims from docs/DESIGN.md §Residency, measured on a reduced MoE arch:

* **Wave grouping.**  Decode is bandwidth-bound by *activated expert
  weights*, so a wave's cost scales with its distinct activated experts.
  Under a skewed trace (requests cluster into routing families — here,
  repeated-single-token prompts chosen via the router probe), grouping
  waves by predicted expert overlap (``expert_batching``) lowers mean
  distinct activated experts per wave vs FIFO age-order waves of the same
  size, with bitwise-identical outputs (pinned by tests/test_residency.py).
* **Residency headroom.**  With only ``resident_experts`` of E expert
  weights held per layer (cold experts host-offloaded, demand-restored),
  the serving memory model frees weight bytes that admission converts into
  resident request caches: the acceptance target is >= 1.3x admitted
  concurrency at the same budget, zero accepted requests lost, outputs
  bitwise equal to the never-offloaded scheduler.

Emits CSV lines per repo convention and writes ``BENCH_residency.json``
(skipped in tiny/CI mode: SERVING_BENCH_TINY=1 or RESIDENCY_BENCH_TINY=1).
"""

from __future__ import annotations

import json
import os

ARCH = "mixtral-8x7b"
EXPERTS = 8                     # keep the full expert table in reduced()
                                # (top_k=2 of 8: per-request expert sets are
                                # sparse enough for grouping to matter)
SLOTS = 8
WAVE = 2
PREFILL_CHUNK = 8
CACHE_LEN = 160
PROMPT = 8                      # one repeated token id per request
GEN = 12
FAMILY = 4                      # requests per routing family (2 families)
MONO_FIT = 3                    # budget sized to admit ~3 full-weight caches
RESIDENT = 2                    # resident experts per layer in section B


def _family_tokens(params, cfg, ctx):
    """Two token ids whose probed expert sets overlap least — the seeds of
    two routing families the wave grouping can separate."""
    import itertools

    import jax.numpy as jnp
    import numpy as np
    from repro.serving import engine

    probe = engine.get_router_probe(cfg, ctx)
    cand = np.arange(1, min(cfg.vocab_size, 256), dtype=np.int32)
    counts = np.asarray(probe(params, jnp.asarray(cand)))   # (N, L, E)
    sets = [frozenset(np.flatnonzero(c.sum(0) > 0)) for c in counts]
    best = min(itertools.combinations(range(len(cand)), 2),
               key=lambda ab: (len(sets[ab[0]] & sets[ab[1]]),
                               -len(sets[ab[0]] ^ sets[ab[1]])))
    return int(cand[best[0]]), int(cand[best[1]])


def _skewed_trace(tok_a, tok_b, n_per_family, gen=GEN):
    """Interleaved families (rid parity), so FIFO age-order waves mix them
    while the grouped policy can reunite each family."""
    import numpy as np
    from repro.serving.scheduler import Request

    out = []
    for i in range(2 * n_per_family):
        tok = tok_a if i % 2 == 0 else tok_b
        out.append(Request(rid=i,
                           tokens=np.full(PROMPT, tok, np.int32),
                           max_new_tokens=gen, arrival=0.0))
    return out


def _uniform_trace(rng, n, vocab, gen=GEN):
    import numpy as np
    from repro.serving.scheduler import Request

    return [Request(rid=i, tokens=rng.integers(1, vocab, PROMPT)
                    .astype(np.int32), max_new_tokens=gen, arrival=0.0)
            for i in range(n)]


def _budget(cfg):
    """Midpoint between MONO_FIT and MONO_FIT+1 FULL-weight residents: the
    line the residency tier must beat by shedding cold expert bytes."""
    import dataclasses

    from repro.configs.base import GPU_64G
    from repro.core import memory_model as mm
    kw = dict(cache_len=CACHE_LEN, decode_tokens=SLOTS,
              prefill_tokens=PREFILL_CHUNK, dtype_bytes=2)
    lo = mm.serving_peak_bytes(cfg, requests=MONO_FIT, **kw)
    hi = mm.serving_peak_bytes(cfg, requests=MONO_FIT + 1, **kw)
    return dataclasses.replace(GPU_64G, hbm_bytes=(lo + hi) / 2, alpha=1.0)


def run() -> list[str]:
    import dataclasses

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.models import transformer
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)

    tiny = bool(os.environ.get("SERVING_BENCH_TINY")
                or os.environ.get("RESIDENCY_BENCH_TINY"))
    per_family = 2 if tiny else FAMILY
    ctx = DistContext()
    cfg = get_config(ARCH).reduced(max_experts=EXPERTS)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    E = cfg.moe.num_experts
    lines, out = [], {"arch": ARCH, "slots": SLOTS, "wave": WAVE,
                      "experts": E, "requests_per_family": per_family}

    # -- A: grouped vs FIFO waves on a skewed routing trace ------------------
    tok_a, tok_b = _family_tokens(params, cfg, ctx)
    base = ServeConfig(max_slots=SLOTS, cache_len=CACHE_LEN,
                       prefill_chunk=PREFILL_CHUNK, wave_size=WAVE)
    results = {}
    for mode, grouped in (("fifo", False), ("grouped", True)):
        sched = ContinuousBatchingScheduler(
            params, cfg, ctx,
            dataclasses.replace(base, expert_batching=grouped))
        sched.run(_skewed_trace(tok_a, tok_b, 1))      # warm compiles
        sched.reset()
        results[mode] = sched.run(_skewed_trace(tok_a, tok_b, per_family))
    fifo_d = results["fifo"]["mean_distinct_experts"]
    grp_d = results["grouped"]["mean_distinct_experts"]
    wave_row = {
        "family_tokens": [tok_a, tok_b],
        "fifo_mean_distinct_experts": round(fifo_d, 3),
        "grouped_mean_distinct_experts": round(grp_d, 3),
        "reduction_pct": round(100 * (1 - grp_d / fifo_d), 1) if fifo_d else 0,
        "grouped_no_worse": grp_d <= fifo_d,
        "fifo_waves": results["fifo"]["expert_waves"],
        "grouped_waves": results["grouped"]["expert_waves"],
        "forced_includes": results["grouped"]["forced_includes"],
    }
    out["wave_grouping"] = wave_row
    lines.append(
        f"residency_wave,arch={ARCH},fifo_distinct="
        f"{wave_row['fifo_mean_distinct_experts']},grouped_distinct="
        f"{wave_row['grouped_mean_distinct_experts']},reduction_pct="
        f"{wave_row['reduction_pct']},no_worse={wave_row['grouped_no_worse']}")

    # -- B: admitted concurrency at equal budget, residency on vs off --------
    hw = _budget(cfg)
    n_req = SLOTS
    full_cfg = ServeConfig(max_slots=SLOTS, cache_len=CACHE_LEN,
                           prefill_chunk=PREFILL_CHUNK, hw=hw)
    res_cfg = dataclasses.replace(full_cfg, resident_experts=RESIDENT,
                                  prefetch_experts=1)
    runs = {}
    outs = {}
    for mode, scfg in (("full", full_cfg), ("resident", res_cfg)):
        sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
        sched.run(_uniform_trace(np.random.default_rng(1), 2,
                                 cfg.vocab_size))
        sched.reset()
        runs[mode] = sched.run(_uniform_trace(np.random.default_rng(0),
                                              n_req, cfg.vocab_size))
        outs[mode] = {r.rid: list(r.out) for r in sched.finished}
        runs[mode]["_lost"] = n_req - len(sched.finished)
    ratio = (runs["resident"]["max_occupancy"]
             / max(runs["full"]["max_occupancy"], 1))
    res_m = runs["resident"]
    res_row = {
        "budget_gb": round(res_m["budget_bytes"] / 1e9, 4),
        "full_occupancy": runs["full"]["max_occupancy"],
        "resident_occupancy": res_m["max_occupancy"],
        "admitted_ratio": round(ratio, 2),
        "target_1_3x_met": ratio >= 1.3,
        "full_peak_gb": round(runs["full"]["modeled_peak_bytes"] / 1e9, 4),
        "resident_peak_gb": round(res_m["modeled_peak_bytes"] / 1e9, 4),
        "within_budget": (res_m["modeled_peak_bytes"]
                          <= res_m["budget_bytes"]),
        "bitwise_identical": outs["full"] == outs["resident"],
        "accepted_lost": res_m["_lost"],
        "prefetch_hits": res_m["prefetch_hits"],
        "prefetch_misses": res_m["prefetch_misses"],
        "demand_reruns": res_m["demand_reruns"],
        "residency": res_m["residency"],
    }
    out["residency"] = res_row
    lines.append(
        f"residency,arch={ARCH},resident={RESIDENT}/{E},full_occ="
        f"{res_row['full_occupancy']},resident_occ="
        f"{res_row['resident_occupancy']},admitted_ratio="
        f"{res_row['admitted_ratio']},target_1_3x_met="
        f"{res_row['target_1_3x_met']},bitwise={res_row['bitwise_identical']},"
        f"lost={res_row['accepted_lost']}")

    if not tiny:
        with open("BENCH_residency.json", "w") as f:
            json.dump(out, f, indent=2)
        lines.append("residency,written=BENCH_residency.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
