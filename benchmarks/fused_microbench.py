"""Fused single-launch MoE leg vs the three-launch Pallas path.

Per-chunk expert-leg step time across FCDA chunk counts c ∈ {1, 2, 4, 8}
(T = total/c tokens per chunk): the persistent fused kernel
(kernels/fused_moe.py — dispatch -> SwiGLU -> down-proj -> combine in ONE
``pallas_call``) against the three-launch composition (dispatch_rows ->
ragged_expert_ffn -> combine_rows), both jitted in interpret mode.

Three sections:

* **step time** — paired-block timing (min over repeats per block, median of
  per-block paired ratios), the repo's standard drift-robust methodology.
  CPU caveat: interpret mode measures launch/emulation overhead, not MXU
  time — the launch-count and traffic wins are structural, the ratio is a
  trajectory anchor, not a TPU speedup.
* **modeled HBM traffic** — analytic activation bytes per chunk.  The
  three-launch path round-trips the (R, d) dispatch buffer, the (R, f)
  SwiGLU output and the (R, d) FFN output through HBM; the fused kernel
  keeps all three VMEM-resident, so only x in and (T, d) out remain.
  Weight traffic is per-block identical between the paths and excluded.
* **measured autotune** — ``kernels/autotune.autotune`` over the fused
  kernel's contraction tile with the heuristic default as the prepended
  baseline, so autotuned >= heuristic on the selection measurements by
  construction; winners persist to the on-disk cache every kernel consults.
* **MACT schedule shift** — Eq. 2 loses the dispatch-buffer term under
  ``fused``, s'_max grows by (1 + h/g_e), and the planner picks coarser
  (bin, depth) schedules on the deepseek-mini-16l / GPU_64G anchor config.

Emits CSV lines per repo convention and writes ``BENCH_fused.json``.
"""

from __future__ import annotations

import json
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

TINY = bool(os.environ.get("FUSED_BENCH_TINY"))   # CI smoke mode

TOTAL_TOKENS = 64
CHUNK_COUNTS = (2, 8) if TINY else (1, 2, 4, 8)
K, E, D, F, BM = 2, 8, 32, 64, 8
BLOCKS, REPEATS = (2, 2) if TINY else (4, 3)
DTYPE_BYTES = 4

MACT_ARCH = "deepseek-mini-16l"
MACT_SEQS = (4096,) if TINY else (4096, 8192, 16384)
MACT_STATIC = 43e9               # measured-M_sta anchor (adaptive_microbench)


def _case(T, seed):
    from repro.core import dispatch as dsp
    rng = np.random.default_rng(seed)
    topk = np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
    R = -(-(T * K + E * BM) // BM) * BM
    plan = dsp.make_ragged_plan(jnp.asarray(topk, jnp.int32), E, R, BM)
    x = jnp.asarray(rng.standard_normal((T, D)) * 0.5, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((E, D, F)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((E, F, D)) * 0.1, jnp.float32)
    wtk = jnp.asarray(rng.random((T, K)), jnp.float32)
    return plan, R, x, w1, w3, w2, wtk


def _paired_time(fns):
    """{name: zero-arg fn} -> {name: median-of-block-min seconds}, plus the
    per-block lists for paired ratios."""
    blocks = {k: [] for k in fns}
    for _ in range(BLOCKS):
        best = {k: float("inf") for k in fns}
        for _ in range(REPEATS):                      # interleaved
            for k, f in fns.items():
                t0 = time.perf_counter()
                f()
                best[k] = min(best[k], time.perf_counter() - t0)
        for k in fns:
            blocks[k].append(best[k])
    return {k: statistics.median(v) for k, v in blocks.items()}, blocks


def _hbm_model(T, R_live):
    """Analytic activation HBM bytes per chunk (weights excluded: per-block
    reads are identical between the paths)."""
    three = DTYPE_BYTES * (2 * T * D          # x in, out
                           + 4 * R_live * D   # dispatch buf + FFN out, w+r
                           + 2 * R_live * F)  # SwiGLU intermediate, w+r
    fused = DTYPE_BYTES * (2 * T * D)
    return three, fused


def run() -> list[str]:
    from repro.core.mact import MACTController
    from repro.configs import get_config
    from repro.configs.base import GPU_64G
    from repro.core import memory_model as mm
    from repro.kernels import autotune
    from repro.kernels.ops import (combine_rows, dispatch_rows, moe_ffn,
                                   ragged_expert_ffn)
    from repro.kernels.tiling import resolve_tiles

    lines, rows, tune_rows = [], [], []

    for c in CHUNK_COUNTS:
        T = TOTAL_TOKENS // c
        plan, R, x, w1, w3, w2, wtk = _case(T, seed=c)

        def fused_fn(x, w1, w3, w2, wtk, block_k=None):
            return moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                           plan.total_rows, wtk, block_m=BM, block_k=block_k,
                           use_pallas=True, interpret=True)

        def three_fn(x, w1, w3, w2, wtk):
            buf = dispatch_rows(x, plan.slots, R, plan.total_rows,
                                use_pallas=True, interpret=True, block_m=BM)
            y = ragged_expert_ffn(buf, w1, w3, w2, plan.block_to_expert,
                                  plan.total_rows, block_m=BM,
                                  use_pallas=True, interpret=True)
            return combine_rows(y, plan.slots, wtk, plan.total_rows,
                                use_pallas=True, interpret=True)

        jf, jt = jax.jit(fused_fn), jax.jit(three_fn)
        args = (x, w1, w3, w2, wtk)
        np.testing.assert_allclose(jf(*args), jt(*args),
                                   rtol=1e-4, atol=1e-4)   # sanity
        for f in (jf, jt):
            f(*args).block_until_ready()                   # compile
        med, blocks = _paired_time({
            "fused": lambda: jf(*args).block_until_ready(),
            "three": lambda: jt(*args).block_until_ready()})
        speedup = statistics.median(
            t / f for t, f in zip(blocks["three"], blocks["fused"]))

        R_live = int(plan.total_rows)
        hbm_three, hbm_fused = _hbm_model(T, R_live)
        row = {"chunks": c, "tokens_per_chunk": T, "rows_live": R_live,
               "three_launch_ms": round(med["three"] * 1e3, 3),
               "fused_ms": round(med["fused"] * 1e3, 3),
               "speedup": round(speedup, 3),
               "hbm_model_three_bytes": hbm_three,
               "hbm_model_fused_bytes": hbm_fused,
               "hbm_model_ratio": round(hbm_three / hbm_fused, 2)}
        rows.append(row)
        lines.append(f"fused,chunks={c},tokens={T},"
                     f"three_launch_ms={row['three_launch_ms']:.3f},"
                     f"fused_ms={row['fused_ms']:.3f},"
                     f"speedup={row['speedup']:.3f},"
                     f"hbm_model_ratio={row['hbm_model_ratio']:.2f}")

        # measured autotune over the contraction tile; the heuristic default
        # is the prepended baseline, so winner <= baseline by construction
        shape = (T, D, F, E, BM)

        def make_fn(bk, _fused=fused_fn, _args=args):
            f = jax.jit(lambda *a: _fused(*a, block_k=bk))
            return lambda: f(*_args).block_until_ready()

        res = autotune.autotune(
            "fused_moe", shape, x.dtype, make_fn,
            [{"bk": b} for b in (4, 8, 16, 32)],
            baseline={"bk": 512}, blocks=3, repeats=2)
        resolved = resolve_tiles("fused_moe", shape, x.dtype, {"bk": 512})
        trow = {"shape": list(shape), "winner": res.winner,
                "autotuned_ms": round(res.winner_ms, 3),
                "heuristic_ms": round(res.baseline_ms, 3),
                "speedup_vs_heuristic": round(res.speedup_vs_baseline, 3),
                "cache_resolves_to": resolved}
        tune_rows.append(trow)
        lines.append(f"fused,autotune,tokens={T},"
                     f"heuristic_ms={trow['heuristic_ms']:.3f},"
                     f"autotuned_ms={trow['autotuned_ms']:.3f},"
                     f"winner_bk={res.winner['bk']},"
                     f"speedup={trow['speedup_vs_heuristic']:.3f}")

    # MACT schedule shift: Eq. 2 without the dispatch-buffer round trip
    cfg = get_config(MACT_ARCH)
    par = mm.Parallelism(t=1, p=4, e=32, b=1)
    mact_rows = []
    for seq in MACT_SEQS:
        ctl = {f: MACTController(cfg, par, GPU_64G, seq,
                                 static_override=MACT_STATIC, fused=f)
               for f in (False, True)}
        sched = {f: ctl[f].choose_schedule(max_depth=2) for f in ctl}
        ratio = ctl[True].s_prime_max() / ctl[False].s_prime_max()
        mact_rows.append({"seq_len": seq,
                          "schedule_three_launch": list(sched[False]),
                          "schedule_fused": list(sched[True]),
                          "s_prime_max_ratio": round(ratio, 2)})
        lines.append(f"fused,mact,seq={seq},"
                     f"sched={tuple(sched[False])}->{tuple(sched[True])},"
                     f"s_max_ratio={ratio:.2f}")

    with open("BENCH_fused.json", "w") as f:
        json.dump({"total_tokens": TOTAL_TOKENS, "top_k": K, "experts": E,
                   "d": D, "d_ff": F, "block_m": BM, "blocks": BLOCKS,
                   "repeats": REPEATS, "rows": rows, "autotune": tune_rows,
                   "mact": {"arch": MACT_ARCH, "parallelism": "t1 p4 e32 b1",
                            "static_gb": MACT_STATIC / 1e9,
                            "rows": mact_rows},
                   "autotune_cache": autotune.cache_path()}, f, indent=2)
    lines.append("fused,written=BENCH_fused.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
