"""Ablation (paper §2.2 argument): capacity-factor load balancing (GShard)
drops tokens and hurts the loss, while MemFine stays dropless at bounded
memory.  We train the same smoke MoE with (a) dropless + FCDA chunking and
(b) a hard capacity cap, and report drop counts and final CE."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core.moe import DistContext
from repro.training.trainer import Trainer

STEPS = 10


def _run(capacity_mode: str, factor: float = 1.0):
    base = get_config("mixtral-8x7b").reduced()
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_mode=capacity_mode,
                                      capacity_factor=factor))
    tr = Trainer(cfg, DistContext(moe_chunks=2), seq_len=64, global_batch=4,
                 lr=2e-3, use_mact=False, seed=3)
    tr.fit(STEPS)
    ce = np.mean([r["ce"] for r in tr.log[-3:]])
    drops = np.sum([r["drops"] for r in tr.log])
    return ce, drops


def run() -> list[str]:
    ce_dropless, d0 = _run("dropless")
    ce_cap, d1 = _run("capacity", 0.75)
    return [
        f"ablation_capacity,dropless_memfine,final_ce={ce_dropless:.4f},"
        f"dropped_tokens={d0:.0f}",
        f"ablation_capacity,capacity_0.75,final_ce={ce_cap:.4f},"
        f"dropped_tokens={d1:.0f}",
        f"ablation_capacity,dropless_better={ce_dropless <= ce_cap},"
        f"paper_claim=capacity_hurts_convergence",
    ]


if __name__ == "__main__":
    print("\n".join(run()))
