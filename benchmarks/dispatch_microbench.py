"""Dispatch-planning microbench: the per-chunk cost MACT multiplies.

Three variants of the per-chunk dispatch -> (identity expert) -> combine
path, timed across FCDA chunk counts c ∈ {1, 2, 4, 8}:

  * ``two_sort``    — the old construction: one stable argsort for the
    device plan + one for the expert/ragged plan, ``.at[].add`` scatters.
  * ``single_sort`` — the unified planner (one argsort; the receiver plan
    falls out of cumsums over the counts matrix), jnp scatters.
  * ``pallas_interp`` — single-sort planner + the Pallas scatter/gather
    kernels in interpret mode (functional check of the kernel path; on CPU
    the interpreter adds overhead, so treat these numbers as a trajectory
    anchor for TPU runs, not a win in themselves).

Emits CSV lines per repo convention and writes ``BENCH_dispatch.json`` so
later PRs can track the trajectory.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as dsp
from repro.kernels import ops

T_TOTAL = 2048          # tokens per step (split into c chunks)
E = 8                   # experts
K = 2                   # top_k
D = 64                  # model dim
BLOCK = 128             # ragged block
CHUNKS = (1, 2, 4, 8)
REPEATS = 20


def _chunk_fn_two_sort(xc, idx):
    """The old EP chunk: argsort #1 for the device plan, scatter into the
    send buffer, argsort #2 for the ragged plan over the received rows."""
    t_c = xc.shape[0]
    cap_send = t_c * K
    R = cap_send + E * BLOCK
    R = -(-R // BLOCK) * BLOCK
    plan_dev = dsp.make_plan(idx // E, 1, cap_send)            # sort #1
    send = dsp.scatter_rows(xc, plan_dev, 1, cap_send)
    eid = dsp.scatter_values(idx, plan_dev, 1, cap_send,
                             fill=jnp.int32(-1)).reshape(-1)
    rows = send.reshape(cap_send, -1)                          # P=1: no a2a
    valid = eid >= 0
    plan_r = dsp.make_ragged_plan(                             # sort #2
        jnp.where(valid, eid, E)[:, None], E, R, BLOCK,
        valid=valid[:, None])
    buf = dsp.scatter_rows_flat(rows, plan_r.slots, R)
    back = dsp.gather_rows_flat(buf, plan_r.slots)
    return dsp.gather_rows(back.reshape(1, cap_send, -1), plan_dev,
                           jnp.ones((t_c, K), xc.dtype))


def _chunk_fn_single_sort(xc, idx, use_pallas=False):
    """The new EP chunk: ONE argsort; the receiver plan falls out of
    cumsums over the counts matrix."""
    t_c = xc.shape[0]
    cap_send = t_c * K
    R = cap_send + E * BLOCK
    R = -(-R // BLOCK) * BLOCK
    up = dsp.make_unified_plan(idx, E, 1, cap_send=cap_send)   # THE sort
    send = ops.dispatch_rows(xc, up.send_slots, cap_send,
                             use_pallas=use_pallas, interpret=use_pallas)
    eid = dsp.eids_from_counts(up.counts, cap_send)            # no eid buffer
    plan_r = dsp.recv_ragged_plan(up.counts, eid, R, BLOCK)    # no sort
    buf = ops.dispatch_rows(send, plan_r.slots, R,
                            total_rows=plan_r.total_rows,
                            use_pallas=use_pallas, interpret=use_pallas)
    back = ops.combine_rows(buf, plan_r.slots, use_pallas=use_pallas,
                            interpret=use_pallas)
    return ops.combine_rows(back, up.send_slots,
                            jnp.ones((t_c, K), xc.dtype),
                            use_pallas=use_pallas, interpret=use_pallas)


def _time_variant(name, fn, chunks):
    t_c = T_TOTAL // chunks
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T_TOTAL, D)), jnp.float32)
    idx = jnp.asarray(
        np.stack([rng.permutation(E)[:K] for _ in range(T_TOTAL)]), jnp.int32)

    @jax.jit
    def step(x, idx):
        xs = x.reshape(chunks, t_c, D)
        ids = idx.reshape(chunks, t_c, K)
        ys = jax.lax.map(lambda a: fn(a[0], a[1]), (xs, ids))
        return ys.reshape(T_TOTAL, D)

    step(x, idx).block_until_ready()            # compile
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        step(x, idx).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3                           # min-of-N: robust to CPU noise


def run() -> list[str]:
    variants = {
        "two_sort": _chunk_fn_two_sort,
        "single_sort": lambda xc, idx: _chunk_fn_single_sort(xc, idx, False),
        "pallas_interp": lambda xc, idx: _chunk_fn_single_sort(xc, idx, True),
    }
    lines, results = [], []
    for chunks in CHUNKS:
        row = {"chunks": chunks}
        for name, fn in variants.items():
            if name == "pallas_interp" and chunks > 2:
                continue            # interpreter is slow; 2 points anchor it
            ms = _time_variant(name, fn, chunks)
            row[name] = round(ms, 3)
            lines.append(f"dispatch,{name},chunks={chunks},ms={ms:.3f}")
        if "two_sort" in row and "single_sort" in row:
            speedup = row["two_sort"] / max(row["single_sort"], 1e-9)
            row["speedup_single_vs_two"] = round(speedup, 3)
            lines.append(f"dispatch,speedup,chunks={chunks},"
                         f"single_vs_two_sort={speedup:.3f}")
        results.append(row)
    with open("BENCH_dispatch.json", "w") as f:
        json.dump({"tokens": T_TOTAL, "experts": E, "top_k": K, "d": D,
                   "repeats": REPEATS, "rows": results}, f, indent=2)
    lines.append("dispatch,written=BENCH_dispatch.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
