"""Adaptive per-layer MACT vs static global schedules under drifting skew.

Two measurements (docs/DESIGN.md §Adaptive):

1. **Modeled memory, controller in the loop** — a synthetic per-layer load
   stream drifts over T steps (one layer ramps to ~7x the uniform load, one
   sits mid-skew, the rest idle with +-5% noise).  The stream feeds the
   telemetry EMA -> ``choose_layer_schedules`` (re-plan interval + load-margin
   hysteresis), and the Eq. 2/9 model scores every step's peak activation
   (max over layers: chunk recompute keeps one layer's buffers live).
   Compared against (a) the full static (bin, depth) grid applied globally
   and (b) the *offline* static baseline — the schedule a pre-adaptive MACT
   plans once from the step-0 estimate and never revisits.  The adaptive
   controller must pick >= 2 distinct layer schedules, match or beat the
   best static grid point on peak modeled memory, and emit no more distinct
   schedule vectors (= trainer recompiles) than the bucketed key bound.

2. **Measured throughput** — real jitted train steps of a small 4-MoE-layer
   model on the local path: the adaptive heterogeneous schedule vector vs
   the best-memory static global schedule, timed interleaved in paired
   blocks (median of per-block ratios, same methodology as the pipeline
   microbench).  Cool layers running 1-2 chunks instead of the hot layer's 8
   is pure overhead removed, so the adaptive vector should be at worst
   within 5% of — and typically faster than — the static schedule.

Emits CSV lines per repo convention and writes ``BENCH_adaptive.json``.
"""

from __future__ import annotations

import json
import statistics
import time

import numpy as np

T_STEPS = 60
LAYERS = 4
REPLAN = 5
HYSTERESIS = 0.1
HEADROOM = 0.3
EMA_DECAY = 0.6
MAX_DEPTH = 2
SEQ = 4096

BLOCKS = 5
REPEATS = 5


def _controller():
    from repro.configs import GPU_64G, get_config
    from repro.core.mact import MACTController
    from repro.core.memory_model import Parallelism

    # the mact_tuning operating point: s'_max ~ 5.1e5 tokens on a 64 GB GPU
    return MACTController(get_config("deepseek-mini-16l"),
                          Parallelism(t=1, p=4, e=32, b=1), GPU_64G,
                          seq_len=SEQ, static_override=43e9)


def _load_stream(s_max: float):
    """(T, LAYERS, E) loads: layer 3 ramps 0.8->7x s'_max, layer 2 mid-skew,
    layers 0-1 idle with +-5% sinusoidal noise (the hysteresis workout)."""
    E = 8
    out = np.zeros((T_STEPS, LAYERS, E))
    for t in range(T_STEPS):
        noise = 1.0 + 0.05 * np.sin(2.2 * t)
        s_pp = [0.8 * s_max * noise,                       # cool
                0.8 * s_max * noise,                       # cool
                1.8 * s_max,                               # mid
                s_max * (0.8 + 6.2 * t / (T_STEPS - 1))]   # drifting hot
        for j in range(LAYERS):
            out[t, j] = s_pp[j] / E
    return out


def _peak_gb(mact, schedules, loads_t) -> float:
    """Modeled peak bytes at one step: static + the worst layer's Eq. 2
    activation under its schedule (chunk recompute: one layer live)."""
    from repro.core import memory_model as mm

    acts = []
    for j, (b, d) in enumerate(schedules):
        s_pp = float(loads_t[j].sum())
        acts.append(mm.activation_bytes(mact.dims, SEQ, s_pp, mact.par,
                                        chunks=b, pipeline_depth=d))
    return (mact.static + max(acts)) / 2**30


def _model_part(lines: list[str]) -> dict:
    from repro.core.telemetry import LoadTelemetry

    mact = _controller()
    s_max = mact.s_prime_max()
    stream = _load_stream(s_max)
    telemetry = LoadTelemetry(LAYERS, stream.shape[-1], decay=EMA_DECAY)

    vectors, peaks, cur = [], [], None
    for t in range(T_STEPS):
        if cur is None or t % REPLAN == 0:
            cur = mact.choose_layer_schedules(
                telemetry.loads, LAYERS, ep_size=1, max_depth=MAX_DEPTH,
                current=cur, hysteresis=HYSTERESIS, headroom=HEADROOM)
        vectors.append(cur)
        peaks.append(_peak_gb(mact, cur, stream[t]))
        telemetry.update(stream[t])

    distinct_vectors = sorted({tuple(map(tuple, v)) for v in vectors})
    final = vectors[-1]
    distinct_layer_scheds = sorted({tuple(s) for s in final})

    # static grid: every (bin, depth) the controller could pick, global
    grid = {}
    for sched in mact.schedule_space(MAX_DEPTH):
        vec = tuple([sched] * LAYERS)
        grid[tuple(sched)] = max(_peak_gb(mact, vec, stream[t])
                                 for t in range(T_STEPS))
    best_static = min(grid, key=grid.get)

    # offline baseline: plan once from the step-0 estimate, never revisit
    offline = mact.choose_layer_schedules(stream[0], LAYERS, ep_size=1,
                                          max_depth=MAX_DEPTH)
    offline_peak = max(_peak_gb(mact, offline, stream[t])
                       for t in range(T_STEPS))

    space = mact.schedule_space(MAX_DEPTH)
    bound = len(space) ** LAYERS
    adaptive_peak = max(peaks)
    res = {
        "adaptive_peak_gb": round(adaptive_peak, 3),
        "best_static": {"schedule": list(best_static),
                        "peak_gb": round(grid[best_static], 3)},
        "static_grid": {f"b{b}d{d}": round(v, 3)
                        for (b, d), v in sorted(grid.items())},
        "offline_static": {"schedule": [list(s) for s in offline],
                           "peak_gb": round(offline_peak, 3)},
        "final_layer_schedules": [list(s) for s in final],
        "distinct_layer_schedules": len(distinct_layer_scheds),
        "recompiles": len(distinct_vectors),
        "schedule_key_space_per_layer": len(space),
        "schedule_key_bound": bound,
        "replan_interval": REPLAN,
        "hysteresis": HYSTERESIS,
        "headroom": HEADROOM,
    }
    lines.append(
        f"adaptive,distinct_schedules={res['distinct_layer_schedules']},"
        f"adaptive_peak_gb={res['adaptive_peak_gb']:.3f},"
        f"best_static_peak_gb={grid[best_static]:.3f},"
        f"offline_static_peak_gb={offline_peak:.3f},"
        f"recompiles={res['recompiles']},bound={bound}")
    assert res["distinct_layer_schedules"] >= 2
    assert adaptive_peak <= grid[best_static] * 1.0001
    assert res["recompiles"] <= bound
    return res


def _throughput_part(lines: list[str], final_scheds) -> dict:
    import jax

    from repro.configs.base import (AttentionSpec, LayerSpec, ModelConfig,
                                    MoEConfig)
    from repro.core.chunking import ScheduleSpec
    from repro.core.moe import DistContext
    from repro.data.pipeline import SyntheticLMData
    from repro.training.step import init_train_state, make_train_step

    cfg = ModelConfig(
        name="adaptive-bench", family="moe", source="benchmarks",
        num_layers=LAYERS, d_model=128, num_heads=8, num_kv_heads=4,
        d_ff=256, vocab_size=1024,
        pattern=(LayerSpec(mixer="attn", ffn="moe", attn=AttentionSpec()),),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=256),
        dtype="float32")
    vec = tuple(ScheduleSpec(*s) for s in final_scheds)
    hot_bin = max(s[0] for s in vec)
    ctxs = {
        "static": DistContext(moe_chunks=hot_bin),      # best-memory global
        "adaptive": DistContext(layer_schedules=vec),
    }
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {k: jax.numpy.asarray(v) for k, v in
             SyntheticLMData(cfg, 256, 4).batch_at(0).items()}
    fns = {k: jax.jit(make_train_step(cfg, ctx, lr=1e-3))
           for k, ctx in ctxs.items()}
    for f in fns.values():
        f(state, batch)[1]["loss"].block_until_ready()   # compile
    blocks = {k: [] for k in fns}
    for _ in range(BLOCKS):
        best = {k: float("inf") for k in fns}
        for _ in range(REPEATS):                          # interleaved
            for k, f in fns.items():
                t0 = time.perf_counter()
                f(state, batch)[1]["loss"].block_until_ready()
                best[k] = min(best[k], time.perf_counter() - t0)
        for k in fns:
            blocks[k].append(best[k])
    ratio = statistics.median(a / s for a, s in
                              zip(blocks["adaptive"], blocks["static"]))
    res = {
        "static_ms": round(statistics.median(blocks["static"]) * 1e3, 3),
        "adaptive_ms": round(statistics.median(blocks["adaptive"]) * 1e3, 3),
        "throughput_cost_pct": round((ratio - 1.0) * 100, 2),
        "schedule_vector": [list(s) for s in vec],
        "static_chunks": hot_bin,
    }
    lines.append(
        f"adaptive,static_ms={res['static_ms']:.3f},"
        f"adaptive_ms={res['adaptive_ms']:.3f},"
        f"throughput_cost_pct={res['throughput_cost_pct']:+.2f}")
    return res


def run() -> list[str]:
    lines: list[str] = []
    model = _model_part(lines)
    # the measured part runs the depth-1 projection of the final vector: the
    # local (tp_gspmd) path has no all-to-all to overlap, so depth is moot
    proj = [(b, 1) for b, _ in model["final_layer_schedules"]]
    thr = _throughput_part(lines, proj)
    with open("BENCH_adaptive.json", "w") as f:
        json.dump({"steps": T_STEPS, "layers": LAYERS, "seq_len": SEQ,
                   "model": model, "throughput": thr}, f, indent=2)
    lines.append("adaptive,written=BENCH_adaptive.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
