"""Paper Table 4: per-method memory on Model I/II (t=1 p=4 e=32 b=1 s=4096).

Method 1: no chunking + full recomputation (Megatron baseline).
Method 2: MemFine, fixed c=8.
Method 3: MemFine + MACT (bins [1,2,4,8]).

We report the theoretical-model numbers with the calibrated s'' (docs/DESIGN.md)
next to the paper's measured GB, and the reduction ratios the paper headlines
(-83.84 % / -48.03 %).  Units follow the paper's table (decimal GB).
"""

from __future__ import annotations

from repro.configs import GPU_64G, get_config
from repro.core import memory_model as mm
from repro.core.mact import MACTController

PAR = mm.Parallelism(t=1, p=4, c=1, e=32, d=1, b=1)
S = 4096
S_PP = 5.97e5                    # calibrated observed worst per-GPU tokens
PAPER = {  # model -> method -> (static GB, active GB)
    "deepseek-mini-16l": {1: (43.0, 22.9), 2: (43.0, 3.7), 3: (43.0, 11.9)},
    "deepseek-mini-8l": {1: (39.5, 22.9), 2: (39.5, 3.7), 3: (39.5, 11.9)},
}


def rows():
    out = []
    for model, paper in PAPER.items():
        cfg = get_config(model)
        dims = mm.LayerDims.from_config(cfg)
        mact = MACTController(cfg, PAR, GPU_64G, seq_len=S,
                              static_override=paper[1][0] * 1e9)
        c3 = mact.snap(mact.optimal_c(S_PP))
        base = mm.activation_bytes(dims, S, S_PP, PAR, chunks=1)
        for method, chunks in ((1, 1), (2, 8), (3, c3)):
            act = mm.activation_bytes(dims, S, S_PP, PAR, chunks=chunks)
            fits = mm.fits(paper[method][0] * 1e9, act, GPU_64G)
            out.append({
                "model": model, "method": method, "chunks": chunks,
                "active_gb_model": act / 1e9,
                "active_gb_paper": paper[method][1],
                "reduction_vs_m1": 1 - act / base,
                "trains": fits,
            })
    return out


def run() -> list[str]:
    lines = []
    for r in rows():
        paper_red = {1: 0.0, 2: 0.8384, 3: 0.4803}[r["method"]]
        lines.append(
            f"table4_memory,{r['model']},method{r['method']},c={r['chunks']},"
            f"active_model={r['active_gb_model']:.2f}GB,"
            f"active_paper={r['active_gb_paper']}GB,"
            f"reduction={r['reduction_vs_m1']*100:.2f}%,"
            f"paper_reduction={paper_red*100:.2f}%,trains={r['trains']}")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
