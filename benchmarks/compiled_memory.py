"""Beyond the paper's Table 4: the SAME comparison measured from the XLA
buffer assignment of the production-mesh dry-run (paper Model II, train_4k,
256 chips), not just the theoretical model.

Reads the cached sweep results when present; otherwise launches the dry-run
subprocess per chunk setting (c=1 Method 1 analogue, c=2, c=8).  Note the
CPU-backend bf16 legalization inflates absolute bytes ~2x vs TPU (docs/DESIGN.md);
the RATIOS are the result.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

ARCH = "deepseek-mini-8l"
SHAPE = "train_4k"
OUT = "results/dryrun"


def _path(tag: str) -> str:
    suffix = f"_{tag}" if tag else ""
    return os.path.join(OUT, f"{ARCH}__{SHAPE}{suffix}.json")


def _ensure(chunks: int, tag: str) -> dict:
    p = _path(tag)
    if not os.path.exists(p):
        env = {**os.environ, "PYTHONPATH": "src"}
        subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", ARCH,
             "--shape", SHAPE, "--chunks", str(chunks), "--tag", tag,
             "--out", OUT],
            env=env, check=False, capture_output=True, timeout=900)
    with open(p) as f:
        return json.load(f)


def run() -> list[str]:
    recs = {}
    for chunks, tag in ((1, "c1"), (2, "c2"), (8, "c8")):
        try:
            recs[chunks] = _ensure(chunks, tag)
        except FileNotFoundError:
            return [f"compiled_memory,SKIPPED (dry-run unavailable for c={chunks})"]
    base = recs[1]["memory"]["temp_bytes"]
    lines = []
    for c, rec in sorted(recs.items()):
        t = rec["memory"]["temp_bytes"]
        lines.append(
            f"compiled_memory,{ARCH},{SHAPE},c={c},"
            f"temp_gb={t / 1e9:.1f},reduction_vs_c1={(1 - t / base) * 100:.1f}%")
    lines.append("compiled_memory,note=absolute_bytes_inflated_~2x_by_cpu_"
                 "bf16_legalization;ratios_are_the_result")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
