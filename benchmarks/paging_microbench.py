"""Paged vs monolithic serving cache under an equal memory budget.

The monolithic slot map reserves every resident's FULL ``cache_len`` ring
up front, so a budget that fits k max-length caches admits exactly k
requests no matter how short they are.  The paged scheduler
(docs/DESIGN.md §Paging) charges allocated pages plus each resident's
worst-case tail, so short requests on a long ``cache_len`` admit at far
higher concurrency — the acceptance target for this bench is >= 1.3x
admitted concurrency on the short-request trace, at the same budget.

Second axis: the prefix-cache sweep.  Requests share a system-prompt stem
of varying length; the trie skips the shared chunks on every hit, so
prefill chunk count (and time-to-first-token work) drops with stem length
while outputs stay bit-identical (pinned by tests/test_paging.py).

Emits CSV lines per repo convention and writes ``BENCH_paging.json``
(skipped in tiny/CI mode: SERVING_BENCH_TINY=1 or PAGING_BENCH_TINY=1).
"""

from __future__ import annotations

import json
import os

ARCH = "llama3.2-3b"
SLOTS = 8
PAGE = 8
PREFILL_CHUNK = 16
CACHE_LEN = 160                 # long budget line; requests use ~32 tokens
PROMPT = 16
GEN = 16
REQUESTS = 16
TINY_REQUESTS = 6
MONO_FIT = 3                    # budget sized to admit ~3 monolithic caches
STEMS = (0, 16, 32)             # prefix-sweep shared stem lengths
SWEEP_PROMPT = 40               # total prompt length in the prefix sweep


def _trace(rng, n, vocab, stem_len=0, stem=None, prompt=PROMPT):
    import numpy as np
    from repro.serving.scheduler import Request

    out = []
    for i in range(n):
        tail = rng.integers(0, vocab, prompt - stem_len).astype(np.int32)
        toks = tail if stem_len == 0 else np.concatenate([stem[:stem_len],
                                                          tail])
        out.append(Request(rid=i, tokens=toks, max_new_tokens=GEN,
                           arrival=0.0))
    return out


def _budget(cfg):
    """Midpoint between MONO_FIT and MONO_FIT+1 monolithic residents."""
    import dataclasses

    from repro.configs.base import GPU_64G
    from repro.core import memory_model as mm
    kw = dict(cache_len=CACHE_LEN, decode_tokens=SLOTS,
              prefill_tokens=PREFILL_CHUNK, dtype_bytes=2)
    lo = mm.serving_peak_bytes(cfg, requests=MONO_FIT, **kw)
    hi = mm.serving_peak_bytes(cfg, requests=MONO_FIT + 1, **kw)
    return dataclasses.replace(GPU_64G, hbm_bytes=(lo + hi) / 2, alpha=1.0)


def run() -> list[str]:
    import time

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.models import transformer
    from repro.serving.paged_scheduler import PagedScheduler
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)

    tiny = bool(os.environ.get("SERVING_BENCH_TINY")
                or os.environ.get("PAGING_BENCH_TINY"))
    n_requests = TINY_REQUESTS if tiny else REQUESTS
    ctx = DistContext()
    cfg = get_config(ARCH).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    hw = _budget(cfg)
    lines, out = [], {"arch": ARCH, "slots": SLOTS, "page": PAGE,
                      "cache_len": CACHE_LEN, "requests": n_requests}

    # -- admitted concurrency at equal budget -------------------------------
    mono = ContinuousBatchingScheduler(
        params, cfg, ctx,
        ServeConfig(max_slots=SLOTS, cache_len=CACHE_LEN,
                    prefill_chunk=PREFILL_CHUNK, hw=hw))
    paged = PagedScheduler(
        params, cfg, ctx,
        ServeConfig(max_slots=SLOTS, cache_len=CACHE_LEN,
                    prefill_chunk=PREFILL_CHUNK, hw=hw, page_size=PAGE))
    for sched in (mono, paged):          # warm compiles, then reset
        sched.run(_trace(np.random.default_rng(1), 3, cfg.vocab_size))
        sched.reset()
    mm_ = mono.run(_trace(np.random.default_rng(0), n_requests,
                          cfg.vocab_size))
    pm = paged.run(_trace(np.random.default_rng(0), n_requests,
                          cfg.vocab_size))
    conc = pm["max_occupancy"] / max(mm_["max_occupancy"], 1)
    row = {
        "mono_occupancy": mm_["max_occupancy"],
        "paged_occupancy": pm["max_occupancy"],
        "concurrency_x": round(conc, 2),
        "target_1_3x_met": conc >= 1.3,
        "mono_tok_s": round(mm_["tok_per_s"], 2),
        "paged_tok_s": round(pm["tok_per_s"], 2),
        "mono_peak_gb": round(mm_["modeled_peak_bytes"] / 1e9, 4),
        "paged_peak_gb": round(pm["modeled_peak_bytes"] / 1e9, 4),
        "page_hwm_gb": round(pm["page_hwm_bytes"] / 1e9, 4),
        "budget_gb": round(pm["budget_bytes"] / 1e9, 4),
        "within_budget": (pm["modeled_peak_bytes"] <= pm["budget_bytes"]
                          and mm_["modeled_peak_bytes"]
                          <= mm_["budget_bytes"]),
    }
    out["concurrency"] = row
    lines.append(
        f"paging,arch={ARCH},mono_occ={row['mono_occupancy']},"
        f"paged_occ={row['paged_occupancy']},"
        f"concurrency_x={row['concurrency_x']},"
        f"target_1_3x_met={row['target_1_3x_met']},"
        f"within_budget={row['within_budget']}")

    # -- prefix-hit sweep ----------------------------------------------------
    sweep = []
    rngs = np.random.default_rng(7)
    stem = rngs.integers(0, cfg.vocab_size, max(STEMS)).astype(np.int32)
    for stem_len in STEMS:
        sched = PagedScheduler(
            params, cfg, ctx,
            ServeConfig(max_slots=4, cache_len=CACHE_LEN,
                        prefill_chunk=PREFILL_CHUNK, page_size=PAGE,
                        prefix_cache=True))
        sched.run(_trace(np.random.default_rng(2), 3, cfg.vocab_size,
                         stem_len, stem, prompt=SWEEP_PROMPT))
        sched.reset()
        t0 = time.perf_counter()
        m = sched.run(_trace(np.random.default_rng(3), n_requests,
                             cfg.vocab_size, stem_len, stem,
                             prompt=SWEEP_PROMPT))
        dt = time.perf_counter() - t0
        sweep.append({
            "stem": stem_len,
            "hit_rate": round(m["prefix_hit_rate"], 3),
            "tokens_reused": m["prefix_tokens_reused"],
            "prefill_chunks": m["prefill_chunks"],
            "tok_s": round(m["generated_tokens"] / dt, 2),
        })
        lines.append(
            f"paging_prefix,stem={stem_len},"
            f"hit_rate={sweep[-1]['hit_rate']},"
            f"tokens_reused={sweep[-1]['tokens_reused']},"
            f"prefill_chunks={sweep[-1]['prefill_chunks']}")
    out["prefix_sweep"] = sweep

    if not tiny:
        with open("BENCH_paging.json", "w") as f:
            json.dump(out, f, indent=2)
        lines.append("paging,written=BENCH_paging.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
