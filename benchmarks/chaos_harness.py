"""Chaos benchmark: score the resilience runtime under injected faults.

Three scenarios (docs/DESIGN.md §Resilience), all on reduced configs so the
CPU container runs them end to end:

* **training** — two runs with identical chaos (a routing-load burst one
  step before an injected RESOURCE_EXHAUSTED at a skewed step): run A is
  never killed; run B additionally gets its newest checkpoint truncated and
  a hard crash, then auto-resumes from the newest *valid* checkpoint.  Both
  must complete with bounded ladder retries, and run B's final TrainState
  must equal run A's **bit for bit** — the kill-and-resume parity the
  self-healing checkpoint path promises.
* **serving/faulted** — the same request trace with and without an injected
  decode-wave OOM.  The faulted run must finish every accepted request
  (requeue-on-eviction; zero accepted-request loss) with greedy outputs
  identical to the unfaulted run, degrading only in latency.
* **serving/overload** — a tight admission deadline plus a WAITING-queue
  bound: excess requests are shed with a client-visible retry-after while
  the survivors' latency stays bounded — shedding, not crashing.

Emits CSV lines per repo convention and writes ``BENCH_chaos.json``
(skipped in tiny/CI mode: CHAOS_BENCH_TINY=1), which feeds the README
fault-tolerance row via scripts/gen_results_table.py.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

TRAIN_ARCH = "deepseek-mini-8l"
SERVE_ARCH = "mixtral-8x7b"
TRAIN_STEPS = 8
TINY_TRAIN_STEPS = 5
SERVE_REQUESTS = 12
TINY_SERVE_REQUESTS = 5


def _bit_identical(a, b) -> bool:
    import jax
    import numpy as np

    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _train_scenario(steps: int, chaos: str, truncate_at: int,
                    crash_at: int) -> dict:
    """Fault placement must respect one ordering constraint for the
    bit-parity check to be meaningful: a checksum-valid checkpoint has to
    postdate every schedule-affecting fault (burst/oom), because the
    resumed run replays the tail without the injector.  The truncated save
    then tears a *later* checkpoint, forcing resume back to that one."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.runtime.faults import FaultInjector, SimulatedCrash
    from repro.training.trainer import Trainer

    cfg = get_config(TRAIN_ARCH).reduced()
    kw = dict(seq_len=32, global_batch=2,
              lr=1e-3, adaptive_mact=True, replan_interval=2,
              checkpoint_every=2)
    dirs = [tempfile.mkdtemp(prefix="chaos_") for _ in range(2)]
    try:
        # run A: chaos but no kill — the uninterrupted reference
        tr_a = Trainer(cfg, DistContext(), checkpoint_dir=dirs[0],
                       injector=FaultInjector.from_string(chaos), **kw)
        state_a = tr_a.fit(steps)
        # run B: same chaos + a truncated checkpoint + a crash, then resume
        inj_b = FaultInjector.from_string(
            f"{chaos},ckpt_truncate@{truncate_at},crash@{crash_at}")
        tr_b = Trainer(cfg, DistContext(), checkpoint_dir=dirs[1],
                       injector=inj_b, **kw)
        crashed = False
        try:
            tr_b.fit(steps)
        except SimulatedCrash:
            crashed = True
        tr_b2 = Trainer(cfg, DistContext(), checkpoint_dir=dirs[1],
                        resume=True, **kw)
        state_b = tr_b2.fit(steps)
        retries = [r["oom_retries"] for r in tr_a.log]
        return {
            "steps": steps,
            "escalations": len(tr_a.guard.escalations),
            "max_step_retries": max(retries),
            "retries_bounded": max(retries) <= tr_a.max_oom_retries,
            "headroom_widened": bool(tr_a.headroom_widenings),
            "crashed": crashed,
            "truncated_skipped": tr_b2.resumed_from is not None
            and tr_b2.resumed_from < crash_at,
            "resumed_from": tr_b2.resumed_from,
            "completed": int(np.asarray(state_b.step)) == steps,
            "bit_identical": _bit_identical(state_a, state_b),
        }
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)


def _serve_trace(n: int, vocab: int):
    import numpy as np
    from repro.serving.scheduler import Request

    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    tokens=rng.integers(0, vocab, 16).astype(np.int32),
                    max_new_tokens=6, arrival=0.0)
            for i in range(n)]


def _serve_scenario(n_requests: int) -> dict:
    import jax
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.models import transformer
    from repro.runtime.faults import FaultInjector
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)

    cfg = get_config(SERVE_ARCH).reduced()
    ctx = DistContext()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_slots=2, cache_len=32, prefill_chunk=8)

    base = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
    m_base = base.run(_serve_trace(n_requests, cfg.vocab_size))
    ref = {r.rid: list(r.out) for r in base.finished}

    faulted = ContinuousBatchingScheduler(
        params, cfg, ctx, scfg,
        injector=FaultInjector.from_string("oom@4,oom@9"))
    m_fault = faulted.run(_serve_trace(n_requests, cfg.vocab_size))
    got = {r.rid: list(r.out) for r in faulted.finished}
    accepted = set(faulted.admission_order)
    finished = {r.rid for r in faulted.finished}

    over = ContinuousBatchingScheduler(
        params, cfg, ctx,
        ServeConfig(max_slots=1, cache_len=32, prefill_chunk=8,
                    deadline_s=3.0, max_waiting=6))
    m_over = over.run(_serve_trace(n_requests, cfg.vocab_size))

    return {
        "requests": n_requests,
        "baseline": {"tok_s": round(m_base["tok_per_s"], 1),
                     "p99_s": round(m_base["latency_p99_s"], 3)},
        "faulted": {"tok_s": round(m_fault["tok_per_s"], 1),
                    "p99_s": round(m_fault["latency_p99_s"], 3),
                    "faults": m_fault["faults"],
                    "requeues": m_fault["requeues"],
                    "accepted_lost": len(accepted - finished),
                    "outputs_match_baseline": got == ref},
        "overload": {"finished": m_over["requests"],
                     "shed": m_over["shed"],
                     "retry_after_p50_s": round(m_over["retry_after_p50_s"], 2),
                     "p99_s": round(m_over["latency_p99_s"], 3)},
    }


def run() -> list[str]:
    tiny = bool(os.environ.get("CHAOS_BENCH_TINY"))
    if tiny:
        # faults before the first save so the surviving state-2 checkpoint
        # postdates them; the state-4 save is the one torn
        train = _train_scenario(TINY_TRAIN_STEPS, "burst@0x2.0,oom@1",
                                truncate_at=3, crash_at=4)
    else:
        # faults at steps 2-3, captured by the state-4 save; the state-6
        # save is torn, the crash kills step 6
        train = _train_scenario(TRAIN_STEPS, "burst@2x2.0,oom@3",
                                truncate_at=5, crash_at=6)
    serve = _serve_scenario(TINY_SERVE_REQUESTS if tiny else SERVE_REQUESTS)
    lines = [
        f"chaos,training,escalations={train['escalations']},"
        f"retries_bounded={train['retries_bounded']},"
        f"truncated_skipped={train['truncated_skipped']},"
        f"bit_identical={train['bit_identical']}",
        f"chaos,serving_faulted,faults={serve['faulted']['faults']},"
        f"requeues={serve['faulted']['requeues']},"
        f"accepted_lost={serve['faulted']['accepted_lost']},"
        f"outputs_match={serve['faulted']['outputs_match_baseline']}",
        f"chaos,serving_overload,shed={serve['overload']['shed']},"
        f"finished={serve['overload']['finished']},"
        f"retry_after_p50_s={serve['overload']['retry_after_p50_s']}",
    ]
    if not tiny:
        with open("BENCH_chaos.json", "w") as f:
            json.dump({"train_arch": TRAIN_ARCH, "serve_arch": SERVE_ARCH,
                       "training": train, "serving": serve}, f, indent=2)
        lines.append("chaos,written=BENCH_chaos.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
