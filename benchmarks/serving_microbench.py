"""Continuous batching vs static-batch generation under a mixed-length trace.

Two reduced archs — gemma3-27b (windowed attention, dense FFN) and
mixtral-8x7b (windowed attention, MoE) — serve the same trace of requests
with widely varying generation lengths two ways:

* **static**: requests grouped into arrival-order batches of ``SLOTS``;
  each batch prefills together (prompts padded to a common length) and
  decodes until its LONGEST request finishes — the old
  ``serving.engine.generate`` regime, where short requests ride along as
  dead slots.
* **continuous**: the slot-map scheduler (docs/DESIGN.md §Serving) —
  finished requests leave at step boundaries, queued requests join via
  memory-model admission and chunk-interleaved prefill.

Throughput counts only requested tokens, so the static path's dead-slot
waves and pad-token prefill cost it directly.  Both paths run the same
compiled decode step; compiles are warmed (and the scheduler reset) before
timing.  Prompt lengths are drawn as multiples of the prefill chunk so
every chunk shape compiles exactly once.

Also checks the admission invariant: the scheduler's modeled peak stays
<= the configured budget.

Emits CSV lines per repo convention and writes ``BENCH_serving.json``
(skipped in tiny/CI mode: SERVING_BENCH_TINY=1).
"""

from __future__ import annotations

import json
import os
import time

ARCHS = ("gemma3-27b", "mixtral-8x7b")
SLOTS = 4
PREFILL_CHUNK = 16
PROMPT_LENS = (16, 32, 48)
GEN_SHORT = (4, 12)             # 3/4 of requests
GEN_LONG = (40, 64)             # 1/4 long tail — what static batching waits on
REQUESTS = 16
TINY_REQUESTS = 4


def _gen_len(rng) -> int:
    """Long-tailed generation lengths: mostly short replies, a quarter long —
    the mixed-length regime continuous batching exists for.  A static batch
    decodes max(gen) waves for every member; the scheduler backfills."""
    lo, hi = GEN_LONG if rng.random() < 0.25 else GEN_SHORT
    return int(rng.integers(lo, hi + 1))


def _trace(rng, n, vocab):
    import numpy as np
    from repro.serving.scheduler import Request

    return [Request(rid=i,
                    tokens=rng.integers(0, vocab,
                                        int(rng.choice(PROMPT_LENS))).astype(np.int32),
                    max_new_tokens=_gen_len(rng),
                    arrival=0.0)
            for i in range(n)]


def _static_serve(params, cfg, ctx, requests, cache_len):
    """Arrival-order batches of SLOTS; each batch decodes until its longest
    request is done.  Prompts pad (left, token 0) to the global max prompt
    so the prefill compiles once.  Returns (useful_tokens, elapsed_s)."""
    import jax.numpy as jnp
    import numpy as np
    from repro.serving import engine

    pad_to = max(len(r.tokens) for r in requests)
    useful = 0
    t0 = time.perf_counter()
    for i in range(0, len(requests), SLOTS):
        batch = requests[i:i + SLOTS]
        toks = np.zeros((len(batch), pad_to), np.int32)
        for j, r in enumerate(batch):
            toks[j, pad_to - len(r.tokens):] = r.tokens
        steps = max(r.max_new_tokens for r in batch)
        out = engine.generate(params, cfg, ctx, {"tokens": jnp.asarray(toks)},
                              steps=steps, cache_len=cache_len)
        out.block_until_ready()
        useful += sum(r.max_new_tokens for r in batch)
    return useful, time.perf_counter() - t0


def run() -> list[str]:
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.models import transformer
    from repro.serving.scheduler import ContinuousBatchingScheduler, ServeConfig

    tiny = bool(os.environ.get("SERVING_BENCH_TINY"))
    n_requests = TINY_REQUESTS if tiny else REQUESTS
    ctx = DistContext()
    lines, rows = [], []
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        cache_len = max(PROMPT_LENS) + GEN_LONG[1]
        trace = _trace(rng, n_requests, cfg.vocab_size)

        scfg = ServeConfig(max_slots=SLOTS, cache_len=cache_len,
                           prefill_chunk=PREFILL_CHUNK)
        sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
        # warm every compile (prefill shapes, extend chunk, decode wave,
        # static path) on a throwaway slice of the trace, then reset
        warm = _trace(np.random.default_rng(1), min(4, n_requests),
                      cfg.vocab_size)
        sched.run([r for r in warm])
        sched.reset()
        _static_serve(params, cfg, ctx, warm, cache_len)

        trace_static = _trace(np.random.default_rng(0), n_requests,
                              cfg.vocab_size)
        m = sched.run(trace)
        static_tokens, static_s = _static_serve(params, cfg, ctx,
                                                trace_static, cache_len)
        static_tps = static_tokens / static_s
        speedup = m["tok_per_s"] / static_tps
        row = {
            "arch": arch,
            "requests": n_requests,
            "continuous_tok_s": round(m["tok_per_s"], 2),
            "static_tok_s": round(static_tps, 2),
            "speedup": round(speedup, 3),
            "latency_p50_s": round(m["latency_p50_s"], 3),
            "latency_p99_s": round(m["latency_p99_s"], 3),
            "modeled_peak_gb": round(m["modeled_peak_bytes"] / 1e9, 4),
            "budget_gb": round(m["budget_bytes"] / 1e9, 1),
            "within_budget": m["modeled_peak_bytes"] <= m["budget_bytes"],
            "max_occupancy": m["max_occupancy"],
        }
        rows.append(row)
        lines.append(f"serving,arch={arch},continuous_tok_s="
                     f"{row['continuous_tok_s']},static_tok_s="
                     f"{row['static_tok_s']},speedup={row['speedup']},"
                     f"within_budget={row['within_budget']}")
    if not tiny:
        with open("BENCH_serving.json", "w") as f:
            json.dump({"slots": SLOTS, "prefill_chunk": PREFILL_CHUNK,
                       "prompt_lens": PROMPT_LENS,
                       "gen_short": GEN_SHORT, "gen_long": GEN_LONG,
                       "requests": REQUESTS, "rows": rows}, f, indent=2)
        lines.append("serving,written=BENCH_serving.json")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
