"""Telemetry-driven expert placement vs identity layout under routing skew.

Three variants of the same EP MoE step (docs/DESIGN.md §Placement):

* ``balanced``  — round-robin routing (token t -> experts (t%E, t%E+1)): the
  no-skew reference where every peer does equal work.
* ``identity``  — worst-case skew: EVERY token routes to experts {0, 1},
  which the identity layout co-locates on peer 0, so that peer receives the
  whole step's routed tokens and the step runs at its pace.
* ``placed``    — the same skewed trace under a placement solved from the
  observed load (LPT + one replica slot per peer): experts 0 and 1 are
  re-homed and each replicated across two peers, restoring the balanced
  per-peer load exactly.

Part 1 (correctness, real 4-peer mesh): the skewed trace is run through the
actual ``moe_ffn`` EP path with and without the placement — the placed
output must be BITWISE-identical with zero drops, and the observed load
histogram feeds ``plan_placement`` exactly like the trainer's telemetry
does at a replan boundary.

Part 2 (timing): the dropless EP path computes over static capacity-padded
buffers, so on this CPU backend the full step's wall time cannot express a
load imbalance (every peer's buffer is the same shape regardless of
routing).  What DOES track the imbalance — and what sets the step time on
real hardware — is the hottest peer's expert-FFN leg, so that is what gets
measured: a single-device gated-FFN over each variant's modeled
bottleneck-peer token count (identity: 4x the balanced tokens; placed: 1x).
Variants are timed interleaved in blocks (min over repeats) and ratios are
medians of per-block PAIRED ratios, per the repo's benchmark methodology.

Emits CSV lines per repo convention and writes ``BENCH_placement.json``.
``PLACEMENT_BENCH_TINY=1`` shrinks shapes/repeats for CI smoke.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

TINY = bool(int(os.environ.get("PLACEMENT_BENCH_TINY", "0")))
DEVICES = 4
BLOCKS = 2 if TINY else 6
REPEATS = 2 if TINY else 8
B, S, D = (2, 128, 64) if TINY else (4, 1024, 128)
EXPERTS, TOP_K, D_FF = 8, 2, (128 if TINY else 256)

_INNER = f"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={DEVICES} "
    "--xla_cpu_multi_thread_eigen=false "
    "--xla_cpu_enable_concurrency_optimized_scheduler=true")
import json, math, statistics, time
import numpy as np
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.core import moe as M
from repro.core import placement as plc
from repro.configs.base import MoEConfig

E, K, B, S, D = {EXPERTS}, {TOP_K}, {B}, {S}, {D}
cfg = MoEConfig(num_experts=E, top_k=K, d_ff_expert={D_FF})
mesh = jax.make_mesh((1, {DEVICES}), ("data", "model"))
params = M.init_moe(jax.random.PRNGKey(0), D, cfg)
# router reads the first E features verbatim: a two-hot spike per token
# forces its (top1, top2) pair exactly
params["router"]["w"] = jnp.concatenate(
    [jnp.eye(E, dtype=jnp.float32),
     jnp.zeros((D - E, E), jnp.float32)], axis=0)

def trace(e1, e2):
    rng = np.random.default_rng(0)
    T = B * S
    x = (rng.standard_normal((T, D)) * 0.1).astype(np.float32)
    x[:, :E] = 0.0
    x[np.arange(T), e1] = 5.0
    x[np.arange(T), e2] = 4.0
    return jnp.asarray(x.reshape(B, S, D))

t = np.arange(B * S)
x_bal = trace(t % E, (t + 1) % E)            # round-robin: even per-peer load
x_skew = trace(np.zeros_like(t), np.ones_like(t))   # all tokens -> {{0, 1}}

def ctx_for(placement=None):
    return M.DistContext(mesh=mesh, moe_chunks=2, moe_strategy="ep_shardmap",
                         placement=placement)

# -- part 1: real EP step on the mesh — parity + the observed load ----------
with set_mesh(mesh):
    step = jax.jit(lambda p, x, c=ctx_for(): M.moe_ffn(p, x, cfg, c))
    y_skew, s_skew = step(params, x_skew)
    _, s_bal = step(params, x_bal)
load = np.asarray(s_skew["load"], np.float64)
assert load[0] == B * S and load[1] == B * S, load   # the forcing worked
spec = plc.plan_placement(load, {DEVICES}, replicas=1)
ident = plc.PlacementSpec.identity(E, {DEVICES})
with set_mesh(mesh):
    y_placed, s_placed = jax.jit(
        lambda p, x, c=ctx_for(spec): M.moe_ffn(p, x, cfg, c))(params, x_skew)
np.testing.assert_array_equal(np.asarray(y_skew), np.asarray(y_placed))
assert float(s_placed["drops"]) == 0.0 and float(s_skew["drops"]) == 0.0

# -- part 2: bottleneck-peer expert-FFN leg, sized by the modeled map -------
bottleneck = {{
    "balanced": plc.bottleneck(ident, np.asarray(s_bal["load"], np.float64)),
    "identity": plc.bottleneck(ident, load),
    "placed": plc.bottleneck(spec, load),
}}
w1 = jax.random.normal(jax.random.PRNGKey(2), (D, {D_FF})) * D ** -0.5
w3 = jax.random.normal(jax.random.PRNGKey(3), (D, {D_FF})) * D ** -0.5
w2 = jax.random.normal(jax.random.PRNGKey(4), ({D_FF}, D)) * {D_FF} ** -0.5

def leg(x):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2

fns, xs = {{}}, {{}}
for name, n in bottleneck.items():
    n = int(math.ceil(n))
    xs[name] = jax.random.normal(jax.random.PRNGKey(5), (n, D))
    fns[name] = jax.jit(leg)
    fns[name](xs[name]).block_until_ready()          # compile
blocks = {{k: [] for k in fns}}
for _ in range({BLOCKS}):
    best = {{k: float("inf") for k in fns}}
    for _ in range({REPEATS}):                       # interleaved
        for k, f in fns.items():
            t0 = time.perf_counter()
            f(xs[k]).block_until_ready()
            best[k] = min(best[k], time.perf_counter() - t0)
    for k in fns:
        blocks[k].append(best[k])

out = {{
    "balanced_ms": round(statistics.median(blocks["balanced"]) * 1e3, 3),
    "identity_ms": round(statistics.median(blocks["identity"]) * 1e3, 3),
    "placed_ms": round(statistics.median(blocks["placed"]) * 1e3, 3),
    # paired per-block ratios: machine drift hits both variants alike
    "identity_over_balanced": round(statistics.median(
        i / b for i, b in zip(blocks["identity"], blocks["balanced"])), 3),
    "placed_over_balanced": round(statistics.median(
        p / b for p, b in zip(blocks["placed"], blocks["balanced"])), 3),
    "bottleneck_tokens": {{k: float(v) for k, v in bottleneck.items()}},
    "placement": [spec.num_experts, spec.num_peers, list(spec.slot_to_expert)],
    "parity": "bitwise",
    "drops": 0.0,
}}
print(json.dumps(out))
"""


def run() -> list[str]:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "src")
    if os.environ.get("PYTHONPATH"):
        path = path + os.pathsep + os.environ["PYTHONPATH"]
    out = subprocess.run([sys.executable, "-c", _INNER], capture_output=True,
                         text=True, timeout=1800,
                         env={**os.environ, "PYTHONPATH": path})
    if out.returncode != 0:
        raise RuntimeError(f"placement microbench subprocess failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    row = json.loads(out.stdout.strip().splitlines()[-1])
    with open("BENCH_placement.json", "w") as f:
        json.dump({"devices": DEVICES, "tokens": B * S, "experts": EXPERTS,
                   "top_k": TOP_K, "d": D, "d_ff": D_FF, "tiny": TINY,
                   "blocks": BLOCKS, "repeats": REPEATS, "row": row}, f,
                  indent=2)
    return [
        f"placement,balanced_ms={row['balanced_ms']:.3f},"
        f"identity_ms={row['identity_ms']:.3f},"
        f"placed_ms={row['placed_ms']:.3f},"
        f"identity_over_balanced={row['identity_over_balanced']:.3f},"
        f"placed_over_balanced={row['placed_over_balanced']:.3f},"
        f"parity={row['parity']}",
        "placement,written=BENCH_placement.json",
    ]


if __name__ == "__main__":
    print("\n".join(run()))
