"""Paper Fig. 4: training throughput (TGS) of Methods 1/2/3.

CPU-scale reproduction: the smoke DeepSeek-mini config, real wall-clock over
a few steps per method.  The paper's finding to reproduce: Method 3 (MACT)
beats Method 2 (fixed c=8) because it uses the smallest chunk count that
fits (+18.26 % on Model I), and lands within a few percent of (or above)
Method 1 while Method 1 risks OOM under imbalance.
"""

from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.core.moe import DistContext
from repro.training.trainer import Trainer

STEPS = 14
SEQ = 128
BATCH = 4


def _tgs(use_mact: bool, chunks: int, remat: str = "memfine") -> float:
    import dataclasses
    cfg = dataclasses.replace(get_config("deepseek-mini-8l").reduced(),
                              remat_policy=remat)
    ctx = DistContext(moe_chunks=chunks)
    tr = Trainer(cfg, ctx, seq_len=SEQ, global_batch=BATCH, lr=1e-3,
                 use_mact=use_mact)
    tr.fit(STEPS)
    # drop compile steps; min-of-steps is the standard microbenchmark
    # statistic on a contended core (median still flipped sign run-to-run)
    best = min(r["time_s"] for r in tr.log[2:])
    return BATCH * SEQ / best


def run() -> list[str]:
    m1 = _tgs(False, 1, remat="full")      # Megatron full recompute, no chunks
    m2 = _tgs(False, 8)                    # MemFine fixed c=8
    m3 = _tgs(True, 1)                     # MemFine + MACT
    lines = [
        f"fig4_throughput,method1_full_recompute,tgs={m1:.0f}",
        f"fig4_throughput,method2_fixed_c8,tgs={m2:.0f}",
        f"fig4_throughput,method3_mact,tgs={m3:.0f}",
        f"fig4_throughput,m3_vs_m2,{(m3 / m2 - 1) * 100:+.1f}%"
        f",paper=+18.26%_modelI",
        f"fig4_throughput,m3_vs_m1,{(m3 / m1 - 1) * 100:+.1f}%"
        f",paper=+4.42%_modelII",
    ]
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
