"""Paper Fig. 2: received-token distribution across experts over iterations.

The paper observes that early in training the distribution is extremely
uneven — max approaching the theoretical peak, min near zero.  We train the
smoke DeepSeek-mini (loss-free bias on) and log the per-expert load spread
from the real router each iteration."""

from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.moe import DistContext
from repro.training.trainer import Trainer


def run() -> list[str]:
    cfg = get_config("deepseek-mini-8l").reduced()
    tr = Trainer(cfg, DistContext(), seq_len=128, global_batch=4, lr=1e-3,
                 use_mact=False)
    state = None
    per_step = []
    for _ in range(10):
        state = tr.fit(1, state=state)
        load = np.asarray(tr._last_load)
        per_step.append((float(load.max()), float(load.min()),
                         float(load.mean())))
    lines = []
    for i, (mx, mn, mean) in enumerate(per_step):
        lines.append(f"fig2_distribution,iter={i},max_load={mx:.0f},"
                     f"min_load={mn:.0f},imbalance={mx / max(mean, 1):.2f}x")
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
