"""Roofline aggregation (deliverable g): reads results/dryrun/*.json and
emits, per (arch x shape x mesh):

  compute    = HLO_FLOPs(per device) / peak_FLOP/s        [197 TFLOP/s bf16]
  memory     = HLO_bytes(per device) / HBM_bw             [819 GB/s]
  collective = collective_bytes(per device) / link_bw     [~50 GB/s ICI]

plus the dominant term, MODEL_FLOPS = 6*N*D (train; 2*N*D inference) with
N = active params for MoE, and the useful-compute ratio
MODEL_FLOPS / (chips * HLO_FLOPs_per_device).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.memory_model import active_params, total_params

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_params(cfg) if cfg.moe else total_params(cfg)
    if shape.mode == "train":
        toks = shape.global_batch * shape.seq_len
        return 6 * n * toks
    if shape.mode == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2 * n * toks
    return 2 * n * shape.global_batch          # decode: one token per seq


def load_records(pattern: str = "*.json") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def summarise(rec: dict) -> str | None:
    if rec.get("status") == "skipped":
        return (f"roofline,{rec['arch']},{rec['shape']},{rec['mesh']},"
                f"SKIPPED,{rec.get('reason', '')[:60]}")
    if rec.get("status") != "ok" or "roofline" not in rec:
        return (f"roofline,{rec.get('arch')},{rec.get('shape')},"
                f"{rec.get('mesh')},ERROR,{rec.get('error', '')[:60]}")
    r = rec["roofline"]
    chips = 256 if rec["mesh"] == "16x16" else 512
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["cost"]["flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total else 0.0
    return (f"roofline,{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"tag={rec.get('tag', '')},c={rec.get('chunks', '')},"
            f"compute_s={r['t_compute_s']:.4f},memory_s={r['t_memory_s']:.4f},"
            f"collective_s={r['t_collective_s']:.4f},dominant={r['dominant']},"
            f"useful_flops_ratio={useful:.3f},"
            f"peak_gb={rec['memory']['peak_device_gb']:.1f}")


def run() -> list[str]:
    recs = [x for x in load_records() if not x.get("tag")]
    if not recs:
        return ["roofline,NO_RESULTS (run the dry-run sweep first)"]
    return [s for s in (summarise(r) for r in recs) if s]


if __name__ == "__main__":
    print("\n".join(run()))
