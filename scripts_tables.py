#!/usr/bin/env python3
"""Regenerate the EXPERIMENTS.md dry-run/roofline tables from results/dryrun.

  PYTHONPATH=src python scripts_tables.py > results/tables.md
"""

import glob
import json
import os
import sys

sys.path.insert(0, "src")
from repro.configs import SHAPES, get_config                      # noqa: E402
from repro.core.memory_model import active_params, total_params   # noqa: E402

RESULTS = "results/dryrun"
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def model_flops(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = active_params(cfg) if cfg.moe else total_params(cfg)
    if shape.mode == "train":
        return 6 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2 * n * shape.global_batch * shape.seq_len
    return 2 * n * shape.global_batch


def load():
    recs = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        recs[(r.get("arch"), r.get("shape"), r.get("mesh"),
              r.get("tag", ""))] = r
    return recs


def fmt_row(r):
    arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
    if r["status"] == "skipped":
        return f"| {arch} | {shape} | {mesh} | — | skipped: sub-quadratic rule |||||||"
    if r["status"] != "ok":
        return f"| {arch} | {shape} | {mesh} | — | ERROR {r.get('error','')[:40]} |||||||"
    ro, m, c = r["roofline"], r["memory"], r["cost"]
    chips = 512 if mesh == "2x16x16" else 256
    mf = model_flops(arch, shape)
    useful = mf / max(c["flops_per_device"] * chips, 1)
    return (f"| {arch} | {shape} | {mesh} | c={r.get('chunks','')} "
            f"| {ro['t_compute_s']:.3f} | {ro['t_memory_s']:.3f} "
            f"| {ro['t_collective_s']:.3f} | **{ro['dominant']}** "
            f"| {min(useful, 99):.2f} | {m['peak_device_gb']:.1f} "
            f"| {r['collectives']['total_bytes'] / 1e9:.0f} |")


HEADER = ("| arch | shape | mesh | chunks | compute s | memory s | collective s "
          "| dominant | useful-FLOPs ratio | peak GB/dev | coll GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    recs = load()
    archs = sorted({k[0] for k in recs if k[0]})
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh} ({256 if mesh == '16x16' else 512} chips)\n")
        print(HEADER)
        for arch in archs:
            for shape in SHAPE_ORDER:
                r = recs.get((arch, shape, mesh, ""))
                if r:
                    print(fmt_row(r))
    print("\n### Optimized-variant records (tags)\n")
    print(HEADER.replace("| chunks |", "| tag/chunks |"))
    for key in sorted(recs):
        if key[3]:
            r = recs[key]
            row = fmt_row(r)
            row = row.replace(f"| c={r.get('chunks','')} ",
                              f"| {key[3]} c={r.get('chunks','')} ", 1)
            print(row)


if __name__ == "__main__":
    main()
