"""Deterministic synthetic LM data pipeline (host-side, numpy).

Sequences follow a noisy affine-recurrence over the vocab (token_{t+1} =
(a * token_t + b) mod V with epsilon-noise), so the LM loss has real signal
and the end-to-end examples show it decreasing.  Batches are generated
per-step from a counter-derived seed: fully deterministic, resumable from a
checkpointed step, and shardable (each host could generate only its slice —
here one host generates all and jax.device_put shards).

Modality stubs (docs/DESIGN.md carve-out): VLM patch embeddings and audio frame
embeddings are deterministic pseudo-features of the right shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.configs.base import InputShape, ModelConfig


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        V = self.cfg.vocab_size
        B = self.global_batch
        S = self.seq_len
        n_patch = self.cfg.num_patch_tokens
        s_text = S - n_patch
        a = 31 if V > 31 else 3
        toks = np.empty((B, s_text + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        noise_mask = rng.random((B, s_text)) < self.noise
        noise_tok = rng.integers(0, V, (B, s_text))
        for t in range(s_text):
            nxt = (toks[:, t] * a + 7) % V
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if n_patch:
            batch["patches"] = rng.standard_normal(
                (B, n_patch, self.cfg.d_model)).astype(np.float32)
            # patch positions carry no LM loss
            pad = np.full((B, n_patch), -1, np.int32)
            batch["labels"] = np.concatenate([pad, batch["labels"]], axis=1)
        if self.cfg.encoder_layers:
            batch["frames"] = rng.standard_normal(
                (B, self.cfg.encoder_seq, self.cfg.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg: ModelConfig, shape: InputShape, dtype=np.float32) -> dict:
    """Abstract train/prefill batch structure (shapes only) for the dry-run."""
    import jax
    B, S = shape.global_batch, shape.seq_len
    n_patch = cfg.num_patch_tokens
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S - n_patch), np.int32),
        "labels": jax.ShapeDtypeStruct((B, S), np.int32),
    }
    if n_patch:
        specs["patches"] = jax.ShapeDtypeStruct((B, n_patch, cfg.d_model), dtype)
    if cfg.encoder_layers:
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dtype)
    return specs
