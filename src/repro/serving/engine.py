"""Serving engine: single-pass batched prefill + compiled decode/extend steps.

``serve_step`` is what the decode_32k / long_500k dry-run shapes lower: ONE
new token against a cache of ``seq_len`` entries.  Window/chunked-attention
layers keep ring caches bounded by their window (how long_500k decode stays
affordable for mixtral/gemma3/llama4); SSM layers carry constant-size state.

Prefill is a SINGLE ``transformer.forward`` pass that writes every layer's
decode cache as it goes (``return_cache=True``, docs/DESIGN.md §Serving) —
replacing the old token-by-token replay loop, which dispatched O(S) compiled
decode steps per prompt.  The replay survives as ``prefill_replay``, the
reference oracle the cache-layout parity tests compare against.

Compiled steps are hoisted into a per-(cfg, ctx) cache: the old code wrapped
``jax.jit(functools.partial(...))`` inside every ``prefill``/``generate``
call, so each invocation re-traced the decode step from scratch.  On
non-CPU backends the decode step donates its cache argument, updating K/V
rings in place.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.chunking import chunk_spans
from repro.core.moe import DistContext
from repro.models import transformer


def init_serve_cache(params: dict, cfg: ModelConfig, batch: int, seq_len: int,
                     dtype=jnp.float32, enc_out: Optional[jax.Array] = None):
    return transformer.init_cache(params, cfg, batch, seq_len, dtype,
                                  enc_out=enc_out)


def build_placements(cfg: ModelConfig, ctx: DistContext, num_peers: int, *,
                     loads=None, replicas: int = 0):
    """Static expert placement chosen at engine build (docs/DESIGN.md
    §Placement).

    Serving never replans — weights are loaded once, so the placement is
    resolved here from an offline/warmup load profile (``loads``: a
    ``(L_moe, E)`` matrix, e.g. a training run's telemetry EMA; None means
    identity) and baked into the ctx every compiled step is traced under.
    Returns ``(ctx with per-layer placements, replica_weight_bytes)`` — the
    second element is what ``ServeConfig.replica_weight_bytes`` should carry
    so admission control prices the replica slots
    (core/memory_model.py::serving_peak_bytes).
    """
    import dataclasses

    from repro.core import memory_model as mm
    from repro.core import placement as plc

    n_moe = transformer.num_moe_layers(cfg)
    if cfg.moe is None or n_moe == 0 or num_peers <= 1 \
            or cfg.moe.num_experts % num_peers:
        return ctx, 0.0
    placements = plc.choose_placements(
        loads, n_moe, num_peers, num_experts=cfg.moe.num_experts,
        replicas=replicas, hysteresis=0.0)
    extra_slots = max(p.replica_slots for p in placements)
    replica_bytes = mm.replica_weight_bytes(
        cfg, extra_slots, mm.Parallelism(e=num_peers))
    if all(p.is_identity for p in placements):
        return ctx, 0.0
    return dataclasses.replace(ctx, placements=placements), replica_bytes


def make_serve_step(cfg: ModelConfig, ctx: DistContext):
    """Returns step(params, cache, tokens (B,1)) -> (logits, new_cache)."""

    def serve_step(params, cache, tokens):
        return transformer.decode_step(params, cfg, ctx, cache, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# compiled-step cache: one trace per (cfg, ctx), not one per call
# ---------------------------------------------------------------------------

_STEP_CACHE: dict = {}


def step_cache_info() -> dict:
    """Snapshot of the compiled-step cache keys (tests/observability)."""
    return {"entries": len(_STEP_CACHE)}


def clear_step_cache() -> None:
    _STEP_CACHE.clear()


def _cached(key, build):
    """Memoise ``build()`` under ``key``; unhashable keys (exotic mesh
    objects in a ctx) simply skip the cache rather than fail."""
    try:
        fn = _STEP_CACHE.get(key)
    except TypeError:
        return build()
    if fn is None:
        fn = build()
        _STEP_CACHE[key] = fn
    return fn


def _jit(fn, donate_cache_arg: Optional[int] = None):
    if donate_cache_arg is not None and jax.default_backend() != "cpu":
        # steady-state decode rewrites the whole cache every step: donating
        # it lets XLA update the K/V rings in place instead of allocating a
        # second full-size cache per step
        return jax.jit(fn, donate_argnums=(donate_cache_arg,))
    return jax.jit(fn)


def get_decode_step(cfg: ModelConfig, ctx: DistContext):
    """The compiled single-token step(params, cache, tokens (B,1))."""
    def build():
        def fn(params, cache, tokens):
            return transformer.decode_step(params, cfg, ctx, cache, tokens)
        return _jit(fn, donate_cache_arg=1)
    return _cached(("decode", cfg, ctx), build)


def get_extend_step(cfg: ModelConfig, ctx: DistContext):
    """The compiled chunk step(params, cache, tokens (B,C)) — chunked
    prefill continuation."""
    def build():
        def fn(params, cache, tokens):
            return transformer.extend_step(params, cfg, ctx, cache, tokens)
        return _jit(fn, donate_cache_arg=1)
    return _cached(("extend", cfg, ctx), build)


def get_prefill_fn(cfg: ModelConfig, ctx: DistContext, cache_len: int,
                   dtype=jnp.float32):
    """The compiled single-pass prefill(params, batch) -> (logits, cache)."""
    dtype = jnp.dtype(dtype)

    def build():
        def fn(params, batch):
            logits, _stats, cache = transformer.forward(
                params, cfg, ctx, batch, return_cache=True,
                cache_len=cache_len, cache_dtype=dtype)
            return logits[:, -1:], cache
        return _jit(fn)
    return _cached(("prefill", cfg, ctx, cache_len, dtype.name), build)


# ---------------------------------------------------------------------------
# expert-aware steps (docs/DESIGN.md §Residency)
#
# The loads/masked variants are deliberately NOT cache-donating: the
# residency demand loop may discard a wave that activated an offloaded
# expert and re-run it from the SAME pre-wave cache after restoring the
# missing weights, so the input cache must survive the call.
# ---------------------------------------------------------------------------

def get_decode_step_masked(cfg: ModelConfig, ctx: DistContext):
    """Compiled subset-wave decode over the slot-stacked cache:
    step(params, cache, tokens (S,1), mask (S,) bool)
    -> (logits (S,1,V), cache', load (S, L_moe, E)).

    Every slot runs the vmapped per-slot step (slot math is independent, so
    member outputs are bitwise those of the full-batch step regardless of
    which other slots share the wave); the mask then tree-selects which
    slots' cache entries advance — non-members keep their old cache bits
    exactly, which is what makes grouped waves equivalent to FIFO waves.
    Non-member load rows are zeroed so layer unions only see members."""
    def build():
        def fn(params, cache, tokens, mask):
            logits, new_cache, load = jax.vmap(
                lambda c, t: transformer.decode_step(
                    params, cfg, ctx, c, t, return_load=True),
                in_axes=(0, 0))(cache, tokens)

            def keep(n, o):
                m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
                return jnp.where(m, n, o)
            out_cache = jax.tree_util.tree_map(keep, new_cache, cache)
            load = load * mask.astype(load.dtype)[:, None, None]
            return logits, out_cache, load
        return _jit(fn)
    return _cached(("decode_masked", cfg, ctx), build)


def get_extend_step_loads(cfg: ModelConfig, ctx: DistContext):
    """Compiled chunk step that also reports the (L_moe, E) routed load —
    step(params, cache, tokens (B,C)) -> (logits (B,C,V), cache, load)."""
    def build():
        def fn(params, cache, tokens):
            return transformer.extend_step(params, cfg, ctx, cache, tokens,
                                           return_load=True)
        return _jit(fn)
    return _cached(("extend_loads", cfg, ctx), build)


def get_prefill_fn_loads(cfg: ModelConfig, ctx: DistContext, cache_len: int,
                         dtype=jnp.float32):
    """Single-pass prefill that also reports the (L_moe, E) routed load."""
    dtype = jnp.dtype(dtype)

    def build():
        def fn(params, batch):
            logits, stats, cache = transformer.forward(
                params, cfg, ctx, batch, return_cache=True,
                cache_len=cache_len, cache_dtype=dtype)
            if cfg.moe is not None:
                load = stats["load_per_layer"]
            else:
                load = jnp.zeros((0, 1), jnp.float32)
            return logits[:, -1:], cache, load
        return _jit(fn)
    return _cached(("prefill_loads", cfg, ctx, cache_len, dtype.name), build)


def get_router_probe(cfg: ModelConfig, ctx: DistContext):
    """Compiled router-only probe: probe(params, tokens (N,)) -> (N, L_moe, E)
    activation counts.

    Runs every MoE layer's router directly on the token EMBEDDINGS — no
    attention, no FFN — as a cheap approximation of where those tokens
    would route (the §Residency prefetch hint for requests with no
    telemetry yet).  Approximate by construction: real routing sees the
    residual stream, the probe sees layer-0 input; it is a prediction
    seed, never a correctness input (demand restore covers its misses).
    """
    from repro.core.router import route
    from repro.serving.residency import moe_layer_refs

    refs = moe_layer_refs(cfg)

    def build():
        def fn(params, tokens):
            x = jnp.take(params["embed"], tokens, axis=0)
            x = x.astype(params["embed"].dtype)
            E = cfg.moe.num_experts
            per_layer = []
            for head, i, p in refs:
                router = params[head][i]["ffn"]["router"]
                if p is not None:
                    router = jax.tree_util.tree_map(lambda a: a[p], router)
                r = route(router, x, cfg.moe)
                per_layer.append(
                    jax.nn.one_hot(r.expert_idx, E, dtype=jnp.float32)
                    .sum(axis=1))                              # (N, E)
            if not per_layer:
                return jnp.zeros((tokens.shape[0], 0, 1), jnp.float32)
            return jnp.stack(per_layer, axis=1)                # (N, L, E)
        return _jit(fn)
    return _cached(("router_probe", cfg, ctx), build)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict,
            cache_len: int, dtype=jnp.float32):
    """Single-pass batched prefill: ONE forward pass writes K/V rings, SSM
    state and cross K/V for every layer (docs/DESIGN.md §Serving).

    Returns (next_token_logits (B, 1, V), cache) — the same contract as the
    replay it replaces.  Cache contents are bit-identical to the replay's
    given the same layer inputs (the layout math is identical; deep layers
    agree to float tolerance because replay's decode-attention and
    forward's blocked attention round the residual stream differently —
    tests/test_serving.py pins both properties).
    """
    return get_prefill_fn(cfg, ctx, cache_len, dtype)(params, batch)


def prefill_replay(params: dict, cfg: ModelConfig, ctx: DistContext,
                   batch: dict, cache_len: int, dtype=jnp.float32):
    """Token-by-token replay prefill — O(S) compiled-step dispatches.  Kept
    as the reference oracle for cache-layout parity tests; production
    callers use ``prefill``."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = None
    if cfg.encoder_layers:
        enc_out = transformer.encode(params, cfg, batch["frames"], ctx)
    cache = init_serve_cache(params, cfg, B, cache_len, dtype, enc_out=enc_out)
    step = get_decode_step(cfg, ctx)
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
    return logits, cache


def prefill_chunk(params: dict, cfg: ModelConfig, ctx: DistContext,
                  cache, seg: jax.Array, cache_len: int, dtype=jnp.float32,
                  *, return_load: bool = False):
    """One chunked-prefill span: the first (``cache is None``) runs the
    single-pass prefill, later spans the compiled extend step.  The single
    dispatch point shared by ``prefill_chunked`` and the scheduler's
    interleave.  Returns (next_token_logits (B, 1, V), cache), plus the
    span's (L_moe, E) routed load when ``return_load`` (the expert-aware
    scheduler's telemetry feed; these variants do not donate the cache, so
    the span can re-run after a residency demand restore)."""
    if return_load:
        if cache is None:
            return get_prefill_fn_loads(cfg, ctx, cache_len, dtype)(
                params, {"tokens": seg})
        full, cache, load = get_extend_step_loads(cfg, ctx)(params, cache, seg)
        return full[:, -1:], cache, load
    if cache is None:
        return prefill(params, cfg, ctx, {"tokens": seg}, cache_len, dtype)
    full, cache = get_extend_step(cfg, ctx)(params, cache, seg)
    return full[:, -1:], cache


def prefill_chunked(params: dict, cfg: ModelConfig, ctx: DistContext,
                    tokens: jax.Array, cache_len: int, chunk: int,
                    dtype=jnp.float32):
    """Prefill a (B, S) prompt in <= ``chunk``-token pieces: the first span
    through the single-pass prefill, the rest through compiled extend
    steps.  What the scheduler interleaves between decode waves; also
    usable standalone to bound prefill activation memory for long prompts.
    Returns (next_token_logits (B, 1, V), cache)."""
    S = tokens.shape[1]
    if S > cache_len:
        # the extend path cannot check this itself: chunk write positions
        # are traced, and dynamic_update_slice would silently clamp a
        # linear-cache overflow instead of raising
        raise ValueError(f"prompt length {S} exceeds cache_len {cache_len}")
    logits = cache = None
    for start, stop in chunk_spans(S, chunk):
        logits, cache = prefill_chunk(params, cfg, ctx, cache,
                                      tokens[:, start:stop], cache_len, dtype)
    return logits, cache


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def generate(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict,
             steps: int, cache_len: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None):
    """Greedy/temperature batched generation (example + test driver)."""
    logits, cache = prefill(params, cfg, ctx, batch, cache_len)
    step = get_decode_step(cfg, ctx)
    if temperature > 0 and key is None:
        key = jax.random.PRNGKey(0)   # seeded default; split(None) crashed
    out = []
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        out.append(nxt)
        logits, cache = step(params, cache, nxt[:, None].astype(jnp.int32))
    return jnp.stack(out, axis=1)
