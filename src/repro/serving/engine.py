"""Batched serving: prefill + single-token serve_step over static KV caches.

``serve_step`` is what the decode_32k / long_500k dry-run shapes lower: ONE
new token against a cache of ``seq_len`` entries.  Window/chunked-attention
layers keep ring caches bounded by their window (how long_500k decode stays
affordable for mixtral/gemma3/llama4); SSM layers carry constant-size state.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import DistContext
from repro.models import transformer


def init_serve_cache(params: dict, cfg: ModelConfig, batch: int, seq_len: int,
                     dtype=jnp.float32, enc_out: Optional[jax.Array] = None):
    return transformer.init_cache(params, cfg, batch, seq_len, dtype,
                                  enc_out=enc_out)


def make_serve_step(cfg: ModelConfig, ctx: DistContext):
    """Returns step(params, cache, tokens (B,1)) -> (logits, new_cache)."""

    def serve_step(params, cache, tokens):
        return transformer.decode_step(params, cfg, ctx, cache, tokens)

    return serve_step


def prefill(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict,
            cache_len: int, dtype=jnp.float32):
    """Run the prompt through the forward pass, then replay it into a decode
    cache (token-by-token cache fill is exact for every cache variant).

    Returns (next_token_logits, cache).  For production prefill one would
    write K/V during the forward pass; replay keeps a single code path for
    full/window/chunked/ssm caches and is used by tests and examples.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = None
    if cfg.encoder_layers:
        enc_out = transformer.encode(params, cfg, batch["frames"], ctx)
    cache = init_serve_cache(params, cfg, B, cache_len, dtype, enc_out=enc_out)
    step = jax.jit(functools.partial(transformer.decode_step, params, cfg, ctx))
    logits = None
    for i in range(S):
        logits, cache = step(cache, tokens[:, i:i + 1])
    return logits, cache


def generate(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict,
             steps: int, cache_len: int, temperature: float = 0.0,
             key: Optional[jax.Array] = None):
    """Greedy/temperature batched generation (example + test driver)."""
    logits, cache = prefill(params, cfg, ctx, batch, cache_len)
    step = jax.jit(functools.partial(transformer.decode_step, params, cfg, ctx))
    out = []
    for i in range(steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        out.append(nxt)
        logits, cache = step(cache, nxt[:, None].astype(jnp.int32))
    return jnp.stack(out, axis=1)
