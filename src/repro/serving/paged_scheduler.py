"""Paged continuous batching: block allocation, prefix reuse, preemption.

``PagedScheduler`` keeps the parent's control flow (FIFO admission at step
boundaries, chunked-prefill interleave, requeue-on-fault) and swaps the
memory substrate (docs/DESIGN.md §Paging):

* **Paged residency.**  The per-slot monolithic cache pool becomes page
  pools (serving/paged_cache.py); a request holds pages for the blocks it
  has actually filled, and admission charges the *paged* memory model
  (core/memory_model.py::serving_paged_fits) with allocated bytes plus
  each resident's outstanding worst-case reservation — so a short request
  no longer reserves a full max-length ring, which is where the admitted
  concurrency headroom comes from.
* **Prefix reuse.**  With ``prefix_cache`` on, finished prefills register
  whole aligned blocks of their prompt in a token-id trie; a later request
  sharing the prefix adopts those pages (CoW-shared), resumes its chunked
  prefill from the matched boundary, and pays pages only for the tail.
* **Preemption.**  With ``preemption`` on, a refused head-of-queue request
  walks the ServingGuard escalation ladder: reclaim prefix pages, then
  spill the lowest-priority (strictly below the incoming) active request
  to host; the victim re-enters the queue head, ``accepted`` and
  deadline-exempt, and restores bit-exactly once pages free up.

The decode wave gathers per-slot dense caches from the page tables and
runs the unchanged vmapped ``transformer.decode_step``, so paged decode is
token-identical to the slot-map path — pinned against the monolithic
scheduler and the prefill_replay / greedy-vs-generate oracles in
tests/test_paging.py.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import memory_model as mm
from repro.core.chunking import chunk_spans
from repro.core.moe import DistContext
from repro.models import transformer
from repro.runtime.faults import FaultInjector
from repro.runtime.guard import is_oom_error
from repro.serving import engine
from repro.serving.paged_cache import PagedCachePool
from repro.serving.paging import (PagesExhausted, PrefixTrie, RequestPages,
                                  prefix_align)
from repro.serving.scheduler import (ACTIVE, PREFILL, WAITING,
                                     ContinuousBatchingScheduler, Request,
                                     ServeConfig)


class PagedScheduler(ContinuousBatchingScheduler):
    def __init__(self, params: dict, cfg: ModelConfig, ctx: DistContext,
                 scfg: ServeConfig, key: Optional[jax.Array] = None,
                 injector: Optional[FaultInjector] = None,
                 token_pages: Optional[int] = None,
                 state_blocks: Optional[int] = None):
        if scfg.page_size < 1:
            raise ValueError("PagedScheduler needs ServeConfig.page_size >= 1")
        super().__init__(params, cfg, ctx, scfg, key=key, injector=injector)
        self.cache = None               # the monolithic slot pool is unused
        self.pool = PagedCachePool(
            params, cfg, ctx, scfg.max_slots, scfg.cache_len, scfg.page_size,
            dtype_bytes=scfg.dtype_bytes, token_pages=token_pages,
            state_blocks=state_blocks)
        self.align = prefix_align(scfg.page_size, scfg.prefill_chunk)
        self.trie = (PrefixTrie(self.pool.ops, self.align)
                     if scfg.prefix_cache else None)
        if injector is not None:
            self.pool.ops.fault_hook = (
                lambda where: injector.maybe_fail_step(self.steps, where))
        self.preemptions = 0
        self.prefix_evictions = 0
        self._snapshots: dict[int, dict] = {}   # rid -> {boundary: state}
        self._shared_len: dict[int, int] = {}   # rid -> adopted prefix len

    def reset(self) -> None:
        for req in list(self.active.values()):
            self.pool.release(req.rp)
        if self._prefilling is not None and self._prefilling.rp is not None:
            self.pool.release(self._prefilling.rp)
        if self.trie is not None:
            self.trie.clear()
        super().reset()
        self.preemptions = 0
        self.prefix_evictions = 0
        self._snapshots.clear()
        self._shared_len.clear()

    # -- paged memory model --------------------------------------------------

    def _outstanding_reservations(self) -> float:
        """Bytes residents may still allocate: each request's worst case
        minus what it privately owns already.  Admission charges allocated
        + outstanding so later on-demand allocations can never push the
        modeled peak past the budget."""
        residents = list(self.active.values())
        if self._prefilling is not None:
            residents.append(self._prefilling)
        total = 0.0
        for req in residents:
            if req.rp is None:
                continue
            wc = self._worst_case(req, self._shared_len.get(req.rid, 0))
            total += max(0.0, wc - req.rp.private_bytes)
        return total

    def _worst_case(self, req: Request, shared_len: int) -> float:
        return self.pool.ops.worst_case_bytes(
            len(req.prompt) + req.max_new_tokens, shared_len)

    def _page_bytes_now(self, extra: float = 0.0) -> float:
        return (self.pool.alloc.allocated_bytes()
                + self._outstanding_reservations() + extra)

    def modeled_bytes(self, requests: Optional[int] = None) -> float:
        s = self.scfg
        occ = self.occupancy() if requests is None else requests
        return mm.serving_paged_peak_bytes(
            self.cfg, page_bytes=self._page_bytes_now(),
            decode_tokens=min(s.max_slots, occ),
            prefill_tokens=s.prefill_chunk, dtype_bytes=s.dtype_bytes,
            weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes,
            **self._resident_kw())

    def _fits_extra(self, extra_bytes: float, occ_after: int) -> bool:
        s = self.scfg
        return mm.serving_paged_fits(
            self.cfg, s.hw, page_bytes=self._page_bytes_now(extra_bytes),
            decode_tokens=min(s.max_slots, occ_after),
            prefill_tokens=s.prefill_chunk, dtype_bytes=s.dtype_bytes,
            weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes,
            **self._resident_kw())

    # -- intake --------------------------------------------------------------

    def submit(self, req: Request, now: float = 0.0) -> None:
        s = self.scfg
        if len(req.tokens) + req.max_new_tokens > s.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.tokens)} + gen "
                f"{req.max_new_tokens} exceeds cache_len {s.cache_len}")
        req.prompt = np.asarray(req.tokens)
        wc = self._worst_case(req, 0)
        if not mm.serving_paged_fits(
                self.cfg, s.hw, page_bytes=wc, decode_tokens=1,
                prefill_tokens=s.prefill_chunk, dtype_bytes=s.dtype_bytes,
                weight_bytes=s.weight_bytes,
                replica_weight_bytes=s.replica_weight_bytes,
                **self._resident_kw()):
            raise ValueError(
                f"request {req.rid} can never be admitted: its worst-case "
                f"pages ({wc / 1e9:.2f} GB) plus weights exceed "
                f"{s.hw.alpha:.2f} * {s.hw.hbm_bytes / 1e9:.0f} GB")
        if self.guard.overloaded(len(self.queue)):
            self._shed(req, now)
            return
        req.state = WAITING
        self.queue.append(req)

    # -- admission: prefix reuse + escalation ladder -------------------------

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            req = self.queue[0]
            if req.spill is not None:
                if not self._readmit_preempted(req):
                    break
                continue
            if self._prefilling is not None:
                break
            matched, nodes = (self.trie.lookup(req.tokens)
                              if self.trie is not None else (0, []))
            while matched >= len(req.tokens):   # keep >=1 token to prefill
                nodes.pop()
                matched -= self.align
            wc = self._worst_case(req, matched)
            if not self._fits_extra(wc, self.occupancy() + 1):
                if not self._relieve_pressure(req):
                    break
                continue
            self.queue.popleft()
            req.state = PREFILL
            req.accepted = True
            req.slot = self.free_slots.pop(0)
            req.rp = self.pool.ops.new_request()
            self._shared_len[req.rid] = matched
            self._snapshots[req.rid] = {}
            if matched:
                self.trie.adopt(req.rp, nodes)
                req.cache = self.pool.gather_dense(
                    req.rp.tables, nodes[-1].snapshot, matched)
                req.chunks_done = matched // self.scfg.prefill_chunk
            self._prefilling = req
            self.admission_order.append(req.rid)
        self.max_occupancy = max(self.max_occupancy, self.occupancy())
        self.modeled_peak = max(self.modeled_peak, self.modeled_bytes())

    def _readmit_preempted(self, req: Request) -> bool:
        """A spilled request at the queue head: restore its pages (fully
        private — sharing does not survive a spill) straight into ACTIVE;
        its position, sampled tokens and decode feed are exactly where the
        preemption left them."""
        wc = self._worst_case(req, 0)
        if not self._fits_extra(wc, self.occupancy() + 1):
            if self.trie is not None and self.trie.evict_lru_leaf():
                self.prefix_evictions += 1
                return True
            return False
        try:
            rp = self.pool.restore(req.spill)
        except PagesExhausted:
            return False
        self.queue.popleft()
        req.spill = None
        req.rp = rp
        self._shared_len[req.rid] = 0
        req.slot = self.free_slots.pop(0)
        req.state = ACTIVE
        self.active[req.slot] = req
        return True

    def _relieve_pressure(self, incoming: Request) -> bool:
        """Walk the guard's escalation ladder for a refused admission:
        evict a prefix-cache leaf, then preempt the lowest-priority active
        request strictly below the incoming one.  Returns True if any rung
        freed memory (the caller re-checks admission)."""
        for rung in self.guard.admission_escalation(
                self.trie is not None, self.scfg.preemption):
            if rung == "evict_prefix":
                if self.trie.evict_lru_leaf():
                    self.prefix_evictions += 1
                    return True
            elif rung == "preempt":
                victim = self._pick_victim(incoming.priority)
                if victim is not None and self._preempt(victim):
                    return True
        return False

    def _pick_victim(self, above: int) -> Optional[Request]:
        cands = [r for r in self.active.values() if r.priority < above]
        if not cands:
            return None
        # lowest priority first; among ties, the most recently admitted
        # (its lost batching time is smallest)
        return max(cands, key=lambda r: (-r.priority, r.t_first or 0.0))

    def _preempt(self, victim: Request) -> bool:
        hook = None
        if self.injector is not None:
            hook = lambda where: self.injector.maybe_fail_step(  # noqa: E731
                self.steps, where)
        try:
            saved = self.pool.spill(victim.rp, fault_hook=hook)
        except Exception as exc:
            if not is_oom_error(exc):
                raise
            # fault mid-preemption: the spill aborted before any reference
            # dropped — the victim stays resident, nothing is lost
            self.faults += 1
            return False
        victim.rp = None
        victim.spill = saved
        victim.preemptions += 1
        self.preemptions += 1
        self._shared_len.pop(victim.rid, None)
        self.active.pop(victim.slot)
        self.free_slots.append(victim.slot)
        victim.state = WAITING               # accepted: deadline-exempt
        # behind the incoming head it was evicted for — putting it in front
        # would readmit it into the pages just freed and preempt it again,
        # forever; behind everything would starve an accepted request
        self.queue.insert(min(1, len(self.queue)), victim)
        return True

    # -- prefill: snapshot capture + paged install ---------------------------

    def _prefill_step(self, now: float) -> None:
        req = self._prefilling
        spans = chunk_spans(len(req.tokens), self.scfg.prefill_chunk)
        start, stop = spans[req.chunks_done]
        seg = jnp.asarray(req.tokens[None, start:stop], jnp.int32)
        logits, req.cache = self._prefill_compute(req, seg)
        req.chunks_done += 1
        self.prefill_chunks += 1
        if (self.trie is not None and stop % self.align == 0
                and stop <= self._registrable_len(len(req.tokens))):
            # state at an aligned boundary: what a prefix-hit resume needs
            self._snapshots[req.rid][stop] = self.pool.state_snapshot(
                req.cache)
        if req.chunks_done == len(spans):
            self._install(req, logits, now)

    def _registrable_len(self, prompt_len: int) -> int:
        """Prefix blocks are only stable while no ring has wrapped: a
        prompt longer than a ring overwrote its earliest blocks during
        prefill, so nothing registers for it."""
        for g in self.pool.groups:
            if g.ring and prompt_len > g.length:
                return 0
        return prompt_len

    def _install(self, req: Request, logits, now: float) -> None:
        S = len(req.tokens)
        try:
            self.pool.install(req.rp, req.cache, S,
                              shared_len=self._shared_len.get(req.rid, 0))
        except Exception as exc:
            if not (is_oom_error(exc) or isinstance(exc, PagesExhausted)):
                raise
            # physical pages ran out mid-install (or an injected CoW fault):
            # requeue this request; nothing accepted is lost
            self.faults += 1
            self._requeue_prefilling(req)
            return
        if self.trie is not None:
            upto = self._registrable_len(S) // self.align * self.align
            if upto:
                self.trie.register(req.tokens, upto, req.rp,
                                   self._snapshots.get(req.rid, {}))
        req.cache = None
        req.pos = S
        req.state = ACTIVE
        if req.t_first is None:
            req.t_first = now
        self.active[req.slot] = req
        self._prefilling = None
        if req.pending_token >= 0:
            req.next_token = req.pending_token
            req.pending_token = -1
        else:
            self._append_token(req, np.asarray(logits[0, -1]), now)

    def _requeue_prefilling(self, req: Request) -> None:
        self.pool.release(req.rp)
        req.rp = None
        self._shared_len.pop(req.rid, None)
        req.cache = None
        req.chunks_done = 0
        req.state = WAITING
        req.requeues += 1
        self.requeued += 1
        self.free_slots.append(req.slot)
        self._prefilling = None
        self.queue.appendleft(req)

    # -- decode: paged wave --------------------------------------------------

    def _requeue_active(self, now: float) -> None:
        for req in self.active.values():
            self.pool.release(req.rp)
            req.rp = None
            self._shared_len.pop(req.rid, None)
        super()._requeue_active(now)

    def _decode_wave(self, now: float) -> None:
        if self._expert_aware:
            self._decode_wave_expert(now)
            return
        s = self.scfg
        toks = np.zeros((s.max_slots, 1, 1), np.int32)
        pos = np.zeros((s.max_slots,), np.int32)
        try:
            for slot, req in self.active.items():
                toks[slot, 0, 0] = req.next_token
                pos[slot] = req.pos
                # the write block must be exclusively owned before the wave
                # (CoW fires here on ring wrap into a shared prefix page);
                # runs before the generic wave fault point so an armed
                # kind@step spec lands mid-CoW-fork when one is pending
                self.pool.prepare_decode_write(req.rp, req.pos)
            if self.injector is not None:
                self.injector.maybe_fail_step(self.steps, "decode_wave")
            slot_rps = [self.active[i].rp if i in self.active else None
                        for i in range(s.max_slots)]
            logits = np.asarray(
                self.pool.decode_wave(self.params, slot_rps, pos, toks))
        except Exception as exc:
            if not (is_oom_error(exc) or isinstance(exc, PagesExhausted)):
                raise
            self.faults += 1
            self._requeue_active(now)
            if jax.default_backend() != "cpu":
                # the donated pools may be torn mid-wave: rebuild them and
                # drop the trie's now-dangling pins (prefixes recompute)
                self._rebuild_pools()
            return
        self.decode_waves += 1
        for slot, req in list(self.active.items()):
            req.pos += 1
            self._append_token(req, logits[slot, 0, -1], now)

    # -- expert-aware wave hooks (docs/DESIGN.md §Residency) -----------------

    def _wave_fault_ok(self, exc: Exception) -> bool:
        return is_oom_error(exc) or isinstance(exc, PagesExhausted)

    def _wave_recover(self, now: float) -> None:
        self.faults += 1
        self._requeue_active(now)
        if jax.default_backend() != "cpu":
            self._rebuild_pools()

    def _advance_member(self, req: Request) -> None:
        req.pos += 1

    def _run_wave(self, members: list, mask: np.ndarray):
        """Paged member wave to the residency fixpoint.  Membership rides
        the page tables — non-member slots get ``rp=None`` (zero-page
        reads, scratch-page writes), so even the committed clean run never
        touches a non-member's pages; discarded demand re-runs reuse the
        unchanged input pools."""
        s = self.scfg
        toks = np.zeros((s.max_slots, 1, 1), np.int32)
        pos = np.zeros((s.max_slots,), np.int32)
        for slot in members:
            req = self.active[slot]
            toks[slot, 0, 0] = req.next_token
            pos[slot] = req.pos
            # CoW before the wave, as in the FIFO path; ensure_writable is
            # idempotent, so demand re-runs see the same owned block
            self.pool.prepare_decode_write(req.rp, req.pos)
        if self.injector is not None:
            self.injector.maybe_fail_step(self.steps, "decode_wave")
        slot_rps = [self.active[i].rp if mask[i] and i in self.active else None
                    for i in range(s.max_slots)]
        out = {}

        def once():
            logits, load, new_pools = self.pool.decode_wave_loads(
                self.params, slot_rps, pos, toks)
            out["logits"], out["pools"] = logits, new_pools
            # non-member slots decoded garbage from the zero page: zero
            # their load rows so unions/telemetry only see members
            out["load"] = np.asarray(load) * mask[:, None, None]
            return out["load"].sum(0) > 0, \
                lambda: setattr(self.pool, "pools", out["pools"])

        self._demand_fixpoint(once)
        return np.asarray(out["logits"]), out["load"]

    def _rebuild_pools(self) -> None:
        if self.trie is not None:
            self.trie.clear()
        self.pool.pools = tuple(
            None if p is None else jnp.zeros_like(p)
            for p in self.pool.pools)

    def _evict(self, req: Request, now: float) -> None:
        if req.rp is not None:
            self.pool.release(req.rp)
            req.rp = None
        self._shared_len.pop(req.rid, None)
        self._snapshots.pop(req.rid, None)
        super()._evict(req, now)

    # -- telemetry -----------------------------------------------------------

    def metrics(self, elapsed: float) -> dict:
        m = super().metrics(elapsed)
        m["preemptions"] = self.preemptions
        m["prefix_evictions"] = self.prefix_evictions
        m["page_hwm_bytes"] = self.pool.alloc.hwm_bytes()
        m["page_allocated_bytes"] = self.pool.alloc.allocated_bytes()
        if self.trie is not None:
            m.update({f"prefix_{k}": v for k, v in self.trie.stats().items()})
        return m
