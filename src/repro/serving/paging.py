"""Paged serving-cache bookkeeping: allocator, page tables, prefix trie.

MemFine's discipline — plan memory through an explicit model instead of
over-allocating for the worst case — applied to serving *state*
(docs/DESIGN.md §Paging).  The slot-map scheduler reserved each request's
full max-length K/V ring up front; here every cache layout (K/V ring,
linear K/V, SSM-state/conv-tail, cross K/V) is carved into fixed-size
pages handed out on demand:

* **PageAllocator** — free-list allocation with per-page refcounts over
  named *spaces* (one per distinct token-cache length, plus one for the
  constant-size per-request state bundle).  Refcounts > 1 express
  copy-on-write sharing; byte accounting (allocated + high-watermark)
  feeds the paged serving memory model
  (core/memory_model.py::serving_paged_peak_bytes).
* **RequestPages** — one request's page tables: per-group block -> page id
  (None = not yet allocated), a shared-block set (pages the request may
  read but must CoW before writing), and its state block.
* **PrefixTrie** — token-id-keyed trie at ``align``-token granularity.
  A node pins the pages holding its block's K/V rows plus a host snapshot
  of the non-token state (SSM state / conv tail / pos) at the block's end
  boundary, so a later request with the same prompt prefix skips that
  prefill entirely and copy-on-writes at the first divergent append.

Everything in this module is pure host-side Python over integer page ids —
no arrays — which is what makes it tractable to property-test exhaustively
(tests/test_paging_properties.py: random alloc/free/fork/preempt/CoW
sequences against an independent reference model).  The array side (page
pools, gather/scatter decode, install/spill) lives in
serving/paged_cache.py.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

#: every token space reserves two page ids: ``ZERO_PAGE`` is never written
#: and backs never-filled blocks in gathers (so a paged dense view is
#: bit-identical to the zero-initialised monolithic cache), ``SCRATCH_PAGE``
#: absorbs writes from inactive decode slots and never-read scatter targets.
ZERO_PAGE = 0
SCRATCH_PAGE = 1
RESERVED_PAGES = 2


class PagesExhausted(RuntimeError):
    """Allocation failed: the space has no free pages.  The scheduler treats
    this like an OOM (requeue / preempt), never as a crash."""


class AllocatorCorruption(AssertionError):
    """An allocator invariant failed (double free, leak, negative ref)."""


@dataclass
class _Space:
    total: int                      # usable pages (reserved ids excluded)
    page_bytes: float               # modeled bytes per page (production dtype)
    free: list = field(default_factory=list)
    ref: dict = field(default_factory=dict)   # page id -> refcount (>0)
    hwm: int = 0                    # high watermark of allocated pages


class PageAllocator:
    """Free-list page allocator with refcounts over named spaces.

    Invariants (checked by ``audit()``; the property harness calls it after
    every operation):

    * ``allocated + len(free) == total`` per space — no leak, no double free;
    * every refcount is >= 1 — a page frees exactly when its count hits 0;
    * free pages carry no refcount entry.
    """

    def __init__(self) -> None:
        self.spaces: dict = {}

    def add_space(self, key, pages: int, page_bytes: float = 0.0) -> None:
        if key in self.spaces:
            raise ValueError(f"space {key!r} already exists")
        if pages < 1:
            raise ValueError(f"space {key!r} needs >= 1 usable page")
        self.spaces[key] = _Space(
            total=pages, page_bytes=page_bytes,
            free=list(range(RESERVED_PAGES, RESERVED_PAGES + pages)))

    # -- core ops ------------------------------------------------------------

    def alloc(self, key) -> int:
        sp = self.spaces[key]
        if not sp.free:
            raise PagesExhausted(
                f"space {key!r}: all {sp.total} pages allocated")
        page = sp.free.pop()
        sp.ref[page] = 1
        sp.hwm = max(sp.hwm, len(sp.ref))
        return page

    def incref(self, key, page: int) -> None:
        """Share ``page`` (CoW fork / trie pin): one more owner."""
        sp = self.spaces[key]
        if page not in sp.ref:
            raise AllocatorCorruption(
                f"space {key!r}: incref of unallocated page {page}")
        sp.ref[page] += 1

    def decref(self, key, page: int) -> bool:
        """Drop one owner; frees the page (returns True) at refcount zero."""
        sp = self.spaces[key]
        if page not in sp.ref:
            raise AllocatorCorruption(
                f"space {key!r}: decref of unallocated page {page} "
                f"(double free?)")
        sp.ref[page] -= 1
        if sp.ref[page] == 0:
            del sp.ref[page]
            sp.free.append(page)
            return True
        return False

    def refcount(self, key, page: int) -> int:
        return self.spaces[key].ref.get(page, 0)

    def is_shared(self, key, page: int) -> bool:
        return self.refcount(key, page) > 1

    # -- accounting ----------------------------------------------------------

    def allocated(self, key) -> int:
        return len(self.spaces[key].ref)

    def free_pages(self, key) -> int:
        return len(self.spaces[key].free)

    def hwm(self, key) -> int:
        return self.spaces[key].hwm

    def allocated_bytes(self) -> float:
        return sum(len(sp.ref) * sp.page_bytes for sp in self.spaces.values())

    def hwm_bytes(self) -> float:
        """High-watermark bytes — conservative: per-space watermarks may
        have peaked at different times, so this bounds the true peak."""
        return sum(sp.hwm * sp.page_bytes for sp in self.spaces.values())

    def audit(self) -> None:
        for key, sp in self.spaces.items():
            if len(sp.ref) + len(sp.free) != sp.total:
                raise AllocatorCorruption(
                    f"space {key!r}: {len(sp.ref)} allocated + "
                    f"{len(sp.free)} free != total {sp.total}")
            if len(set(sp.free)) != len(sp.free):
                raise AllocatorCorruption(f"space {key!r}: duplicate free page")
            for page, ref in sp.ref.items():
                if ref < 1:
                    raise AllocatorCorruption(
                        f"space {key!r}: page {page} refcount {ref} < 1")
                if page in sp.free:
                    raise AllocatorCorruption(
                        f"space {key!r}: page {page} both allocated and free")
                if page < RESERVED_PAGES:
                    raise AllocatorCorruption(
                        f"space {key!r}: reserved page {page} was allocated")


# ---------------------------------------------------------------------------
# per-group block math (ring vs linear layouts)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Group:
    """One token-cache layout class: every attention leaf whose cache holds
    ``length`` token slots with the same ring-ness shares this group's page
    tables (the physical pools stay per-leaf — see paged_cache.py)."""
    length: int                     # Sc: token slots in this cache layout
    ring: bool                      # window-sized ring vs linear

    def blocks(self, page: int) -> int:
        return math.ceil(self.length / page)

    def slot(self, pos: int) -> int:
        return pos % self.length if self.ring else pos

    def block_of(self, pos: int, page: int) -> int:
        return self.slot(pos) // page

    def touched_blocks(self, start: int, stop: int, page: int) -> set:
        """Blocks written when positions [start, stop) are appended."""
        if stop <= start:
            return set()
        if self.ring and stop - start >= self.length:
            return set(range(self.blocks(page)))
        return {self.block_of(p, page) for p in range(start, stop)}


def space_key(group: Group) -> tuple:
    return ("kv", group.length, "ring" if group.ring else "linear")


STATE_SPACE = ("state",)


# ---------------------------------------------------------------------------
# per-request page tables
# ---------------------------------------------------------------------------

@dataclass
class RequestPages:
    """One request's view of the paged cache: per-group page tables plus its
    state block.  ``shared`` marks blocks whose page the request does not
    own exclusively — reads are fine, writes must CoW first."""
    tables: dict                    # Group -> list[Optional[int]] page ids
    shared: dict                    # Group -> set of shared block indices
    state_block: Optional[int] = None
    private_bytes: float = 0.0      # modeled bytes of exclusively-owned pages

    @classmethod
    def empty(cls, groups, page: int) -> "RequestPages":
        return cls(tables={g: [None] * g.blocks(page) for g in groups},
                   shared={g: set() for g in groups})

    def pages_of(self, group: Group) -> list:
        return [p for p in self.tables[group] if p is not None]


class PageTableOps:
    """Host-side table operations shared by the scheduler and the property
    harness: allocate-on-demand, CoW resolution, fork-from-prefix, release.

    Array copies are delegated to ``copy_page_fn(group, src, dst)`` /
    ``zero_state_fn(block)`` callbacks so the pure bookkeeping stays
    testable without materialising pools.
    """

    def __init__(self, alloc: PageAllocator, groups, page: int,
                 state_bytes: float = 0.0, copy_page_fn=None):
        self.alloc = alloc
        self.groups = list(groups)
        self.page = page
        self.state_bytes = state_bytes
        self.copy_page_fn = copy_page_fn or (lambda group, src, dst: None)
        # chaos hook (runtime/faults.py): called at the designated fault
        # points BEFORE any bookkeeping mutates, so an injected fault always
        # observes (and leaves behind) a consistent allocator
        self.fault_hook = None

    def _page_bytes(self, group: Group) -> float:
        return self.alloc.spaces[space_key(group)].page_bytes

    # -- request lifecycle ---------------------------------------------------

    def new_request(self) -> RequestPages:
        return RequestPages.empty(self.groups, self.page)

    def alloc_state(self, rp: RequestPages) -> int:
        if rp.state_block is None:
            rp.state_block = self.alloc.alloc(STATE_SPACE)
            rp.private_bytes += self.state_bytes
        return rp.state_block

    def ensure_block(self, rp: RequestPages, group: Group, block: int) -> int:
        """Allocate ``block``'s page if the table has none yet."""
        page = rp.tables[group][block]
        if page is None:
            page = self.alloc.alloc(space_key(group))
            rp.tables[group][block] = page
            rp.private_bytes += self._page_bytes(group)
        return page

    def ensure_writable(self, rp: RequestPages, group: Group,
                        block: int) -> int:
        """CoW: after this, ``block``'s page is exclusively owned.  Copies
        the shared page's contents into a fresh page via ``copy_page_fn``."""
        page = self.ensure_block(rp, group, block)
        if block not in rp.shared[group]:
            return page
        if self.fault_hook is not None:
            self.fault_hook("cow_fork")
        fresh = self.alloc.alloc(space_key(group))
        self.copy_page_fn(group, page, fresh)
        self.alloc.decref(space_key(group), page)
        rp.tables[group][block] = fresh
        rp.shared[group].discard(block)
        rp.private_bytes += self._page_bytes(group)
        return fresh

    def adopt_shared(self, rp: RequestPages, group: Group, block: int,
                     page: int) -> None:
        """Point ``block`` at an existing page owned elsewhere (prefix hit /
        fork).  Increfs; the block is marked shared so writes CoW."""
        assert rp.tables[group][block] is None, "block already mapped"
        self.alloc.incref(space_key(group), page)
        rp.tables[group][block] = page
        rp.shared[group].add(block)

    def release(self, rp: RequestPages) -> None:
        """Drop every reference this request holds (eviction/preemption)."""
        for group in self.groups:
            key = space_key(group)
            for block, page in enumerate(rp.tables[group]):
                if page is not None:
                    self.alloc.decref(key, page)
                rp.tables[group][block] = None
            rp.shared[group].clear()
        if rp.state_block is not None:
            self.alloc.decref(STATE_SPACE, rp.state_block)
            rp.state_block = None
        rp.private_bytes = 0.0

    # -- admission-side worst-case reservation -------------------------------

    def worst_case_bytes(self, total_len: int, shared_len: int = 0) -> float:
        """Modeled bytes this request may come to own exclusively: the
        admission reservation (docs/DESIGN.md §Paging).

        Per linear group the shared prefix is never rewritten, so only the
        tail's blocks count; per ring group a request whose total length
        wraps the ring worst-cases to every block private (each shared page
        CoWs as the ring write cursor re-enters it)."""
        total = self.state_bytes
        for group in self.groups:
            pb = self._page_bytes(group)
            occupied = min(total_len, group.length)
            if group.ring and total_len > group.length:
                blocks = group.blocks(self.page)            # full CoW
            else:
                blocks = (math.ceil(occupied / self.page)
                          - min(shared_len, occupied) // self.page)
            total += blocks * pb
        return total


# ---------------------------------------------------------------------------
# prefix cache trie
# ---------------------------------------------------------------------------

@dataclass
class PrefixNode:
    key: tuple                      # this block's ``align`` token ids
    pages: dict                     # Group -> list[int], align//page pages
    snapshot: object                # host state snapshot at the end boundary
    children: dict = field(default_factory=dict)
    last_used: int = 0
    parent: Optional["PrefixNode"] = None


class PrefixTrie:
    """Token-id-keyed prefix cache at ``align``-token node granularity.

    ``align`` is lcm(page_size, prefill_chunk): node boundaries land on both
    page and prefill-chunk boundaries, which is what makes a prefix-hit
    prefill bit-identical to the cold chunked prefill (the resumed extend
    steps see bitwise-equal cache inputs — tests/test_paging.py).

    The trie owns one reference per pinned page; borrowers take their own
    on lookup.  ``max_nodes`` bounds residency with LRU leaf eviction.
    """

    def __init__(self, ops: PageTableOps, align: int, max_nodes: int = 256):
        self.ops = ops
        self.align = align
        self.max_nodes = max_nodes
        self.root: dict = {}            # key -> PrefixNode
        self.n_nodes = 0
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.tokens_reused = 0

    def _blocks_per_node(self) -> int:
        return self.align // self.ops.page

    # -- lookup --------------------------------------------------------------

    def lookup(self, tokens) -> tuple:
        """Longest registered prefix of ``tokens`` in whole ``align`` blocks.

        Returns ``(matched_len, nodes)`` — the caller adopts the nodes'
        pages (shared) and resumes from the deepest node's state snapshot.
        Does NOT touch refcounts; ``adopt`` does, per matched node."""
        self.clock += 1
        nodes: list[PrefixNode] = []
        level = self.root
        n_full = len(tokens) // self.align
        for i in range(n_full):
            key = tuple(int(t) for t in tokens[i * self.align:
                                               (i + 1) * self.align])
            node = level.get(key)
            if node is None:
                break
            node.last_used = self.clock
            nodes.append(node)
            level = node.children
        if nodes:
            self.hits += 1
            self.tokens_reused += len(nodes) * self.align
        else:
            self.misses += 1
        return len(nodes) * self.align, nodes

    def adopt(self, rp: RequestPages, nodes) -> None:
        """Map the matched nodes' pages into ``rp`` as shared blocks."""
        bpn = self._blocks_per_node()
        for depth, node in enumerate(nodes):
            for group, pages in node.pages.items():
                base = depth * bpn
                for j, page in enumerate(pages):
                    self.ops.adopt_shared(rp, group, base + j, page)

    # -- registration --------------------------------------------------------

    def register(self, tokens, upto: int, rp: RequestPages,
                 snapshots: dict) -> int:
        """Pin ``rp``'s pages for every whole aligned block of ``tokens[:upto]``
        that has a state snapshot, creating missing trie nodes.  The donor's
        registered blocks become shared (its later ring wraps CoW away from
        the trie's copy instead of corrupting it).  Returns nodes created."""
        bpn = self._blocks_per_node()
        created = 0
        level = self.root
        parent = None
        for i in range(upto // self.align):
            end = (i + 1) * self.align
            key = tuple(int(t) for t in tokens[i * self.align:end])
            node = level.get(key)
            if node is None:
                if end not in snapshots:
                    break                      # no resume state: stop here
                pages: dict = {}
                ok = True
                for group in self.ops.groups:
                    blk = [rp.tables[group][i * bpn + j] for j in range(bpn)]
                    if any(p is None for p in blk):
                        ok = False
                        break
                    pages[group] = blk
                if not ok:
                    break
                for group, blk in pages.items():
                    pb = self.ops._page_bytes(group)
                    for j, page in enumerate(blk):
                        self.ops.alloc.incref(space_key(group), page)
                        if i * bpn + j not in rp.shared[group]:
                            # the donor no longer owns this page outright:
                            # a later ring wrap must CoW away from the trie
                            # copy, so its outstanding reservation grows back
                            rp.private_bytes -= pb
                        rp.shared[group].add(i * bpn + j)
                node = PrefixNode(key=key, pages=pages,
                                  snapshot=snapshots[end], parent=parent,
                                  last_used=self.clock)
                level[key] = node
                self.n_nodes += 1
                created += 1
            else:
                # already registered by an earlier request (possibly with
                # different physical pages); keep the existing node
                node.last_used = self.clock
            parent = node
            level = node.children
        if created:
            self._evict_to_cap()
        return created

    # -- eviction ------------------------------------------------------------

    def _leaves(self):
        out = []

        def walk(level):
            for node in level.values():
                if node.children:
                    walk(node.children)
                else:
                    out.append(node)
        walk(self.root)
        return out

    def _drop(self, node: PrefixNode) -> None:
        for group, pages in node.pages.items():
            for page in pages:
                self.ops.alloc.decref(space_key(group), page)
        level = node.parent.children if node.parent is not None else self.root
        del level[node.key]
        self.n_nodes -= 1

    def _evict_to_cap(self) -> None:
        while self.n_nodes > self.max_nodes:
            victim = min(self._leaves(), key=lambda n: n.last_used)
            self._drop(victim)

    def evict_lru_leaf(self) -> bool:
        """Free the least-recently-used leaf node's pages (memory-pressure
        escalation rung before preemption).  Returns True if one was freed."""
        leaves = self._leaves()
        if not leaves:
            return False
        self._drop(min(leaves, key=lambda n: n.last_used))
        return True

    def clear(self) -> None:
        while self.evict_lru_leaf():
            pass

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"nodes": self.n_nodes, "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "tokens_reused": self.tokens_reused}


def prefix_align(page_size: int, prefill_chunk: int) -> int:
    """Prefix-sharing granularity: lcm of the page and the prefill chunk, so
    shared boundaries land on both page edges (whole pages are pinned) and
    chunk edges (the resumed prefill replays the cold path bit-for-bit)."""
    return page_size * prefill_chunk // math.gcd(page_size, prefill_chunk)
