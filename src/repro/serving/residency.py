"""Expert-weight residency tier: host-offloaded cold experts, streamed in
ahead of the decode wave that needs them (docs/DESIGN.md §Residency).

MemFine's core trade — recompute/transfer for peak memory — applied to the
weights themselves: decode is memory-bandwidth-bound by *activated expert
weights*, not tokens (arXiv 2512.09277), so only a per-layer resident set
of expert FFN weights (w1/w3/w2) stays on device.  Cold experts live in a
permanent host mirror (numpy, captured at construction — restore is
bitwise because the mirror IS the original bits) and their device rows are
zeroed.  The telemetry-predicted set for the next wave is prefetched
(modeled as a double-buffered stream, the weight analogue of the PR 8
spill/restore machinery); anything the wave actually activates that
prediction missed is demand-restored and the wave re-runs from its held
pre-wave cache, so outputs stay bit-identical to the all-resident engine:

* A run in which every *activated* expert held true weights is bitwise
  equal to the all-resident run — non-activated experts contribute nothing
  (dispatch gathers only routed rows; the dense oracle combines them at
  zero weight), so zeroed cold rows are unobservable.
* A run with a miss is discarded (the compiled steps the scheduler uses
  for this path are non-donating and non-committing), the missing experts
  are restored, and the step re-runs.  Layer-0 routing depends only on
  dense weights, so each re-run fixes a strictly longer correct prefix of
  MoE layers; the loop converges in <= L_moe * E iterations.

Eviction is heat-driven (an EMA over observed per-layer loads — the same
signal ``core/telemetry.py`` feeds MACT and placement), never touches the
always-resident set (experts the engine-build ``PlacementSpec`` replicated
— PR 9's hot experts), and runs *after* the wave: capacity is a target the
memory model prices, and transient demand restores above it are reported
honestly through the high-water mark.
"""

from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

#: expert FFN leaves the tier streams (router/shared-expert weights are
#: dense-stage: always resident)
EXPERT_LEAVES = ("w1", "w3", "w2")

#: demand-restore loop bound (paranoia: convergence is <= L_moe * E)
RERUN_LIMIT = 64


def moe_layer_refs(cfg: ModelConfig) -> List[Tuple[str, int, Optional[int]]]:
    """Param-tree address of every MoE layer, in ``load_per_layer`` order.

    Each ref is ``(head, index, period)``: ``params[head][index]["ffn"]``
    holds the layer's MoE params, with ``period`` indexing the stacked
    leading axis when the layer sits inside the scanned periods (pre and
    rem layers have ``period=None``).  Mirrors ``transformer.init_params``
    layout and ``forward``'s telemetry order (pre, periods period-major,
    remainder) — pinned against ``num_moe_layers`` in tests.
    """
    refs: List[Tuple[str, int, Optional[int]]] = []
    for i, spec in enumerate(cfg.prefix):
        if spec.ffn == "moe":
            refs.append(("pre", i, None))
    if cfg.num_periods > 1:
        for p in range(cfg.num_periods):
            for i, spec in enumerate(cfg.pattern):
                if spec.ffn == "moe":
                    refs.append(("periods", i, p))
        rem = cfg.remainder_layers
    else:
        rem = cfg.num_layers - len(cfg.prefix)
    for i in range(rem):
        if cfg.pattern[i % len(cfg.pattern)].ffn == "moe":
            refs.append(("rem", i, None))
    return refs


def always_resident_sets(placements, num_layers: int,
                         num_experts: int) -> List[frozenset]:
    """Per-MoE-layer expert ids the residency tier must never offload: the
    experts the engine-build placement replicated across peers
    (docs/DESIGN.md §Placement) — replication marked them persistently hot,
    and a replica row on another peer is useless if the canonical weights
    just left the device."""
    if placements is None:
        return [frozenset()] * num_layers
    if len(placements) != num_layers:
        raise ValueError(f"{len(placements)} placements for {num_layers} "
                         "MoE layers")
    out = []
    for spec in placements:
        if spec is None:
            out.append(frozenset())
            continue
        counts = spec.replica_counts()
        out.append(frozenset(int(e) for e in np.flatnonzero(counts > 1)))
    return out


def _ffn_updated(params: dict, head: str, idx: int, updates: dict) -> dict:
    """Functional params update: replace ``params[head][idx]["ffn"]`` leaves
    without mutating any shared container (parity tests hand the same
    params object to several schedulers)."""
    layers = list(params[head])
    layer = dict(layers[idx])
    ffn = dict(layer["ffn"])
    ffn.update(updates)
    layer["ffn"] = ffn
    layers[idx] = layer
    out = dict(params)
    out[head] = layers
    return out


class ExpertResidency:
    """Per-layer resident-set manager over the model params pytree.

    All methods are functional over ``params`` (they return a new pytree;
    the caller — the scheduler — reassigns ``self.params``), while the
    manager keeps the host mirror, resident sets, heat EMA and transfer
    counters as its own state.
    """

    def __init__(self, params: dict, cfg: ModelConfig, capacity: int, *,
                 always_resident: Optional[Sequence[frozenset]] = None,
                 heat_decay: float = 0.6):
        if cfg.moe is None:
            raise ValueError("expert residency needs a MoE config")
        self.cfg = cfg
        self.refs = moe_layer_refs(cfg)
        self.num_layers = len(self.refs)
        self.num_experts = cfg.moe.num_experts
        if not 1 <= capacity <= self.num_experts:
            raise ValueError(f"resident capacity {capacity} outside "
                             f"[1, {self.num_experts}]")
        self.capacity = capacity
        self.always = (list(always_resident) if always_resident is not None
                       else [frozenset()] * self.num_layers)
        if len(self.always) != self.num_layers:
            raise ValueError(f"{len(self.always)} always-resident sets for "
                             f"{self.num_layers} MoE layers")
        for j, a in enumerate(self.always):
            if len(a) > capacity:
                raise ValueError(
                    f"layer {j}: {len(a)} always-resident (replicated) "
                    f"experts exceed capacity {capacity}")
        # permanent host mirror: the exact construction-time bits of every
        # expert's FFN leaves — restore round-trips through it bitwise
        self.host: List[dict] = []
        for head, i, p in self.refs:
            ffn = params[head][i]["ffn"]
            self.host.append({
                name: np.asarray(ffn[name][p] if p is not None else ffn[name])
                for name in EXPERT_LEAVES})
        self.resident: List[set] = [set(range(self.num_experts))
                                    for _ in range(self.num_layers)]
        self.heat = np.zeros((self.num_layers, self.num_experts))
        self.heat_decay = heat_decay
        self.reset_stats()

    # -- accounting ----------------------------------------------------------

    def reset_stats(self) -> None:
        self.restores = 0          # expert-layer rows streamed host -> device
        self.offloads = 0          # expert-layer rows zeroed on device
        self.demand_restores = 0   # restores a wave had to block on (misses)
        self.hwm_experts = max(len(s) for s in self.resident) \
            if hasattr(self, "resident") else self.capacity

    def stats(self) -> dict:
        return {"restores": self.restores, "offloads": self.offloads,
                "demand_restores": self.demand_restores,
                "resident_experts_hwm": self.hwm_experts}

    def resident_counts(self) -> np.ndarray:
        return np.asarray([len(s) for s in self.resident], np.int64)

    def _note_hwm(self) -> None:
        self.hwm_experts = max(self.hwm_experts,
                               max(len(s) for s in self.resident))

    # -- heat ----------------------------------------------------------------

    def note(self, load_per_layer) -> None:
        """Fold an observed (L_moe, E) load matrix into the heat EMA — the
        eviction policy's frequency signal (same decay contract as
        ``LoadTelemetry``)."""
        obs = np.asarray(load_per_layer, dtype=np.float64)
        if obs.shape != self.heat.shape:
            raise ValueError(f"load of shape {obs.shape}, expected "
                             f"{self.heat.shape}")
        self.heat = self.heat_decay * self.heat + (1 - self.heat_decay) * obs

    # -- tier transitions ----------------------------------------------------

    def offload_cold(self, params: dict) -> dict:
        """Initial tiering: keep the always-resident experts plus the
        lowest-id fillers up to capacity per layer; zero every other
        expert's device rows.  (With no telemetry yet, low ids are as good
        a guess as any — the first prefill's demand loop corrects it.)"""
        for j in range(self.num_layers):
            keep = set(self.always[j])
            for e in range(self.num_experts):
                if len(keep) >= self.capacity:
                    break
                keep.add(e)
            drop = set(range(self.num_experts)) - keep
            params = self._apply(params, j, drop, restore=False)
            self.resident[j] = keep
            self.offloads += len(drop)
        self.hwm_experts = max(len(s) for s in self.resident)
        return params

    def missing(self, active: np.ndarray) -> List[Tuple[int, int]]:
        """(layer, expert) pairs an (L_moe, E) bool activation matrix hits
        that are NOT resident — what a wave must demand-restore before its
        members' math is trustworthy."""
        act = np.asarray(active)
        if act.shape != (self.num_layers, self.num_experts):
            raise ValueError(f"activation of shape {act.shape}, expected "
                             f"({self.num_layers}, {self.num_experts})")
        return [(j, int(e)) for j in range(self.num_layers)
                for e in np.flatnonzero(act[j])
                if int(e) not in self.resident[j]]

    def ensure(self, params: dict, pairs: Iterable[Tuple[int, int]], *,
               demand: bool = False) -> dict:
        """Restore the given (layer, expert) pairs from the host mirror."""
        by_layer: dict = {}
        for j, e in pairs:
            if e not in self.resident[j]:
                by_layer.setdefault(j, set()).add(e)
        for j, experts in by_layer.items():
            params = self._apply(params, j, experts, restore=True)
            self.resident[j] |= experts
            self.restores += len(experts)
            if demand:
                self.demand_restores += len(experts)
        self._note_hwm()
        return params

    def prefetch(self, params: dict, predicted: np.ndarray) -> dict:
        """Stream the predicted set for the imminent wave: restore predicted
        cold experts, then evict back toward capacity while protecting the
        prediction (evicting what the next wave needs would thrash)."""
        pred = np.asarray(predicted)
        pairs = [(j, int(e)) for j in range(self.num_layers)
                 for e in np.flatnonzero(pred[j])]
        params = self.ensure(params, pairs)
        keep = [frozenset(int(e) for e in np.flatnonzero(pred[j]))
                | self.always[j] for j in range(self.num_layers)]
        return self.evict_to_capacity(params, protect=keep)

    def evict_to_capacity(self, params: dict,
                          protect: Optional[Sequence[frozenset]] = None
                          ) -> dict:
        """Zero the coldest (heat-EMA) evictable experts above capacity per
        layer.  ``protect`` shields a per-layer set beyond the always-
        resident experts; a layer whose protected set exceeds capacity
        simply stays over target (the hwm reports it)."""
        for j in range(self.num_layers):
            shield = set(self.always[j])
            if protect is not None:
                shield |= set(protect[j])
            over = len(self.resident[j]) - self.capacity
            if over <= 0:
                continue
            cands = sorted(self.resident[j] - shield,
                           key=lambda e: (self.heat[j, e], e))
            drop = set(cands[:over])
            if drop:
                params = self._apply(params, j, drop, restore=False)
                self.resident[j] -= drop
                self.offloads += len(drop)
        return params

    def _apply(self, params: dict, layer: int, experts: set,
               restore: bool) -> dict:
        """Write one layer's expert rows: host bits on restore, zeros on
        offload.  Functional over params; periods leaves carry the stacked
        (num_periods, E, ...) layout."""
        if not experts:
            return params
        head, i, p = self.refs[layer]
        ffn = params[head][i]["ffn"]
        idx = jnp.asarray(sorted(experts), jnp.int32)
        updates = {}
        for name in EXPERT_LEAVES:
            leaf = ffn[name]
            if restore:
                rows = jnp.asarray(self.host[layer][name][np.asarray(idx)])
            else:
                shape = ((len(experts),) + leaf.shape[2:] if p is not None
                         else (len(experts),) + leaf.shape[1:])
                rows = jnp.zeros(shape, leaf.dtype)
            if p is not None:
                updates[name] = leaf.at[p, idx].set(rows)
            else:
                updates[name] = leaf.at[idx].set(rows)
        return _ffn_updated(params, head, i, updates)
