from repro.serving.engine import (generate, get_decode_step, get_extend_step,
                                  init_serve_cache, make_serve_step, prefill,
                                  prefill_chunked, prefill_replay)
from repro.serving.paged_cache import CacheLayout, PagedCachePool
from repro.serving.paged_scheduler import PagedScheduler
from repro.serving.paging import (PageAllocator, PagesExhausted, PageTableOps,
                                  PrefixTrie, RequestPages, prefix_align)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ServeConfig)
