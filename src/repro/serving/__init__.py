from repro.serving.engine import (generate, get_decode_step, get_extend_step,
                                  init_serve_cache, make_serve_step, prefill,
                                  prefill_chunked, prefill_replay)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ServeConfig)
