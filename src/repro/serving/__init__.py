from repro.serving.engine import init_serve_cache, make_serve_step, prefill
