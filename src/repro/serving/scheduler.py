"""Continuous-batching scheduler on the MemFine serving memory model.

MemFine's core move — decompose MoE work into chunks and plan them against a
theoretical memory model — applied to serving (docs/DESIGN.md §Serving):

* **Slot map.**  The decode batch is a fixed-capacity pool of ``max_slots``
  per-request cache slots; the compiled decode step is the single-token
  ``transformer.decode_step`` vmapped over slots, so every slot carries its
  own position (ring write cursors included) and requests join/leave at step
  boundaries without retracing.
* **Admission control.**  A queued request starts only when the serving
  memory model (core/memory_model.py::serving_fits — weights + per-request
  caches + the worse of a decode wave and a prefill chunk) says the modeled
  peak still fits ``alpha * M_GPU``.  Occupancy, not allocation, is what the
  model bounds: the pool is allocated once at ``max_slots``, and a budget
  below the full pool simply admits fewer concurrent requests.
* **Chunked prefill interleave.**  Long prompts are split by
  ``core/chunking.py::chunk_spans`` and prefilled one chunk per scheduler
  step between decode waves — the FCDA idea at the request level: bounded
  prefill activations, bounded decode-latency impact.  The first chunk runs
  the single-pass prefill (``transformer.forward(return_cache=True)``), the
  rest the compiled extend step.

Request lifecycle: WAITING -> PREFILL -> ACTIVE -> FINISHED, plus the
overload exit WAITING -> SHED (docs/DESIGN.md §Resilience): a request whose
admission deadline lapses, or that arrives past the WAITING-queue overload
bound, is shed with a client-visible ``retry_after`` quote.  Shedding
applies ONLY to requests never admitted; once accepted (PREFILL/ACTIVE) a
request survives even a faulted decode wave — the fault handler evicts the
wave's slots and *requeues* each accepted request at the head of the queue
(its generated tokens ride along and prefill re-derives the cache), so an
injected or real RESOURCE_EXHAUSTED never loses accepted work.  One request
prefills at a time; its slot is reserved at admission so installation can
never fail.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GPU_64G, HardwareProfile, ModelConfig
from repro.core import memory_model as mm
from repro.core.chunking import chunk_spans
from repro.core.moe import DistContext
from repro.models import transformer
from repro.runtime.faults import FaultInjector
from repro.runtime.guard import ServingGuard, is_oom_error
from repro.serving import engine

WAITING, PREFILL, ACTIVE, FINISHED, SHED = ("waiting", "prefill", "active",
                                            "finished", "shed")


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # (S,) int32 prompt (grows on requeue:
                                        # prompt + generated-so-far)
    max_new_tokens: int
    arrival: float = 0.0                # seconds after scheduler start
    deadline_s: Optional[float] = None  # admission deadline (None = guard's)
    priority: int = 0                   # preemption rank (paged scheduler):
                                        # higher may preempt strictly lower
    # -- runtime (scheduler-owned) -----------------------------------------
    state: str = WAITING
    slot: int = -1
    chunks_done: int = 0
    cache: object = None                # private (B=1) cache while prefilling
    next_token: int = -1
    out: list = field(default_factory=list)
    t_first: Optional[float] = None     # first-token time (s after start)
    t_done: Optional[float] = None
    accepted: bool = False              # ever admitted — shed-exempt
    prompt: Optional[np.ndarray] = None # original prompt (set at submit)
    pending_token: int = -1             # requeue: already-sampled token the
                                        # re-prefill must NOT resample
    requeues: int = 0
    retry_after: Optional[float] = None # quote handed back when shed
    # -- paged scheduler runtime (docs/DESIGN.md §Paging) -------------------
    rp: object = None                   # RequestPages while resident
    pos: int = 0                        # decode write position (host-side)
    spill: object = None                # host-spilled pages while preempted
    preemptions: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    cache_len: int = 128
    prefill_chunk: int = 32
    hw: HardwareProfile = GPU_64G
    dtype_bytes: int = 2                # modeled cache/act bytes (bf16 target;
                                        # the CPU dry-run holds f32, the model
                                        # describes the production target)
    weight_bytes: float = mm.WEIGHT_ONLY_BYTES
    temperature: float = 0.0
    deadline_s: Optional[float] = None  # default admission deadline; a
                                        # WAITING request older than this is
                                        # shed with retry-after
    max_waiting: int = 0                # overload bound on the queue (0 = off)
    # -- paging (docs/DESIGN.md §Paging; 0/False = monolithic slot map) -----
    page_size: int = 0                  # tokens per cache page
    prefix_cache: bool = False          # trie-shared prompt prefixes
    preemption: bool = False            # spill low-priority residents under
                                        # admission pressure
    replica_weight_bytes: float = 0.0   # static cost of the engine-build
                                        # expert placement's replica slots
                                        # (docs/DESIGN.md §Placement); priced
                                        # by admission like any weight bytes


class ContinuousBatchingScheduler:
    def __init__(self, params: dict, cfg: ModelConfig, ctx: DistContext,
                 scfg: ServeConfig, key: Optional[jax.Array] = None,
                 injector: Optional[FaultInjector] = None):
        if cfg.encoder_layers or cfg.num_patch_tokens:
            raise ValueError("continuous batching serves token-only decoders; "
                             f"{cfg.name!r} needs per-request encoder state")
        self.params, self.cfg, self.ctx, self.scfg = params, cfg, ctx, scfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}          # slot -> request
        self.free_slots = list(range(scfg.max_slots))
        self._prefilling: Optional[Request] = None
        one = transformer.init_cache(params, cfg, 1, scfg.cache_len,
                                     jnp.float32)
        self.cache = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (scfg.max_slots,) + l.shape),
            one)
        # donate the slot-pool cache off-CPU (engine._jit), same rationale
        # as the engine's decode step: waves rewrite every ring in place
        self._decode = engine._jit(jax.vmap(
            lambda p, c, t: transformer.decode_step(p, cfg, ctx, c, t),
            in_axes=(None, 0, 0)), donate_cache_arg=1)
        self.injector = injector
        self.guard = ServingGuard(deadline_s=scfg.deadline_s,
                                  max_waiting=scfg.max_waiting)
        # telemetry / invariants
        self.steps = 0
        self.decode_waves = 0
        self.prefill_chunks = 0
        self.max_occupancy = 0
        self.modeled_peak = 0.0
        self.admission_order: list[int] = []
        self.finished: list[Request] = []
        self.shed: list[Request] = []
        self.requeued: int = 0
        self.faults: int = 0

    def reset(self) -> None:
        """Clear all request state and telemetry but keep the compiled
        steps and the allocated slot pool — benchmarks warm the compile
        caches with a throwaway trace, reset, then time steady-state."""
        self.queue.clear()
        self.active.clear()
        self.free_slots = list(range(self.scfg.max_slots))
        self._prefilling = None
        self.steps = self.decode_waves = self.prefill_chunks = 0
        self.max_occupancy = 0
        self.modeled_peak = 0.0
        self.admission_order = []
        self.finished = []
        self.shed = []
        self.requeued = 0
        self.faults = 0

    # -- memory model -------------------------------------------------------

    def occupancy(self) -> int:
        """Requests currently holding cache memory (installed + prefilling)."""
        return len(self.active) + (1 if self._prefilling is not None else 0)

    def modeled_bytes(self, requests: Optional[int] = None) -> float:
        s = self.scfg
        return mm.serving_peak_bytes(
            self.cfg, requests=self.occupancy() if requests is None else requests,
            cache_len=s.cache_len, decode_tokens=s.max_slots,
            prefill_tokens=s.prefill_chunk, dtype_bytes=s.dtype_bytes,
            weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes)

    def _admissible(self, requests: int) -> bool:
        s = self.scfg
        return mm.serving_fits(
            self.cfg, s.hw, requests=requests, cache_len=s.cache_len,
            decode_tokens=s.max_slots, prefill_tokens=s.prefill_chunk,
            dtype_bytes=s.dtype_bytes, weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes)

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request, now: float = 0.0) -> None:
        s = self.scfg
        if len(req.tokens) + req.max_new_tokens > s.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.tokens)} + gen "
                f"{req.max_new_tokens} exceeds cache_len {s.cache_len}")
        if not self._admissible(1):
            raise ValueError(
                f"request {req.rid} can never be admitted: modeled bytes for "
                f"one request ({self.modeled_bytes(1) / 1e9:.2f} GB) exceed "
                f"{s.hw.alpha:.2f} * {s.hw.hbm_bytes / 1e9:.0f} GB")
        req.prompt = np.asarray(req.tokens)
        if self.guard.overloaded(len(self.queue)):     # overload shedding
            self._shed(req, now)
            return
        req.state = WAITING
        self.queue.append(req)

    # -- shedding / fault recovery (docs/DESIGN.md §Resilience) --------------

    def _service_rate(self, now: float) -> float:
        return len(self.finished) / now if now > 0 else 0.0

    def _shed(self, req: Request, now: float) -> None:
        """Refuse a never-accepted request with a client-visible retry-after
        (the backlog drained at the observed service rate)."""
        assert not req.accepted, "accepted requests are never shed"
        req.state = SHED
        req.t_done = now
        backlog = len(self.queue) + self.occupancy()
        req.retry_after = self.guard.retry_after(backlog + 1,
                                                 self._service_rate(now))
        self.shed.append(req)

    def _expire_deadlines(self, now: float) -> None:
        """Shed WAITING requests whose admission deadline lapsed.  Accepted
        requeued requests are deadline-exempt: their work is already paid
        for, and dropping them would violate the no-accepted-loss
        invariant."""
        kept = deque()
        for req in self.queue:
            if not req.accepted and self.guard.expired(req, now):
                self._shed(req, now)
            else:
                kept.append(req)
        self.queue = kept

    def _requeue_active(self, now: float) -> None:
        """A faulted decode wave lost the slot pool's forward progress, not
        the requests: evict every ACTIVE slot and requeue its request at
        the head of the queue.  The request keeps its sampled tokens —
        ``tokens`` becomes prompt + generated-so-far minus the pending one,
        re-prefill rebuilds the cache, and ``pending_token`` re-arms the
        decode feed, so greedy output matches an unfaulted run exactly."""
        for slot in sorted(self.active.keys(), reverse=True):
            req = self.active.pop(slot)
            self.free_slots.append(slot)
            req.tokens = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)])
            req.pending_token = req.out[-1]
            req.chunks_done = 0
            req.cache = None
            req.state = WAITING
            req.requeues += 1
            self.requeued += 1
            self.queue.appendleft(req)     # reverse slot order: slot 0 first

    def _admit(self) -> None:
        """FIFO admission at step boundaries: a slot must be free, at most
        one request prefills at a time, and the serving memory model must
        accept one more resident cache (Eq. 3, serving form)."""
        while (self.queue and self.free_slots and self._prefilling is None
               and self._admissible(self.occupancy() + 1)):
            req = self.queue.popleft()
            req.state = PREFILL
            req.accepted = True
            req.slot = self.free_slots.pop(0)
            self._prefilling = req
            self.admission_order.append(req.rid)
        # occupancy peaks at admission and only falls at evictions, so
        # measuring here (not at end-of-step, after same-step finishes
        # retired) is what makes the reported peak honest
        self.max_occupancy = max(self.max_occupancy, self.occupancy())
        self.modeled_peak = max(self.modeled_peak, self.modeled_bytes())

    # -- prefill interleave -------------------------------------------------

    def _prefill_step(self, now: float) -> None:
        req = self._prefilling
        spans = chunk_spans(len(req.tokens), self.scfg.prefill_chunk)
        start, stop = spans[req.chunks_done]
        seg = jnp.asarray(req.tokens[None, start:stop], jnp.int32)
        logits, req.cache = engine.prefill_chunk(
            self.params, self.cfg, self.ctx, req.cache, seg,
            self.scfg.cache_len)
        req.chunks_done += 1
        self.prefill_chunks += 1
        if req.chunks_done == len(spans):
            self._install(req, logits, now)

    def _install(self, req: Request, logits, now: float) -> None:
        """Join at a step boundary: copy the private prefill cache into the
        reserved slot and sample the first token from the prefill logits."""
        self.cache = jax.tree.map(
            lambda full, one: full.at[req.slot].set(one),
            self.cache, req.cache)
        req.cache = None
        req.state = ACTIVE
        if req.t_first is None:
            req.t_first = now
        self.active[req.slot] = req
        self._prefilling = None
        if req.pending_token >= 0:
            # requeued after a faulted wave: the next decode token was
            # already sampled before the fault — feed it, don't resample
            req.next_token = req.pending_token
            req.pending_token = -1
        else:
            self._append_token(req, np.asarray(logits[0, -1]), now)

    # -- decode -------------------------------------------------------------

    def _sample(self, req: Request, logits_v: np.ndarray) -> int:
        if self.scfg.temperature > 0:
            k = jax.random.fold_in(jax.random.fold_in(self.key, req.rid),
                                   len(req.out))
            return int(jax.random.categorical(
                k, jnp.asarray(logits_v) / self.scfg.temperature))
        return int(np.argmax(logits_v))

    def _append_token(self, req: Request, logits_v: np.ndarray,
                      now: float) -> None:
        tok = self._sample(req, logits_v)
        req.out.append(tok)
        req.next_token = tok
        if len(req.out) >= req.max_new_tokens:
            self._evict(req, now)

    def _evict(self, req: Request, now: float) -> None:
        """Leave at a step boundary: release the slot (contents are dead
        weight until the next install overwrites them)."""
        req.state = FINISHED
        req.t_done = now
        self.active.pop(req.slot, None)
        self.free_slots.append(req.slot)
        self.finished.append(req)

    def _decode_wave(self, now: float) -> None:
        toks = np.zeros((self.scfg.max_slots, 1, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0, 0] = req.next_token
        try:
            if self.injector is not None:
                self.injector.maybe_fail_step(self.steps, "decode_wave")
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
            logits = np.asarray(logits)   # (slots, 1, 1, V): the host fetch
        except Exception as exc:          # is where a real OOM surfaces
            if not is_oom_error(exc):
                raise
            # faulted wave: no token was appended, the slot pool may hold
            # garbage — requeue every accepted request and start clean
            self.faults += 1
            self._requeue_active(now)
            # the wave's donated slot pool may be torn — rebuild it; the
            # requeued requests' re-prefills repopulate their slots
            one = transformer.init_cache(self.params, self.cfg, 1,
                                         self.scfg.cache_len, jnp.float32)
            self.cache = jax.tree.map(
                lambda l: jnp.broadcast_to(
                    l[None], (self.scfg.max_slots,) + l.shape), one)
            return
        self.decode_waves += 1
        for slot, req in list(self.active.items()):
            self._append_token(req, logits[slot, 0, -1], now)

    # -- main loop ----------------------------------------------------------

    def step(self, now: float = 0.0) -> bool:
        """One scheduler step: expire lapsed deadlines, admit, run one
        prefill chunk, run one decode wave.  Returns False when there was
        nothing to do."""
        if self.injector is not None:
            self.injector.maybe_stall(self.steps)      # stalled-prefill chaos
        self._expire_deadlines(now)
        self._admit()
        busy = False
        if self._prefilling is not None:
            self._prefill_step(now)
            busy = True
        if self.active:
            self._decode_wave(now)
            busy = True
        self.steps += 1
        return busy

    def run(self, requests: list[Request]) -> dict:
        """Drive a trace of requests (``arrival`` = seconds after start) to
        completion against the wall clock; returns the metrics dict."""
        pending = sorted(requests, key=lambda r: r.arrival)
        t0 = time.perf_counter()
        i = 0
        while (i < len(pending) or self.queue or self.active
               or self._prefilling is not None):
            now = time.perf_counter() - t0
            while i < len(pending) and pending[i].arrival <= now:
                self.submit(pending[i], now)
                i += 1
            if not self.step(now) and i < len(pending):
                time.sleep(min(pending[i].arrival - now, 0.01))
        return self.metrics(time.perf_counter() - t0)

    def metrics(self, elapsed: float) -> dict:
        lat = [r.t_done - r.arrival for r in self.finished]
        gen = sum(len(r.out) for r in self.finished)
        return {
            "requests": len(self.finished),
            "generated_tokens": gen,
            "elapsed_s": elapsed,
            "tok_per_s": gen / elapsed if elapsed > 0 else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "decode_waves": self.decode_waves,
            "prefill_chunks": self.prefill_chunks,
            "max_occupancy": self.max_occupancy,
            "modeled_peak_bytes": self.modeled_peak,
            "budget_bytes": self.scfg.hw.alpha * self.scfg.hw.hbm_bytes,
            "shed": len(self.shed),
            "retry_after_p50_s": (float(np.percentile(
                [r.retry_after for r in self.shed], 50))
                if self.shed else 0.0),
            "requeues": self.requeued,
            "faults": self.faults,
        }
