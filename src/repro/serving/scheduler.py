"""Continuous-batching scheduler on the MemFine serving memory model.

MemFine's core move — decompose MoE work into chunks and plan them against a
theoretical memory model — applied to serving (docs/DESIGN.md §Serving):

* **Slot map.**  The decode batch is a fixed-capacity pool of ``max_slots``
  per-request cache slots; the compiled decode step is the single-token
  ``transformer.decode_step`` vmapped over slots, so every slot carries its
  own position (ring write cursors included) and requests join/leave at step
  boundaries without retracing.
* **Admission control.**  A queued request starts only when the serving
  memory model (core/memory_model.py::serving_fits — weights + per-request
  caches + the worse of a decode wave and a prefill chunk) says the modeled
  peak still fits ``alpha * M_GPU``.  Occupancy, not allocation, is what the
  model bounds: the pool is allocated once at ``max_slots``, and a budget
  below the full pool simply admits fewer concurrent requests.
* **Chunked prefill interleave.**  Long prompts are split by
  ``core/chunking.py::chunk_spans`` and prefilled one chunk per scheduler
  step between decode waves — the FCDA idea at the request level: bounded
  prefill activations, bounded decode-latency impact.  The first chunk runs
  the single-pass prefill (``transformer.forward(return_cache=True)``), the
  rest the compiled extend step.

Request lifecycle: WAITING -> PREFILL -> ACTIVE -> FINISHED, plus the
overload exit WAITING -> SHED (docs/DESIGN.md §Resilience): a request whose
admission deadline lapses, or that arrives past the WAITING-queue overload
bound, is shed with a client-visible ``retry_after`` quote.  Shedding
applies ONLY to requests never admitted; once accepted (PREFILL/ACTIVE) a
request survives even a faulted decode wave — the fault handler evicts the
wave's slots and *requeues* each accepted request at the head of the queue
(its generated tokens ride along and prefill re-derives the cache), so an
injected or real RESOURCE_EXHAUSTED never loses accepted work.  One request
prefills at a time; its slot is reserved at admission so installation can
never fail.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GPU_64G, HardwareProfile, ModelConfig
from repro.core import memory_model as mm
from repro.core.chunking import chunk_spans
from repro.core.moe import DistContext
from repro.core.telemetry import ExpertTelemetry
from repro.models import transformer
from repro.runtime.faults import FaultInjector
from repro.runtime.guard import ServingGuard, is_oom_error
from repro.serving import engine, residency

WAITING, PREFILL, ACTIVE, FINISHED, SHED = ("waiting", "prefill", "active",
                                            "finished", "shed")


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # (S,) int32 prompt (grows on requeue:
                                        # prompt + generated-so-far)
    max_new_tokens: int
    arrival: float = 0.0                # seconds after scheduler start
    deadline_s: Optional[float] = None  # admission deadline (None = guard's)
    priority: int = 0                   # preemption rank (paged scheduler):
                                        # higher may preempt strictly lower
    # -- runtime (scheduler-owned) -----------------------------------------
    state: str = WAITING
    slot: int = -1
    chunks_done: int = 0
    cache: object = None                # private (B=1) cache while prefilling
    next_token: int = -1
    out: list = field(default_factory=list)
    t_first: Optional[float] = None     # first-token time (s after start)
    t_done: Optional[float] = None
    accepted: bool = False              # ever admitted — shed-exempt
    prompt: Optional[np.ndarray] = None # original prompt (set at submit)
    pending_token: int = -1             # requeue: already-sampled token the
                                        # re-prefill must NOT resample
    requeues: int = 0
    retry_after: Optional[float] = None # quote handed back when shed
    wave_wait: int = 0                  # consecutive decode waves skipped
                                        # while ACTIVE (starvation guard)
    # -- paged scheduler runtime (docs/DESIGN.md §Paging) -------------------
    rp: object = None                   # RequestPages while resident
    pos: int = 0                        # decode write position (host-side)
    spill: object = None                # host-spilled pages while preempted
    preemptions: int = 0


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 4
    cache_len: int = 128
    prefill_chunk: int = 32
    hw: HardwareProfile = GPU_64G
    dtype_bytes: int = 2                # modeled cache/act bytes (bf16 target;
                                        # the CPU dry-run holds f32, the model
                                        # describes the production target)
    weight_bytes: float = mm.WEIGHT_ONLY_BYTES
    temperature: float = 0.0
    deadline_s: Optional[float] = None  # default admission deadline; a
                                        # WAITING request older than this is
                                        # shed with retry-after
    max_waiting: int = 0                # overload bound on the queue (0 = off)
    # -- paging (docs/DESIGN.md §Paging; 0/False = monolithic slot map) -----
    page_size: int = 0                  # tokens per cache page
    prefix_cache: bool = False          # trie-shared prompt prefixes
    preemption: bool = False            # spill low-priority residents under
                                        # admission pressure
    replica_weight_bytes: float = 0.0   # static cost of the engine-build
                                        # expert placement's replica slots
                                        # (docs/DESIGN.md §Placement); priced
                                        # by admission like any weight bytes
    # -- expert-aware decode + residency (docs/DESIGN.md §Residency) --------
    expert_batching: bool = False       # group waves by predicted expert
                                        # overlap instead of FIFO age order
    wave_size: int = 0                  # max members per decode wave (0 =
                                        # every resident; >0 engages the
                                        # masked subset step, FIFO-ordered
                                        # unless expert_batching)
    max_wave_wait: int = 4              # starvation guard: a resident that
                                        # skipped this many waves is force-
                                        # included in the next one
    resident_experts: int = 0           # per-MoE-layer resident expert
                                        # capacity (0 = all resident, tier
                                        # off); cold experts host-offloaded
    prefetch_experts: int = 1           # modeled in-flight prefetch buffer
                                        # (per-expert-layer weight rows the
                                        # memory model prices on top of the
                                        # resident set)
    probe_router: bool = False          # router-only probe on prompt tokens
                                        # seeds the prefetch prediction for
                                        # requests with no telemetry yet


class ContinuousBatchingScheduler:
    def __init__(self, params: dict, cfg: ModelConfig, ctx: DistContext,
                 scfg: ServeConfig, key: Optional[jax.Array] = None,
                 injector: Optional[FaultInjector] = None):
        if cfg.encoder_layers or cfg.num_patch_tokens:
            raise ValueError("continuous batching serves token-only decoders; "
                             f"{cfg.name!r} needs per-request encoder state")
        self.params, self.cfg, self.ctx, self.scfg = params, cfg, ctx, scfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}          # slot -> request
        self.free_slots = list(range(scfg.max_slots))
        self._prefilling: Optional[Request] = None
        one = transformer.init_cache(params, cfg, 1, scfg.cache_len,
                                     jnp.float32)
        self.cache = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (scfg.max_slots,) + l.shape),
            one)
        # donate the slot-pool cache off-CPU (engine._jit), same rationale
        # as the engine's decode step: waves rewrite every ring in place
        self._decode = engine._jit(jax.vmap(
            lambda p, c, t: transformer.decode_step(p, cfg, ctx, c, t),
            in_axes=(None, 0, 0)), donate_cache_arg=1)
        # expert-aware decode + weight-residency tier (§Residency): any of
        # the three knobs engages the masked subset step, which also reports
        # per-slot routed loads (the telemetry feed)
        self._expert_aware = (scfg.expert_batching or scfg.wave_size > 0
                              or scfg.resident_experts > 0)
        self.telemetry: Optional[ExpertTelemetry] = None
        self.residency = None
        self._probe = None
        if self._expert_aware:
            if cfg.moe is None:
                raise ValueError("expert-aware serving (expert_batching / "
                                 "wave_size / resident_experts) needs a MoE "
                                 f"config; {cfg.name!r} is dense")
            n_moe = transformer.num_moe_layers(cfg)
            self.telemetry = ExpertTelemetry(n_moe, cfg.moe.num_experts)
            self._decode_masked = engine.get_decode_step_masked(cfg, ctx)
            if scfg.probe_router:
                self._probe = engine.get_router_probe(cfg, ctx)
            if scfg.resident_experts > 0:
                always = residency.always_resident_sets(
                    ctx.placements, n_moe, cfg.moe.num_experts)
                self.residency = residency.ExpertResidency(
                    params, cfg, scfg.resident_experts,
                    always_resident=always)
                self.params = self.residency.offload_cold(self.params)
        self.injector = injector
        self.guard = ServingGuard(deadline_s=scfg.deadline_s,
                                  max_waiting=scfg.max_waiting)
        # telemetry / invariants
        self.steps = 0
        self.decode_waves = 0
        self.prefill_chunks = 0
        self.max_occupancy = 0
        self.modeled_peak = 0.0
        self.admission_order: list[int] = []
        self.finished: list[Request] = []
        self.shed: list[Request] = []
        self.requeued: int = 0
        self.faults: int = 0
        self._reset_wave_stats()

    def _reset_wave_stats(self) -> None:
        self.expert_waves = 0          # waves run through the masked step
        self.wave_distinct_sum = 0     # sum over waves of distinct activated
        self.wave_members_sum = 0      # experts / of member count
        self.forced_includes = 0       # starvation-guard force-inclusions
        self.prefetch_hits = 0         # activated expert-layer pairs already
        self.prefetch_misses = 0       # resident / demand-restored mid-wave
        self.demand_reruns = 0         # wave/chunk re-runs after a restore

    def reset(self) -> None:
        """Clear all request state and telemetry but keep the compiled
        steps and the allocated slot pool — benchmarks warm the compile
        caches with a throwaway trace, reset, then time steady-state."""
        self.queue.clear()
        self.active.clear()
        self.free_slots = list(range(self.scfg.max_slots))
        self._prefilling = None
        self.steps = self.decode_waves = self.prefill_chunks = 0
        self.max_occupancy = 0
        self.modeled_peak = 0.0
        self.admission_order = []
        self.finished = []
        self.shed = []
        self.requeued = 0
        self.faults = 0
        self._reset_wave_stats()
        if self.telemetry is not None:
            self.telemetry.clear()
        if self.residency is not None:
            self.residency.reset_stats()

    # -- memory model -------------------------------------------------------

    def occupancy(self) -> int:
        """Requests currently holding cache memory (installed + prefilling)."""
        return len(self.active) + (1 if self._prefilling is not None else 0)

    def _resident_kw(self) -> dict:
        """Memory-model kwargs for the residency tier: with a capacity set,
        admission prices only the resident experts plus the in-flight
        prefetch buffer instead of the full expert table (§Residency)."""
        s = self.scfg
        if s.resident_experts <= 0:
            return {}
        return {"resident_experts": s.resident_experts,
                "prefetch_experts": s.prefetch_experts}

    def modeled_bytes(self, requests: Optional[int] = None) -> float:
        s = self.scfg
        return mm.serving_peak_bytes(
            self.cfg, requests=self.occupancy() if requests is None else requests,
            cache_len=s.cache_len, decode_tokens=s.max_slots,
            prefill_tokens=s.prefill_chunk, dtype_bytes=s.dtype_bytes,
            weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes,
            **self._resident_kw())

    def _admissible(self, requests: int) -> bool:
        s = self.scfg
        return mm.serving_fits(
            self.cfg, s.hw, requests=requests, cache_len=s.cache_len,
            decode_tokens=s.max_slots, prefill_tokens=s.prefill_chunk,
            dtype_bytes=s.dtype_bytes, weight_bytes=s.weight_bytes,
            replica_weight_bytes=s.replica_weight_bytes,
            **self._resident_kw())

    # -- request intake -----------------------------------------------------

    def submit(self, req: Request, now: float = 0.0) -> None:
        s = self.scfg
        if len(req.tokens) + req.max_new_tokens > s.cache_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.tokens)} + gen "
                f"{req.max_new_tokens} exceeds cache_len {s.cache_len}")
        if not self._admissible(1):
            raise ValueError(
                f"request {req.rid} can never be admitted: modeled bytes for "
                f"one request ({self.modeled_bytes(1) / 1e9:.2f} GB) exceed "
                f"{s.hw.alpha:.2f} * {s.hw.hbm_bytes / 1e9:.0f} GB")
        req.prompt = np.asarray(req.tokens)
        if self.guard.overloaded(len(self.queue)):     # overload shedding
            self._shed(req, now)
            return
        req.state = WAITING
        self.queue.append(req)

    # -- shedding / fault recovery (docs/DESIGN.md §Resilience) --------------

    def _service_rate(self, now: float) -> float:
        return len(self.finished) / now if now > 0 else 0.0

    def _shed(self, req: Request, now: float) -> None:
        """Refuse a never-accepted request with a client-visible retry-after
        (the backlog drained at the observed service rate)."""
        assert not req.accepted, "accepted requests are never shed"
        req.state = SHED
        req.t_done = now
        backlog = len(self.queue) + self.occupancy()
        req.retry_after = self.guard.retry_after(backlog + 1,
                                                 self._service_rate(now))
        self.shed.append(req)

    def _expire_deadlines(self, now: float) -> None:
        """Shed WAITING requests whose admission deadline lapsed.  Accepted
        requeued requests are deadline-exempt: their work is already paid
        for, and dropping them would violate the no-accepted-loss
        invariant."""
        kept = deque()
        for req in self.queue:
            if not req.accepted and self.guard.expired(req, now):
                self._shed(req, now)
            else:
                kept.append(req)
        self.queue = kept

    def _requeue_active(self, now: float) -> None:
        """A faulted decode wave lost the slot pool's forward progress, not
        the requests: evict every ACTIVE slot and requeue its request at
        the head of the queue.  The request keeps its sampled tokens —
        ``tokens`` becomes prompt + generated-so-far minus the pending one,
        re-prefill rebuilds the cache, and ``pending_token`` re-arms the
        decode feed, so greedy output matches an unfaulted run exactly."""
        for slot in sorted(self.active.keys(), reverse=True):
            req = self.active.pop(slot)
            self.free_slots.append(slot)
            req.tokens = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)])
            req.pending_token = req.out[-1]
            req.chunks_done = 0
            req.cache = None
            req.state = WAITING
            req.requeues += 1
            self.requeued += 1
            self.queue.appendleft(req)     # reverse slot order: slot 0 first

    def _admit(self) -> None:
        """FIFO admission at step boundaries: a slot must be free, at most
        one request prefills at a time, and the serving memory model must
        accept one more resident cache (Eq. 3, serving form)."""
        while (self.queue and self.free_slots and self._prefilling is None
               and self._admissible(self.occupancy() + 1)):
            req = self.queue.popleft()
            req.state = PREFILL
            req.accepted = True
            req.slot = self.free_slots.pop(0)
            self._prefilling = req
            self.admission_order.append(req.rid)
        # occupancy peaks at admission and only falls at evictions, so
        # measuring here (not at end-of-step, after same-step finishes
        # retired) is what makes the reported peak honest
        self.max_occupancy = max(self.max_occupancy, self.occupancy())
        self.modeled_peak = max(self.modeled_peak, self.modeled_bytes())

    # -- prefill interleave -------------------------------------------------

    def _prefill_step(self, now: float) -> None:
        req = self._prefilling
        spans = chunk_spans(len(req.tokens), self.scfg.prefill_chunk)
        start, stop = spans[req.chunks_done]
        seg = jnp.asarray(req.tokens[None, start:stop], jnp.int32)
        logits, req.cache = self._prefill_compute(req, seg)
        req.chunks_done += 1
        self.prefill_chunks += 1
        if req.chunks_done == len(spans):
            self._install(req, logits, now)

    def _prefill_compute(self, req: Request, seg):
        """One prefill/extend chunk for ``req``.  Expert-aware mode uses the
        loads variants (non-donating) so the chunk both feeds the request's
        expert telemetry and, under residency, can re-run from the SAME
        base cache after demand-restoring any cold expert it activated —
        the installed cache is therefore bitwise the all-resident one."""
        if not self._expert_aware:
            return engine.prefill_chunk(self.params, self.cfg, self.ctx,
                                        req.cache, seg, self.scfg.cache_len)
        if (self.residency is not None and self._probe is not None
                and req.chunks_done == 0):
            # no telemetry yet: probe the prompt's routing on embeddings and
            # prefetch the predicted experts before the first chunk
            counts = np.asarray(self._probe(
                self.params, jnp.asarray(np.asarray(seg[0], np.int32))))
            self.params = self.residency.prefetch(self.params, counts.sum(0) > 0)
        out = {}

        def once():
            logits, cache, load = engine.prefill_chunk(
                self.params, self.cfg, self.ctx, req.cache, seg,
                self.scfg.cache_len, return_load=True)
            out["logits"], out["cache"] = logits, cache
            out["load"] = np.asarray(load)
            return out["load"] > 0, lambda: None

        self._demand_fixpoint(once)
        self.telemetry.update(req.rid, out["load"])
        if self.residency is not None:
            self.residency.note(out["load"])
            self.params = self.residency.evict_to_capacity(self.params)
        return out["logits"], out["cache"]

    def _install(self, req: Request, logits, now: float) -> None:
        """Join at a step boundary: copy the private prefill cache into the
        reserved slot and sample the first token from the prefill logits."""
        self.cache = jax.tree.map(
            lambda full, one: full.at[req.slot].set(one),
            self.cache, req.cache)
        req.cache = None
        req.state = ACTIVE
        if req.t_first is None:
            req.t_first = now
        self.active[req.slot] = req
        self._prefilling = None
        if req.pending_token >= 0:
            # requeued after a faulted wave: the next decode token was
            # already sampled before the fault — feed it, don't resample
            req.next_token = req.pending_token
            req.pending_token = -1
        else:
            self._append_token(req, np.asarray(logits[0, -1]), now)

    # -- decode -------------------------------------------------------------

    def _sample(self, req: Request, logits_v: np.ndarray) -> int:
        if self.scfg.temperature > 0:
            k = jax.random.fold_in(jax.random.fold_in(self.key, req.rid),
                                   len(req.out))
            return int(jax.random.categorical(
                k, jnp.asarray(logits_v) / self.scfg.temperature))
        return int(np.argmax(logits_v))

    def _append_token(self, req: Request, logits_v: np.ndarray,
                      now: float) -> None:
        tok = self._sample(req, logits_v)
        req.out.append(tok)
        req.next_token = tok
        if len(req.out) >= req.max_new_tokens:
            self._evict(req, now)

    def _evict(self, req: Request, now: float) -> None:
        """Leave at a step boundary: release the slot (contents are dead
        weight until the next install overwrites them)."""
        req.state = FINISHED
        req.t_done = now
        self.active.pop(req.slot, None)
        self.free_slots.append(req.slot)
        self.finished.append(req)
        if self.telemetry is not None:
            self.telemetry.forget(req.rid)

    def _wave_fault_reset(self, now: float) -> None:
        """Faulted wave: no token was appended, the slot pool may hold
        garbage — requeue every accepted request and rebuild the (possibly
        donated/torn) pool; the requeued requests' re-prefills repopulate
        their slots."""
        self.faults += 1
        self._requeue_active(now)
        one = transformer.init_cache(self.params, self.cfg,
                                     1, self.scfg.cache_len, jnp.float32)
        self.cache = jax.tree.map(
            lambda l: jnp.broadcast_to(
                l[None], (self.scfg.max_slots,) + l.shape), one)

    # -- expert-aware wave formation (docs/DESIGN.md §Residency) -------------

    def _predicted_support(self, req: Request) -> Optional[np.ndarray]:
        """(L_moe, E) bool predicted-activation mask for ``req``: telemetry
        EMA support when seen, router probe as the cold-start fallback."""
        sup = self.telemetry.support(req.rid)
        if sup is not None:
            return sup
        if self._probe is not None:
            toks = np.asarray(req.tokens[-8:], np.int32)
            counts = np.asarray(self._probe(self.params, jnp.asarray(toks)))
            return counts.sum(axis=0) > 0
        return None

    def _expert_set(self, req: Request) -> frozenset:
        sup = self._predicted_support(req)
        if sup is None:
            return frozenset()
        return frozenset(int(e) for e in np.flatnonzero(sup.any(axis=0)))

    def _form_wave(self) -> list:
        """Choose this wave's member slots.

        Everyone decodes when the residents fit ``wave_size``.  Over
        capacity, FIFO mode takes the longest-waiting residents; expert
        mode seeds with the starvation-guard force-includes (wave_wait >=
        max_wave_wait) and the longest-waiting request, then greedily adds
        the resident whose predicted expert set grows the wave's union the
        least — minimizing distinct activated experts per wave, which is
        what the residency tier streams and decode bandwidth pays for."""
        s = self.scfg
        items = sorted(self.active.items())
        cap = s.wave_size if s.wave_size > 0 else len(items)
        if len(items) <= cap:
            return [slot for slot, _ in items]
        by_age = sorted(items, key=lambda kv: (-kv[1].wave_wait, kv[1].rid))
        if not s.expert_batching:
            return [slot for slot, _ in by_age[:cap]]
        chosen = [kv for kv in by_age
                  if kv[1].wave_wait >= s.max_wave_wait][:cap]
        self.forced_includes += len(chosen)
        taken = {slot for slot, _ in chosen}
        pool = [kv for kv in by_age if kv[0] not in taken]
        if not chosen and pool:
            chosen.append(pool.pop(0))            # seed: longest-waiting
        union = set()
        for _, req in chosen:
            union |= self._expert_set(req)
        while len(chosen) < cap and pool:
            best = min(pool, key=lambda kv: (
                len(self._expert_set(kv[1]) - union),
                -kv[1].wave_wait, kv[1].rid))
            pool.remove(best)
            chosen.append(best)
            union |= self._expert_set(best[1])
        return [slot for slot, _ in chosen]

    def _demand_fixpoint(self, run_once):
        """Drive one compute (decode wave or prefill chunk) to the residency
        fixpoint.  ``run_once() -> (act, commit)``: ``act`` the (L_moe, E)
        bool matrix of experts the run's MEMBERS routed through, ``commit``
        a closure applying that run's state effects.  A run that touched a
        cold expert is discarded, the expert demand-restored, and the run
        re-issued from the same inputs — only the clean run commits, so
        committed state is bitwise the all-resident run's.  Convergence:
        layer-0 routing depends only on dense (always-resident) weights, so
        each re-run trues a strictly longer prefix of MoE layers
        (§Residency).  Returns the clean run's activation matrix."""
        demand: set = set()
        for _ in range(residency.RERUN_LIMIT):
            act, commit = run_once()
            if self.residency is None:
                break
            missing = self.residency.missing(act)
            if not missing:
                break
            self.demand_reruns += 1
            demand.update(missing)
            self.params = self.residency.ensure(self.params, missing,
                                                demand=True)
        else:
            raise RuntimeError("residency demand loop did not converge "
                               f"within {residency.RERUN_LIMIT} re-runs")
        commit()
        if self.residency is not None:
            pairs = {(int(j), int(e)) for j, e in zip(*np.nonzero(act))}
            self.prefetch_misses += len(demand)
            self.prefetch_hits += len(pairs - demand)
        return act

    # hook points the paged subclass overrides ------------------------------

    def _wave_fault_ok(self, exc: Exception) -> bool:
        return is_oom_error(exc)

    def _wave_recover(self, now: float) -> None:
        self._wave_fault_reset(now)

    def _advance_member(self, req: Request) -> None:
        pass                                    # paged: decode cursor bump

    def _run_wave(self, members: list, mask: np.ndarray):
        """Run one member wave to the fixpoint and commit its cache.
        Returns (logits, load) host arrays over ALL slots; non-member load
        rows are zero."""
        toks = np.zeros((self.scfg.max_slots, 1, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0, 0] = req.next_token
        if self.injector is not None:
            self.injector.maybe_fail_step(self.steps, "decode_wave")
        toks_j, mask_j = jnp.asarray(toks), jnp.asarray(mask)
        out = {}

        def once():
            logits, new_cache, load = self._decode_masked(
                self.params, self.cache, toks_j, mask_j)
            out["logits"], out["cache"] = logits, new_cache
            out["load"] = np.asarray(load)
            return out["load"].sum(0) > 0, \
                lambda: setattr(self, "cache", out["cache"])

        self._demand_fixpoint(once)
        return np.asarray(out["logits"]), out["load"]

    def _decode_wave_expert(self, now: float) -> None:
        members = self._form_wave()
        if not members:
            return
        mask = np.zeros((self.scfg.max_slots,), bool)
        mask[members] = True
        if self.residency is not None:
            predicted = np.zeros((self.residency.num_layers,
                                  self.residency.num_experts), bool)
            for slot in members:
                sup = self._predicted_support(self.active[slot])
                if sup is not None:
                    predicted |= sup
            self.params = self.residency.prefetch(self.params, predicted)
        try:
            logits, load = self._run_wave(members, mask)
        except Exception as exc:
            if not self._wave_fault_ok(exc):
                raise
            self._wave_recover(now)
            return
        self.decode_waves += 1
        self.expert_waves += 1
        self.wave_members_sum += len(members)
        self.wave_distinct_sum += int(
            np.count_nonzero(load.sum(axis=(0, 1)) > 0))
        member_set = set(members)
        for slot, req in list(self.active.items()):
            if slot not in member_set:
                req.wave_wait += 1
                continue
            req.wave_wait = 0
            self.telemetry.update(req.rid, load[slot])
            self._advance_member(req)
            self._append_token(req, logits[slot, 0, -1], now)
        if self.residency is not None:
            self.residency.note(load.sum(axis=0))

    def _decode_wave(self, now: float) -> None:
        if self._expert_aware:
            self._decode_wave_expert(now)
            return
        toks = np.zeros((self.scfg.max_slots, 1, 1), np.int32)
        for slot, req in self.active.items():
            toks[slot, 0, 0] = req.next_token
        try:
            if self.injector is not None:
                self.injector.maybe_fail_step(self.steps, "decode_wave")
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(toks))
            logits = np.asarray(logits)   # (slots, 1, 1, V): the host fetch
        except Exception as exc:          # is where a real OOM surfaces
            if not is_oom_error(exc):
                raise
            # the wave's donated slot pool may be torn — rebuild it
            self._wave_fault_reset(now)
            return
        self.decode_waves += 1
        for slot, req in list(self.active.items()):
            self._append_token(req, logits[slot, 0, -1], now)

    # -- main loop ----------------------------------------------------------

    def step(self, now: float = 0.0) -> bool:
        """One scheduler step: expire lapsed deadlines, admit, run one
        prefill chunk, run one decode wave.  Returns False when there was
        nothing to do."""
        if self.injector is not None:
            self.injector.maybe_stall(self.steps)      # stalled-prefill chaos
        self._expire_deadlines(now)
        self._admit()
        busy = False
        if self._prefilling is not None:
            self._prefill_step(now)
            busy = True
        if self.active:
            self._decode_wave(now)
            busy = True
        self.steps += 1
        return busy

    def run(self, requests: list[Request]) -> dict:
        """Drive a trace of requests (``arrival`` = seconds after start) to
        completion against the wall clock; returns the metrics dict."""
        pending = sorted(requests, key=lambda r: r.arrival)
        t0 = time.perf_counter()
        i = 0
        while (i < len(pending) or self.queue or self.active
               or self._prefilling is not None):
            now = time.perf_counter() - t0
            while i < len(pending) and pending[i].arrival <= now:
                self.submit(pending[i], now)
                i += 1
            if not self.step(now) and i < len(pending):
                time.sleep(min(pending[i].arrival - now, 0.01))
        return self.metrics(time.perf_counter() - t0)

    def metrics(self, elapsed: float) -> dict:
        lat = [r.t_done - r.arrival for r in self.finished]
        gen = sum(len(r.out) for r in self.finished)
        return {
            "requests": len(self.finished),
            "generated_tokens": gen,
            "elapsed_s": elapsed,
            "tok_per_s": gen / elapsed if elapsed > 0 else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "decode_waves": self.decode_waves,
            "prefill_chunks": self.prefill_chunks,
            "max_occupancy": self.max_occupancy,
            "modeled_peak_bytes": self.modeled_peak,
            "budget_bytes": self.scfg.hw.alpha * self.scfg.hw.hbm_bytes,
            "shed": len(self.shed),
            "retry_after_p50_s": (float(np.percentile(
                [r.retry_after for r in self.shed], 50))
                if self.shed else 0.0),
            "requeues": self.requeued,
            "faults": self.faults,
            # -- expert-aware wave + residency counters (§Residency) --------
            "expert_waves": self.expert_waves,
            "mean_distinct_experts": (self.wave_distinct_sum
                                      / self.expert_waves
                                      if self.expert_waves else 0.0),
            "mean_wave_occupancy": (self.wave_members_sum / self.expert_waves
                                    if self.expert_waves else 0.0),
            "forced_includes": self.forced_includes,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_misses": self.prefetch_misses,
            "demand_reruns": self.demand_reruns,
            **({"residency": self.residency.stats()}
               if self.residency is not None else {}),
        }
