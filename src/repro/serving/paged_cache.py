"""Paged cache pools: the array side of docs/DESIGN.md §Paging.

``serving/paging.py`` owns the pure bookkeeping (allocator, page tables,
prefix trie); this module owns the device arrays and the compiled steps:

* **CacheLayout** — classifies every leaf of the per-request decode cache
  pytree (``transformer.init_cache``) by diffing a batch=1 against a
  batch=2 template: the axis whose size differs is the batch axis.  Leaves
  split into *token* leaves (attention K/V — paged along their token axis,
  grouped by (cache length, ring-ness)), *state* leaves (SSM state, conv
  tail, cross K/V — one constant-size state block per request) and the
  scalar ``pos`` (kept host-side per slot).
* **PagedCachePool** — one pool array per leaf, ``(pages,) + leaf_shape``
  with the token axis cut to ``page_size``.  A decode wave gathers each
  slot's page table into the dense per-slot cache
  (``blocks.gather_paged_tokens``), runs the *unchanged* vmapped
  ``transformer.decode_step``, and scatters the written rows back
  (``blocks.scatter_paged_tokens``) — which is what makes paged decode
  bit-identical to the monolithic slot pool: the reconstructed dense cache
  carries the exact same live values (the zero page stands in for
  never-filled blocks, and rows past a request's filled length are masked
  to exactly-zero attention weight either way), so the compiled step
  computes bitwise-equal logits.

Pool pages that were freed and reallocated may hold stale finite values in
their not-yet-written rows; those rows are unreachable by construction
(gather points never-filled *blocks* at the zero page, and decode/extend
masks unfilled *rows* inside a live block to -inf scores before softmax),
so outputs stay bit-identical without per-allocation zeroing.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.moe import DistContext
from repro.models import blocks, transformer
from repro.serving import engine
from repro.serving.paging import (RESERVED_PAGES, SCRATCH_PAGE, ZERO_PAGE,
                                  Group, PageAllocator, PageTableOps,
                                  RequestPages, space_key, STATE_SPACE)


@dataclass(frozen=True)
class LeafInfo:
    path: tuple                 # normalized key path into the cache pytree
    kind: str                   # "token" | "state" | "pos"
    batch_axis: int             # axis of the per-request batch dim (size 1)
    token_axis: Optional[int]   # token axis in the batchless shape
    group: Optional[Group]      # token leaves: which page space
    bshape: tuple               # per-request shape with the batch axis removed


def _norm_path(path) -> tuple:
    out = []
    for k in path:
        out.append(k.key if hasattr(k, "key") else k.idx)
    return tuple(out)


def _leaf_spec(path: tuple, cfg: ModelConfig):
    """LayerSpec of the layer owning an attention-cache leaf, recovered from
    its position in the cache pytree (pre / scanned periods / remainder)."""
    head, idx = path[0], path[1]
    if head == "pre":
        return cfg.prefix[idx]
    if head == "periods":
        return cfg.pattern[idx]
    assert head == "rem", f"unexpected cache leaf path {path}"
    return cfg.pattern[idx % len(cfg.pattern)]


class CacheLayout:
    """Leaf classification + treedef for one (params, cfg, cache_len)."""

    def __init__(self, params: dict, cfg: ModelConfig, cache_len: int,
                 dtype=jnp.float32, enc_out: Optional[jax.Array] = None):
        eo1 = eo2 = None
        if enc_out is not None:
            eo1 = jnp.zeros_like(enc_out[:1])
            eo2 = jnp.zeros((2,) + enc_out.shape[1:], enc_out.dtype)
        t1 = transformer.init_cache(params, cfg, 1, cache_len, dtype,
                                    enc_out=eo1)
        t2 = transformer.init_cache(params, cfg, 2, cache_len, dtype,
                                    enc_out=eo2)
        l1, self.treedef = jax.tree_util.tree_flatten_with_path(t1)
        l2, _ = jax.tree_util.tree_flatten_with_path(t2)
        self.cache_len = cache_len
        self.dtype = dtype
        self.leaves: list[LeafInfo] = []
        for (p1, a1), (_p2, a2) in zip(l1, l2):
            path = _norm_path(p1)
            if path == ("pos",):
                self.leaves.append(LeafInfo(path, "pos", -1, None, None, ()))
                continue
            diff = [ax for ax, (s1, s2) in enumerate(zip(a1.shape, a2.shape))
                    if s1 != s2]
            assert len(diff) == 1 and a1.shape[diff[0]] == 1, (
                f"cannot locate batch axis of cache leaf {path}: "
                f"{a1.shape} vs {a2.shape}")
            b = diff[0]
            bshape = a1.shape[:b] + a1.shape[b + 1:]
            if "attn" in path and path[-1] in ("k", "v"):
                spec = _leaf_spec(path, cfg)
                t = len(bshape) - 3           # (..., Sc, KH, hd)
                Sc = bshape[t]
                assert Sc == blocks.cache_len(spec, cache_len), path
                group = Group(length=Sc, ring=blocks._is_ring(spec, Sc))
                self.leaves.append(LeafInfo(path, "token", b, t, group,
                                            bshape))
            else:
                self.leaves.append(LeafInfo(path, "state", b, None, None,
                                            bshape))
        self.groups: list[Group] = sorted(
            {i.group for i in self.leaves if i.kind == "token"},
            key=lambda g: (g.length, g.ring))

    # -- modeled sizes (production dtype, not the CPU-dry-run f32) -----------

    def page_bytes(self, group: Group, page: int, dtype_bytes: int) -> float:
        per_token = sum(math.prod(i.bshape) // group.length
                       for i in self.leaves
                       if i.kind == "token" and i.group == group)
        return float(page * per_token * dtype_bytes)

    def state_bytes(self, dtype_bytes: int) -> float:
        return float(sum(math.prod(i.bshape) for i in self.leaves
                         if i.kind == "state") * dtype_bytes)


class PagedCachePool:
    """Page pools + compiled paged decode / install / gather / spill.

    ``n_slots`` bounds the decode-wave width (same role as the monolithic
    slot map); pages, not slots, bound memory.  ``token_pages`` /
    ``state_blocks`` size the physical pools — the *byte* budget is
    enforced by the scheduler through the paged memory model, so the
    physical pools only need to cover what admission can ever grant.
    """

    def __init__(self, params: dict, cfg: ModelConfig, ctx: DistContext,
                 n_slots: int, cache_len: int, page_size: int, *,
                 dtype=jnp.float32, dtype_bytes: int = 2,
                 token_pages: Optional[int] = None,
                 state_blocks: Optional[int] = None,
                 enc_out: Optional[jax.Array] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.cfg, self.ctx = cfg, ctx
        self.n_slots = n_slots
        self.page = page_size
        self.layout = CacheLayout(params, cfg, cache_len, dtype,
                                  enc_out=enc_out)
        self.groups = self.layout.groups
        self._gidx = {g: i for i, g in enumerate(self.groups)}

        self.alloc = PageAllocator()
        for g in self.groups:
            pages = token_pages if token_pages is not None else (
                (n_slots + 2) * g.blocks(page_size) + 8)
            self.alloc.add_space(space_key(g), pages,
                                 self.layout.page_bytes(g, page_size,
                                                        dtype_bytes))
        n_state = state_blocks if state_blocks is not None else n_slots + 2
        self.alloc.add_space(STATE_SPACE, n_state,
                             self.layout.state_bytes(dtype_bytes))
        self.ops = PageTableOps(self.alloc, self.groups, page_size,
                                state_bytes=self.layout.state_bytes(
                                    dtype_bytes),
                                copy_page_fn=self._copy_page)

        # one pool per leaf: (pages,) + batchless shape, token axis -> page
        pools = []
        for info in self.layout.leaves:
            if info.kind == "pos":
                pools.append(None)
            elif info.kind == "token":
                rows = RESERVED_PAGES + self.alloc.spaces[
                    space_key(info.group)].total
                sh = list(info.bshape)
                sh[info.token_axis] = page_size
                pools.append(jnp.zeros((rows, *sh), dtype))
            else:
                rows = RESERVED_PAGES + n_state
                pools.append(jnp.zeros((rows, *info.bshape), dtype))
        self.pools = tuple(pools)
        self._decode_loads = None        # built lazily (expert-aware only)
        self._decode = self._build_decode()
        self._install = self._build_install()
        self._gather = self._build_gather()
        self._restore = self._build_restore()
        self._copy = {g: self._build_copy(g) for g in self.groups}

    # -- table assembly (host) ----------------------------------------------

    def _tables(self, slot_rps: list, for_scatter: bool) -> tuple:
        """(n_slots, n_blocks_g) int32 per group.  Gather points missing
        blocks at the zero page; scatter points them (and inactive slots)
        at the scratch page."""
        hole = SCRATCH_PAGE if for_scatter else ZERO_PAGE
        out = []
        for g in self.groups:
            nb = g.blocks(self.page)
            t = np.full((self.n_slots, nb), hole, np.int32)
            for s, rp in enumerate(slot_rps):
                if rp is None:
                    continue
                for b, pg in enumerate(rp.tables[g]):
                    if pg is not None:
                        t[s, b] = pg
            out.append(jnp.asarray(t))
        return tuple(out)

    def _state_ids(self, slot_rps: list, for_scatter: bool) -> jax.Array:
        hole = SCRATCH_PAGE if for_scatter else ZERO_PAGE
        ids = [hole if rp is None or rp.state_block is None else rp.state_block
               for rp in slot_rps]
        return jnp.asarray(np.asarray(ids, np.int32))

    def _full_tables(self, rp: RequestPages, for_scatter: bool) -> tuple:
        hole = SCRATCH_PAGE if for_scatter else ZERO_PAGE
        return tuple(
            jnp.asarray(np.asarray(
                [hole if p is None else p for p in rp.tables[g]], np.int32))
            for g in self.groups)

    # -- compiled steps ------------------------------------------------------

    def _build_decode(self):
        cfg, ctx = self.cfg, self.ctx
        infos, treedef = self.layout.leaves, self.layout.treedef
        gidx, page = self._gidx, self.page

        def fn(params, pools, gt, st, sg, ss, pos, toks):
            leaves = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    leaves.append(pos)
                elif info.kind == "token":
                    x = blocks.gather_paged_tokens(
                        pools[i], gt[gidx[info.group]], info.token_axis,
                        info.group.length)
                    leaves.append(jnp.expand_dims(x, 1 + info.batch_axis))
                else:
                    leaves.append(jnp.expand_dims(pools[i][sg],
                                                  1 + info.batch_axis))
            cache = jax.tree_util.tree_unflatten(treedef, leaves)
            logits, new_cache = jax.vmap(
                lambda c, t: transformer.decode_step(params, cfg, ctx, c, t),
                in_axes=(0, 0))(cache, toks)
            new_leaves = jax.tree_util.tree_flatten(new_cache)[0]
            new_pools = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    new_pools.append(None)
                    continue
                x = jnp.squeeze(new_leaves[i], 1 + info.batch_axis)
                if info.kind == "token":
                    new_pools.append(blocks.scatter_paged_tokens(
                        pools[i], st[gidx[info.group]], x, info.token_axis,
                        page))
                else:
                    new_pools.append(pools[i].at[ss].set(x))
            return logits, tuple(new_pools)

        return engine._jit(fn, donate_cache_arg=1)

    def _build_decode_loads(self):
        """Loads-reporting twin of ``_build_decode`` for the expert-aware
        scheduler (docs/DESIGN.md §Residency): same gather -> decode ->
        scatter, but the decode also reports per-slot routed loads, pools
        are NOT donated, and the new pools are returned instead of being
        committed — the residency demand loop may discard a run that
        touched an offloaded expert and re-run it against the same input
        pools after restoring the weights."""
        cfg, ctx = self.cfg, self.ctx
        infos, treedef = self.layout.leaves, self.layout.treedef
        gidx, page = self._gidx, self.page

        def fn(params, pools, gt, st, sg, ss, pos, toks):
            leaves = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    leaves.append(pos)
                elif info.kind == "token":
                    x = blocks.gather_paged_tokens(
                        pools[i], gt[gidx[info.group]], info.token_axis,
                        info.group.length)
                    leaves.append(jnp.expand_dims(x, 1 + info.batch_axis))
                else:
                    leaves.append(jnp.expand_dims(pools[i][sg],
                                                  1 + info.batch_axis))
            cache = jax.tree_util.tree_unflatten(treedef, leaves)
            logits, new_cache, load = jax.vmap(
                lambda c, t: transformer.decode_step(params, cfg, ctx, c, t,
                                                     return_load=True),
                in_axes=(0, 0))(cache, toks)
            new_leaves = jax.tree_util.tree_flatten(new_cache)[0]
            new_pools = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    new_pools.append(None)
                    continue
                x = jnp.squeeze(new_leaves[i], 1 + info.batch_axis)
                if info.kind == "token":
                    new_pools.append(blocks.scatter_paged_tokens(
                        pools[i], st[gidx[info.group]], x, info.token_axis,
                        page))
                else:
                    new_pools.append(pools[i].at[ss].set(x))
            return logits, load, tuple(new_pools)

        return engine._jit(fn)

    def _build_install(self):
        infos, gidx, page = self.layout.leaves, self._gidx, self.page

        def fn(pools, dense, tables, state_id):
            dl = jax.tree_util.tree_flatten(dense)[0]
            new_pools = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    new_pools.append(None)
                    continue
                x = jnp.squeeze(dl[i], info.batch_axis)
                if info.kind == "token":
                    new_pools.append(blocks.scatter_paged_tokens(
                        pools[i], tables[gidx[info.group]], x,
                        info.token_axis, page))
                else:
                    new_pools.append(pools[i].at[state_id].set(x))
            return tuple(new_pools)

        return engine._jit(fn, donate_cache_arg=0)

    def _build_gather(self):
        infos, treedef = self.layout.leaves, self.layout.treedef
        gidx = self._gidx

        def fn(pools, tables, state_vals, pos):
            leaves = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    leaves.append(pos)
                elif info.kind == "token":
                    x = blocks.gather_paged_tokens(
                        pools[i], tables[gidx[info.group]], info.token_axis,
                        info.group.length)
                    leaves.append(jnp.expand_dims(x, info.batch_axis))
                else:
                    leaves.append(jnp.expand_dims(state_vals[i],
                                                  info.batch_axis))
            return jax.tree_util.tree_unflatten(treedef, leaves)

        return engine._jit(fn)

    def _build_restore(self):
        infos, gidx = self.layout.leaves, self._gidx

        def fn(pools, rows, tables, state_id):
            new_pools = []
            for i, info in enumerate(infos):
                if info.kind == "pos":
                    new_pools.append(None)
                elif info.kind == "token":
                    new_pools.append(pools[i].at[tables[gidx[info.group]]]
                                     .set(rows[i]))
                else:
                    new_pools.append(pools[i].at[state_id].set(rows[i]))
            return tuple(new_pools)

        return engine._jit(fn, donate_cache_arg=0)

    def _build_copy(self, group: Group):
        idxs = [i for i, info in enumerate(self.layout.leaves)
                if info.kind == "token" and info.group == group]

        def fn(pools, src, dst):
            out = list(pools)
            for i in idxs:
                out[i] = pools[i].at[dst].set(pools[i][src])
            return tuple(out)

        return engine._jit(fn, donate_cache_arg=0)

    def _copy_page(self, group: Group, src: int, dst: int) -> None:
        self.pools = self._copy[group](self.pools, jnp.int32(src),
                                       jnp.int32(dst))

    # -- high-level ops ------------------------------------------------------

    def prepare_decode_write(self, rp: RequestPages, pos: int) -> None:
        """Before a wave: the block receiving position ``pos`` must exist
        and be exclusively owned (CoW fires here when a ring write cursor
        re-enters a prefix-shared or trie-pinned page)."""
        for g in self.groups:
            self.ops.ensure_writable(rp, g, g.block_of(pos, self.page))

    def decode_wave(self, params, slot_rps: list, pos: np.ndarray,
                    toks: np.ndarray):
        """One vmapped decode step over the slot map, paged: gather tables
        -> dense per-slot caches -> unchanged decode_step -> scatter back.
        ``slot_rps[s]`` is the RequestPages of the request in slot s (None =
        empty slot: reads the zero page, writes the scratch page)."""
        gt = self._tables(slot_rps, for_scatter=False)
        st = self._tables(slot_rps, for_scatter=True)
        sg = self._state_ids(slot_rps, for_scatter=False)
        ss = self._state_ids(slot_rps, for_scatter=True)
        logits, self.pools = self._decode(
            params, self.pools, gt, st, sg, ss,
            jnp.asarray(pos.astype(np.int32)), jnp.asarray(toks))
        return logits

    def decode_wave_loads(self, params, slot_rps: list, pos: np.ndarray,
                          toks: np.ndarray):
        """Non-committing, loads-reporting wave for the expert-aware
        scheduler.  Membership is ``slot_rps[s] is not None``: a None slot
        reads the zero page and scatters to the scratch page, so committing
        the returned pools never perturbs a non-member's state — the paged
        form of the monolithic masked step's tree-select.  Returns
        (logits, load (slots, L_moe, E), new_pools); the caller assigns
        ``pool.pools = new_pools`` only after a residency-clean run."""
        if self._decode_loads is None:
            self._decode_loads = self._build_decode_loads()
        gt = self._tables(slot_rps, for_scatter=False)
        st = self._tables(slot_rps, for_scatter=True)
        sg = self._state_ids(slot_rps, for_scatter=False)
        ss = self._state_ids(slot_rps, for_scatter=True)
        return self._decode_loads(
            params, self.pools, gt, st, sg, ss,
            jnp.asarray(pos.astype(np.int32)), jnp.asarray(toks))

    def install(self, rp: RequestPages, dense, filled: int,
                shared_len: int = 0) -> None:
        """Scatter a finished (B=1) prefill cache into the request's pages.

        Allocates every block holding live rows; prefix-shared blocks stay
        shared when their content provably matches the dense cache (linear
        groups, and rings the prefill did not wrap — the scatter then
        rewrites them with bit-identical rows), otherwise they CoW first.
        Blocks wholly past ``filled`` stay unallocated (the concurrency
        win) and their scatter rows land on the scratch page."""
        for g in self.groups:
            live = min(filled, g.length)
            n_live = math.ceil(live / self.page) if live else 0
            if g.ring and filled > g.length:
                n_live = g.blocks(self.page)
                for b in range(n_live):           # wrap rewrote every block
                    self.ops.ensure_writable(rp, g, b)
            else:
                for b in range(n_live):
                    self.ops.ensure_block(rp, g, b)
        self.ops.alloc_state(rp)
        self.pools = self._install(self.pools, dense,
                                   self._full_tables(rp, for_scatter=True),
                                   jnp.int32(rp.state_block))

    def gather_dense(self, rp_tables: dict, state_vals: list, pos: int):
        """Dense (B=1) cache from explicit per-group block->page lists (a
        prefix-trie match) plus host state leaves — the resume point for a
        prefix-hit prefill.  Missing blocks read the zero page, exactly the
        cold cache's zeros."""
        tables = []
        for g in self.groups:
            t = [ZERO_PAGE if p is None else p for p in rp_tables[g]]
            tables.append(jnp.asarray(np.asarray(t, np.int32)))
        vals = [None if v is None else jnp.asarray(v) for v in state_vals]
        return self._gather(self.pools, tuple(tables), vals, jnp.int32(pos))

    def state_snapshot(self, dense) -> list:
        """Host copies of a dense (B=1) cache's state leaves (aligned with
        the layout's leaf order; None elsewhere) — what a prefix-trie node
        stores so an SSM/hybrid resume is bit-exact."""
        dl = jax.tree_util.tree_flatten(dense)[0]
        out = []
        for i, info in enumerate(self.layout.leaves):
            if info.kind == "state":
                out.append(np.asarray(jnp.squeeze(dl[i], info.batch_axis)))
            else:
                out.append(None)
        return out

    # -- preemption: spill to host / restore --------------------------------

    def spill(self, rp: RequestPages, fault_hook=None) -> dict:
        """Copy the request's page contents to host memory and release every
        page reference (trie pins survive — they hold their own refs).

        ``fault_hook`` fires mid-preemption — after the host copy, before
        any reference is dropped — so an injected fault aborts the spill
        with the resident request and the allocator fully intact."""
        rows = []
        for i, info in enumerate(self.layout.leaves):
            if info.kind == "pos":
                rows.append(None)
            elif info.kind == "token":
                t = [ZERO_PAGE if p is None else p
                     for p in rp.tables[info.group]]
                rows.append(np.asarray(self.pools[i][np.asarray(t)]))
            else:
                blk = rp.state_block
                rows.append(np.asarray(self.pools[i][blk])
                            if blk is not None else None)
        if fault_hook is not None:
            fault_hook("preempt_spill")
        saved = {
            "rows": rows,
            "mask": {g: [p is not None for p in rp.tables[g]]
                     for g in self.groups},
        }
        self.ops.release(rp)
        return saved

    def restore(self, saved: dict) -> RequestPages:
        """Re-admission after a spill: fresh fully-private pages, contents
        scattered back from host — the resumed decode is bit-identical to
        one that was never preempted."""
        rp = self.ops.new_request()
        for g in self.groups:
            for b, had in enumerate(saved["mask"][g]):
                if had:
                    self.ops.ensure_block(rp, g, b)
        self.ops.alloc_state(rp)
        rows = [r if r is None else jnp.asarray(r) for r in saved["rows"]]
        self.pools = self._restore(self.pools, rows,
                                   self._full_tables(rp, for_scatter=True),
                                   jnp.int32(rp.state_block))
        return rp

    def release(self, rp: RequestPages) -> None:
        self.ops.release(rp)
