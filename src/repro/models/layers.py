"""Shared layer primitives: norms, RoPE, projections, dense SwiGLU MLP."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params: dict, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"]
    if "bias" in params:
        y = y + params["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                 # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# projections / MLP
# ---------------------------------------------------------------------------

def init_linear(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (d_in, d_out), dtype) * (d_in ** -0.5)


def init_attention(key: jax.Array, d: int, heads: int, kv_heads: int, hd: int,
                   qk_norm: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, heads * hd, dtype),
        "wk": init_linear(ks[1], d, kv_heads * hd, dtype),
        "wv": init_linear(ks[2], d, kv_heads * hd, dtype),
        "wo": init_linear(ks[3], heads * hd, d, dtype),
    }
    if qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def init_mlp(key: jax.Array, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w1": init_linear(ks[0], d, d_ff, dtype),
        "w3": init_linear(ks[1], d, d_ff, dtype),
        "w2": init_linear(ks[2], d_ff, d, dtype),
    }


def apply_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]
