"""Blocked attention in pure jnp, memory-sane at 32k+ sequence lengths.

Rather than materialising (S, S) score matrices, training/prefill attention
iterates over *static* query blocks (python loop -> static slices, exact
FLOPs):

* ``full`` causal: query block i attends kv[0 : (i+1)*qb] — triangular, no
  wasted block FLOPs (a masked rectangular scan would double the compute term
  in the roofline).
* ``window`` (mixtral SWA 4096, gemma3 local 1024): query block i attends the
  kv band [i*qb - W, (i+1)*qb) — O(S*W) FLOPs.
* ``chunked`` (llama4 iRoPE local): chunks of size W fold into the batch dim,
  then plain causal within each chunk.
* cross attention (whisper): single rectangular block, no mask.

Decode (Sq == 1) reads the whole cache with a positional validity mask —
linear in cache length, so every arch supports decode_32k; window/chunked
layers use ring-buffer caches bounded by W (how long_500k stays affordable).

GQA: KV is repeated up to H *before* the score einsum.  The grouped
(B, S, KH, G, hd) formulation would save the repeat locally but breaks GSPMD
head sharding (KH < mesh axis -> replicated scores, observed 34 GB/device in
the dry-run); the repeated layout keeps every score tensor sharded over the
model axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec

NEG_INF = -1e30


def repeat_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, KH, hd) -> (B, S, H, hd) by repeating each KV head H/KH times."""
    KH = k.shape[2]
    if KH == num_heads:
        return k
    return jnp.repeat(k, num_heads // KH, axis=2)


def _block_attend(q, k, v, mask, scale):
    """q: (B, Sq, H, hd), k/v: (B, Skv, H, hd), mask: (Sq, Skv) or None."""
    s = jnp.einsum("bqhd,bshd->bhqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def _causal_mask(sq: int, skv: int, q_start: int, kv_start: int,
                 window: int = 0):
    qpos = q_start + jnp.arange(sq)[:, None]
    kpos = kv_start + jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def attention(q: jax.Array, k: jax.Array, v: jax.Array, spec: AttentionSpec,
              *, causal: bool = True, block_q: int = 1024) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KH, hd) -> (B, Sq, H, hd).

    Training / prefill path (Sq == Skv).  Decode uses ``decode_attention``.
    """
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)

    if spec.kind == "chunked" and causal and S > spec.window:
        C = spec.window
        assert S % C == 0, (S, C)
        n = S // C
        # fold chunks into batch: each chunk is independent causal attention
        qc = q.reshape(B * n, C, H, hd)
        kc = k.reshape(B * n, C, H, hd)
        vc = v.reshape(B * n, C, H, hd)
        mask = _causal_mask(C, C, 0, 0)
        return _block_attend(qc, kc, vc, mask, scale).reshape(B, S, H, hd)

    if not causal:
        return _block_attend(q, k, v, None, scale)

    qb = min(block_q, S)
    assert S % qb == 0, (S, qb)
    n = S // qb
    window = spec.window if spec.kind == "window" else 0
    outs = []
    for i in range(n):
        q_start = i * qb
        lo = max(0, (q_start - window) // qb * qb) if window else 0
        hi = q_start + qb
        qi = q[:, q_start:hi]
        ki, vi = k[:, lo:hi], v[:, lo:hi]
        mask = _causal_mask(qb, hi - lo, q_start, lo, window)
        outs.append(_block_attend(qi, ki, vi, mask, scale))
    return jnp.concatenate(outs, axis=1)


def extend_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Cache-extension attention: a C-token chunk against cached + own K/V.

    q: (B, C, H, hd) — the chunk's queries; k, v: (B, Skv, KH, hd) — the
    *pre-repeat* KV cache concatenated with the chunk's own new K/V;
    mask: (C, Skv) bool — which key slots each query may see.  The caller
    builds the mask from per-slot *positions* (ring layout included), so one
    kernel serves full/window/chunked caches and the non-causal cross case
    (docs/DESIGN.md §Serving).  This is what chunked prefill lowers: decode
    (C == 1) stays on ``decode_attention``'s length-mask fast path.

    Grouped (KH, G) GQA form, same rationale as ``decode_attention``: at
    serving batch sizes the batch dim carries the sharding and not repeating
    the cache saves H/KH cache-sized temporaries.
    """
    B, C, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    scale = hd ** -0.5
    qg = q.reshape(B, C, KH, G, hd)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgcs,bskd->bckgd", p, v)
    return out.reshape(B, C, H, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths, spec: AttentionSpec) -> jax.Array:
    """Single-token decode.  q: (B, 1, H, hd); caches: (B, Sc, KH, hd);
    lengths: (B,) number of valid cache entries (ring caches are always full
    once wrapped, handled by the caller via ``lengths``).

    Uses the grouped (KH, G) GQA form — at decode the batch dim carries the
    sharding, so the head reshape is GSPMD-safe, and NOT repeating the KV
    cache saves H/KH x cache-sized temporaries (observed 4x on mixtral
    decode_32k)."""
    B, _, H, hd = q.shape
    Sc, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = hd ** -0.5
    qg = q.reshape(B, KH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(Sc)[None] < lengths[:, None]               # (B, Sc)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(B, 1, H, hd)
