"""Composable decoder / encoder-decoder transformer over LayerSpec patterns.

The stack is organised as ``num_periods`` repetitions of the config's layer
pattern (jamba 8-layer interleave, gemma3 6-layer 5:1, plain archs period=1)
plus unrolled remainder layers.  Parameters for the repeated period are
*stacked* on a leading axis and the stack is applied with ``lax.scan`` —
keeping HLO size O(period) rather than O(layers), which is what makes the
512-device dry-run compiles of 80-layer configs tractable.

Decode scans the same periods while threading per-period cache slices.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig
from repro.core.moe import DistContext
from repro.models import blocks
from repro.models.layers import apply_norm, init_norm

_ENC_SPEC = LayerSpec(mixer="attn", ffn="dense",
                      attn=AttentionSpec(kind="full", rope=False))


def _constrain(x, pspec):
    if pspec is None:
        return x
    return jax.lax.with_sharding_constraint(x, pspec)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    keys = iter(jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 8))
    cross = cfg.encoder_layers > 0
    pattern = cfg.pattern
    np_, rem = cfg.num_periods, cfg.remainder_layers

    params: dict = {
        "embed": jax.random.normal(next(keys), (cfg.padded_vocab, cfg.d_model),
                                   dtype) * 0.02,
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            next(keys), (cfg.d_model, cfg.padded_vocab), dtype) * (cfg.d_model ** -0.5)
    if cfg.learned_pos:
        params["pos_embed"] = jax.random.normal(
            next(keys), (cfg.learned_pos, cfg.d_model), dtype) * 0.02

    params["pre"] = [blocks.init_layer(next(keys), spec, cfg, cross, dtype)
                     for spec in cfg.prefix]
    if np_ > 1:
        per_period = [
            [blocks.init_layer(next(keys), spec, cfg, cross, dtype)
             for spec in pattern]
            for _ in range(np_)
        ]
        params["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        params["periods"] = None
        rem = cfg.num_layers - len(cfg.prefix)
    params["rem"] = [
        blocks.init_layer(next(keys), pattern[i % len(pattern)], cfg, cross, dtype)
        for i in range(rem)
    ]

    if cfg.encoder_layers:
        ek = iter(jax.random.split(next(keys), cfg.encoder_layers + 2))
        enc_layers = [blocks.init_layer(next(ek), _ENC_SPEC, cfg, False, dtype)
                      for _ in range(cfg.encoder_layers)]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "final_norm": init_norm(cfg.d_model, cfg.norm),
            "pos_embed": jax.random.normal(next(ek), (cfg.encoder_seq, cfg.d_model),
                                           dtype) * 0.02,
        }
    return params


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    if cfg.num_patch_tokens and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.learned_pos:
        S = x.shape[1]
        x = x + params["pos_embed"][:S][None]
    return x


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return (x @ head).astype(jnp.float32)


# ---------------------------------------------------------------------------
# encoder (whisper): frames are precomputed conv-frontend embeddings (stub)
# ---------------------------------------------------------------------------

def encode(params: dict, cfg: ModelConfig, frames: jax.Array,
           ctx: DistContext) -> jax.Array:
    enc = params["encoder"]
    x = frames.astype(enc["pos_embed"].dtype) + enc["pos_embed"][None]
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

    def body(x, layer_params):
        x, _ = blocks.apply_layer(layer_params, x, _ENC_SPEC, cfg, ctx,
                                  positions, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def num_moe_layers(cfg: ModelConfig) -> int:
    """Length of the per-layer schedule vector (adaptive MACT) and of the
    ``load_per_layer`` telemetry matrix's leading axis."""
    return sum(1 for s in cfg.layer_specs() if s.ffn == "moe")


def forward(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict, *,
            return_cache: bool = False, cache_len: Optional[int] = None,
            cache_dtype=jnp.float32):
    """Returns (logits: (B, S, V) f32, stats: summed MoE stats).

    For MoE configs ``stats`` additionally carries ``load_per_layer``, the
    (L_moe, E) matrix of per-MoE-layer routed-token histograms in layer
    order — the telemetry source for adaptive MACT (core/telemetry.py,
    docs/DESIGN.md §Adaptive).  ``ctx.layer_schedules`` (one ScheduleSpec
    per MoE layer) overrides the global (moe_chunks, pipeline_chunks) per
    layer; when the vector differs *across* scanned periods the period scan
    is unrolled (per-layer schedules are static, and a scan body is one
    trace), while a vector uniform across periods keeps the O(period) HLO —
    and reproduces the global path bit-for-bit.

    ``return_cache=True`` is the single-pass serving prefill
    (docs/DESIGN.md §Serving): every layer additionally emits its decode
    cache (K/V rings, SSM state, cross K/V), laid out exactly as
    ``init_cache`` + token-by-token replay would have produced, and the
    return becomes (logits, stats, cache).  ``cache_len`` sizes the caches
    (default: the prompt length); linear caches require cache_len >= S.
    """
    for name in ("layer_schedules", "placements"):
        vec = getattr(ctx, name)
        if vec is not None:
            want = num_moe_layers(cfg)
            if len(vec) != want:
                raise ValueError(
                    f"{name} has {len(vec)} entries, "
                    f"config {cfg.name!r} has {want} MoE layers")
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, batch["frames"], ctx)
    x = embed_inputs(params, cfg, batch)
    x = _constrain(x, ctx.act_pspec)
    B, S, _ = x.shape
    total_len = (cache_len if cache_len is not None else S) if return_cache else None
    cache_kw = (dict(cache_len=total_len, cache_dtype=cache_dtype)
                if return_cache else {})
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pattern = cfg.pattern
    stats_total = blocks.zero_stats(cfg)
    E = cfg.moe.num_experts if cfg.moe else 1
    layer_loads: list = []        # (n, E) pieces, MoE-layer order
    moe_idx = 0                   # position in the per-layer schedule vector
    cache: dict = {"pos": jnp.int32(S)}

    def run_layer(layer_params, x, spec, moe_idx):
        lctx = blocks.layer_ctx(ctx, moe_idx if spec.ffn == "moe" else None)
        out = blocks.apply_layer(layer_params, x, spec, cfg, lctx,
                                 positions, enc_out=enc_out, **cache_kw)
        x, st = out[0], out[1]
        lc = out[2] if return_cache else None
        return _constrain(x, ctx.act_pspec), st, lc

    cache["pre"] = []
    for i, layer_params in enumerate(params.get("pre", [])):
        spec = cfg.prefix[i]
        x, st, lc = run_layer(layer_params, x, spec, moe_idx)
        cache["pre"].append(lc)
        stats_total = jax.tree.map(jnp.add, stats_total, st)
        if spec.ffn == "moe":
            layer_loads.append(st["load"][None])
            moe_idx += 1

    cache["periods"] = None
    if params["periods"] is not None:
        np_ = cfg.num_periods
        n_moe_pat = sum(1 for s in pattern if s.ffn == "moe")
        sched = ctx.layer_schedules
        plac = ctx.placements
        uniform = (sched is None or all(
            len({tuple(sched[moe_idx + p * n_moe_pat + m])
                 for p in range(np_)}) == 1
            for m in range(n_moe_pat))) and (plac is None or all(
                len({plac[moe_idx + p * n_moe_pat + m]
                     for p in range(np_)}) == 1
                for m in range(n_moe_pat)))

        if uniform:
            # one trace serves every period: resolve each pattern position's
            # ctx from period 0's schedule and keep the O(period) scan
            pat_ctx, m = {}, 0
            for i, spec in enumerate(pattern):
                if spec.ffn == "moe":
                    pat_ctx[i] = blocks.layer_ctx(ctx, moe_idx + m)
                    m += 1
                else:
                    pat_ctx[i] = ctx

            def body(x, period_params):
                stats_p = blocks.zero_stats(cfg)
                loads_p = []
                caches_p = []
                for i, spec in enumerate(pattern):
                    out = blocks.apply_layer(period_params[i], x, spec, cfg,
                                             pat_ctx[i], positions,
                                             enc_out=enc_out, **cache_kw)
                    x, st = out[0], out[1]
                    caches_p.append(out[2] if return_cache else None)
                    stats_p = jax.tree.map(jnp.add, stats_p, st)
                    if spec.ffn == "moe":
                        loads_p.append(st["load"])
                x = _constrain(x, ctx.act_pspec)
                loads_p = (jnp.stack(loads_p) if loads_p
                           else jnp.zeros((0, E), jnp.float32))
                return x, (stats_p, loads_p, caches_p)

            x, (stats_stack, loads_stack, caches_stack) = jax.lax.scan(
                body, x, params["periods"])
            stats_total = jax.tree.map(lambda a, s: a + s.sum(0), stats_total,
                                       stats_stack)
            if return_cache:
                cache["periods"] = caches_stack   # scan stacks over periods
            if n_moe_pat:
                layer_loads.append(loads_stack.reshape(np_ * n_moe_pat, E))
        else:
            # heterogeneous schedules inside the scanned region: unroll the
            # periods so each layer compiles under its own (bin, depth)
            period_caches = []
            for p in range(np_):
                period_params = jax.tree.map(lambda a, p=p: a[p],
                                             params["periods"])
                caches_p = []
                for i, spec in enumerate(pattern):
                    x, st, lc = run_layer(period_params[i], x, spec, moe_idx)
                    caches_p.append(lc)
                    stats_total = jax.tree.map(jnp.add, stats_total, st)
                    if spec.ffn == "moe":
                        layer_loads.append(st["load"][None])
                        moe_idx += 1
                period_caches.append(caches_p)
            if return_cache:
                cache["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                                *period_caches)
        if uniform:
            moe_idx += np_ * n_moe_pat

    cache["rem"] = []
    for i, layer_params in enumerate(params["rem"]):
        spec = pattern[i % len(pattern)]
        x, st, lc = run_layer(layer_params, x, spec, moe_idx)
        cache["rem"].append(lc)
        stats_total = jax.tree.map(jnp.add, stats_total, st)
        if spec.ffn == "moe":
            layer_loads.append(st["load"][None])
            moe_idx += 1

    if cfg.moe is not None:
        stats_total["load_per_layer"] = (
            jnp.concatenate(layer_loads, axis=0) if layer_loads
            else jnp.zeros((0, E), jnp.float32))

    logits = unembed(params, cfg, x)
    logits = _constrain(logits, ctx.logits_pspec)
    if return_cache:
        return logits, stats_total, cache
    return logits, stats_total


# ---------------------------------------------------------------------------
# decode: single-token step with per-layer caches
# ---------------------------------------------------------------------------

def init_cache(params: dict, cfg: ModelConfig, batch_size: int, seq_len: int,
               dtype, enc_out: Optional[jax.Array] = None) -> dict:
    pattern = cfg.pattern
    cache: dict = {"pos": jnp.int32(0)}

    def layer_cache(spec: LayerSpec, layer_params):
        cross = layer_params.get("cross") if isinstance(layer_params, dict) else None
        return blocks.init_layer_cache(spec, cfg, batch_size, seq_len, dtype,
                                       enc_out=enc_out, cross_params=cross)

    cache["pre"] = [layer_cache(spec, params["pre"][i])
                    for i, spec in enumerate(cfg.prefix)]
    if params["periods"] is not None:
        n = cfg.num_periods
        per_period = [
            [layer_cache(spec, jax.tree.map(lambda a: a[p], params["periods"][i]))
             for i, spec in enumerate(pattern)]
            for p in range(n)
        ]
        cache["periods"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        cache["periods"] = None
    cache["rem"] = [
        layer_cache(pattern[i % len(pattern)], params["rem"][i])
        for i in range(len(params["rem"]))
    ]
    return cache


def decode_step(params: dict, cfg: ModelConfig, ctx: DistContext,
                cache: dict, tokens: jax.Array, *, return_load: bool = False):
    """tokens: (B, 1) -> (logits (B, 1, V), new cache).  Position from cache.

    ``return_load=True`` appends the (L_moe, E) per-MoE-layer routed-load
    matrix to the return — same layer order as ``forward``'s
    ``load_per_layer`` (pre, scanned periods period-major, remainder) — the
    per-step telemetry source of the expert-aware serving path
    (docs/DESIGN.md §Residency).  The default path is byte-identical to
    before the flag existed."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.learned_pos:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], jnp.minimum(pos, cfg.learned_pos - 1), 1, 0)[None]
    x = x.astype(params["embed"].dtype)
    pattern = cfg.pattern
    E = cfg.moe.num_experts if cfg.moe is not None else 1
    layer_loads: list = []
    new_cache: dict = {"pos": pos + 1}

    new_pre = []
    for i, layer_params in enumerate(params.get("pre", [])):
        out = blocks.apply_layer_decode(layer_params, x, cache["pre"][i],
                                        cfg.prefix[i], cfg, ctx, pos,
                                        return_load=return_load)
        x, c = out[0], out[1]
        new_pre.append(c)
        if return_load and cfg.prefix[i].ffn == "moe":
            layer_loads.append(out[2][None])
    new_cache["pre"] = new_pre

    if params["periods"] is not None:
        def body(x, inp):
            period_params, period_cache = inp
            new_pc = []
            loads_p = []
            for i, spec in enumerate(pattern):
                out = blocks.apply_layer_decode(period_params[i], x,
                                                period_cache[i], spec, cfg,
                                                ctx, pos,
                                                return_load=return_load)
                x = out[0]
                new_pc.append(out[1])
                if return_load and spec.ffn == "moe":
                    loads_p.append(out[2])
            if not return_load:
                return x, new_pc
            loads_p = (jnp.stack(loads_p) if loads_p
                       else jnp.zeros((0, E), jnp.float32))
            return x, (new_pc, loads_p)

        x, ys = jax.lax.scan(body, x, (params["periods"], cache["periods"]))
        if return_load:
            new_periods, loads_stack = ys
            n_moe_pat = sum(1 for s in pattern if s.ffn == "moe")
            if n_moe_pat:
                layer_loads.append(
                    loads_stack.reshape(cfg.num_periods * n_moe_pat, E))
        else:
            new_periods = ys
        new_cache["periods"] = new_periods
    else:
        new_cache["periods"] = None

    new_rem = []
    for i, layer_params in enumerate(params["rem"]):
        spec = pattern[i % len(pattern)]
        out = blocks.apply_layer_decode(layer_params, x, cache["rem"][i],
                                        spec, cfg, ctx, pos,
                                        return_load=return_load)
        x, c = out[0], out[1]
        new_rem.append(c)
        if return_load and spec.ffn == "moe":
            layer_loads.append(out[2][None])
    new_cache["rem"] = new_rem

    logits = unembed(params, cfg, x)
    if return_load:
        load_per_layer = (jnp.concatenate(layer_loads, axis=0) if layer_loads
                          else jnp.zeros((0, E), jnp.float32))
        return logits, new_cache, load_per_layer
    return logits, new_cache


def extend_step(params: dict, cfg: ModelConfig, ctx: DistContext,
                cache: dict, tokens: jax.Array, *, return_load: bool = False):
    """tokens: (B, C) -> (logits (B, C, V), new cache).  Multi-token cache
    extension — the serving chunked-prefill continuation (docs/DESIGN.md
    §Serving): each chunk attends over the cache so far plus itself, then
    its K/V joins the cache.  ``decode_step`` is the C == 1 special case
    (kept separate: decode stays on the length-mask fast path).

    ``return_load=True`` appends the (L_moe, E) routed-load matrix, exactly
    as in ``decode_step``."""
    pos0 = cache["pos"]
    B, C = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.learned_pos:
        idx = jnp.clip(pos0 + jnp.arange(C), 0, cfg.learned_pos - 1)
        x = x + jnp.take(params["pos_embed"], idx, axis=0)[None]
    x = x.astype(params["embed"].dtype)
    pattern = cfg.pattern
    E = cfg.moe.num_experts if cfg.moe is not None else 1
    layer_loads: list = []
    new_cache: dict = {"pos": pos0 + C}

    new_pre = []
    for i, layer_params in enumerate(params.get("pre", [])):
        out = blocks.apply_layer_extend(layer_params, x, cache["pre"][i],
                                        cfg.prefix[i], cfg, ctx, pos0,
                                        return_load=return_load)
        x, c = out[0], out[1]
        new_pre.append(c)
        if return_load and cfg.prefix[i].ffn == "moe":
            layer_loads.append(out[2][None])
    new_cache["pre"] = new_pre

    if params["periods"] is not None:
        def body(x, inp):
            period_params, period_cache = inp
            new_pc = []
            loads_p = []
            for i, spec in enumerate(pattern):
                out = blocks.apply_layer_extend(period_params[i], x,
                                                period_cache[i], spec, cfg,
                                                ctx, pos0,
                                                return_load=return_load)
                x = out[0]
                new_pc.append(out[1])
                if return_load and spec.ffn == "moe":
                    loads_p.append(out[2])
            if not return_load:
                return x, new_pc
            loads_p = (jnp.stack(loads_p) if loads_p
                       else jnp.zeros((0, E), jnp.float32))
            return x, (new_pc, loads_p)

        x, ys = jax.lax.scan(body, x, (params["periods"], cache["periods"]))
        if return_load:
            new_periods, loads_stack = ys
            n_moe_pat = sum(1 for s in pattern if s.ffn == "moe")
            if n_moe_pat:
                layer_loads.append(
                    loads_stack.reshape(cfg.num_periods * n_moe_pat, E))
        else:
            new_periods = ys
        new_cache["periods"] = new_periods
    else:
        new_cache["periods"] = None

    new_rem = []
    for i, layer_params in enumerate(params["rem"]):
        spec = pattern[i % len(pattern)]
        out = blocks.apply_layer_extend(layer_params, x, cache["rem"][i],
                                        spec, cfg, ctx, pos0,
                                        return_load=return_load)
        x, c = out[0], out[1]
        new_rem.append(c)
        if return_load and spec.ffn == "moe":
            layer_loads.append(out[2][None])
    new_cache["rem"] = new_rem

    logits = unembed(params, cfg, x)
    if return_load:
        load_per_layer = (jnp.concatenate(layer_loads, axis=0) if layer_loads
                          else jnp.zeros((0, E), jnp.float32))
        return logits, new_cache, load_per_layer
    return logits, new_cache
