"""One transformer layer: mixer (attention | mamba) + FFN (dense | MoE | none).

Remat policy (docs/DESIGN.md §2):
  * "none"    — store everything (m_g copies in the memory model).
  * "full"    — jax.checkpoint around the whole layer = Megatron full
                recomputation (paper Method 1 when moe_chunks=1).
  * "memfine" — same layer checkpoint, but the MoE inside additionally
                chunk-recomputes (Eq. 7); selected via ctx.moe_chunks > 1
                with remat_chunks=True.  Nested checkpoints compose: during
                a layer's backward, only ONE chunk's dispatch buffers live.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.chunking import ScheduleSpec
from repro.core.moe import DistContext, init_moe, moe_ffn
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, decode_attention
from repro.models.layers import (apply_mlp, apply_norm, apply_rope,
                                 init_attention, init_mlp, init_norm)


def zero_stats(cfg: ModelConfig) -> dict:
    E = cfg.moe.num_experts if cfg.moe else 1
    return {"aux_loss": jnp.float32(0), "load": jnp.zeros((E,), jnp.float32),
            "drops": jnp.float32(0)}


def layer_ctx(ctx: DistContext, moe_index: Optional[int]) -> DistContext:
    """The DistContext one MoE layer actually runs under.

    With a heterogeneous schedule vector (``ctx.layer_schedules``, adaptive
    MACT — docs/DESIGN.md §Adaptive) the layer at MoE position ``moe_index``
    gets its own (chunk bin, pipeline depth); otherwise the global schedule
    applies unchanged.  The returned ctx drops ``layer_schedules`` so the
    MoE layer below sees exactly the static knobs it always did.
    """
    if ctx.layer_schedules is None or moe_index is None:
        return ctx
    spec = ScheduleSpec(*ctx.layer_schedules[moe_index])
    return dataclasses.replace(ctx, moe_chunks=spec.chunks,
                               pipeline_chunks=spec.depth,
                               layer_schedules=None)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key: jax.Array, spec: LayerSpec, cfg: ModelConfig,
               cross_attention: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": init_norm(cfg.d_model, cfg.norm)}
    if spec.mixer == "attn":
        p["mixer"] = init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    qk_norm=spec.attn.qk_norm, dtype=dtype)
    else:
        p["mixer"] = ssm_mod.init_ssm(ks[0], cfg.d_model, spec.ssm, dtype)
    if cross_attention:
        p["norm_x"] = init_norm(cfg.d_model, cfg.norm)
        p["cross"] = init_attention(ks[3], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    dtype=dtype)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if spec.ffn == "dense":
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
    return p


# ---------------------------------------------------------------------------
# attention mixer (train/prefill and decode)
# ---------------------------------------------------------------------------

def _hconstrain(x: jax.Array, ctx: DistContext) -> jax.Array:
    """Pin (B, S, H, hd) tensors to head sharding — GSPMD cannot derive it
    through the (KH, G) reshape/repeat and otherwise replicates the score
    tensors (observed 34 GB/device in the dry-run).  Uneven H pads."""
    if ctx.heads_pspec is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.heads_pspec)


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
         positions: jax.Array, ctx: DistContext):
    from repro.models.attention import repeat_kv
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KH, hd)
    v = (x @ p["wv"]).reshape(B, S, KH, hd)
    if "q_norm" in p:
        q = apply_norm(p["q_norm"], q)
        k = apply_norm(p["k_norm"], k)
    if spec.attn.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if S > 1:  # train/prefill: repeat KV to H so every score dim shards
        k = repeat_kv(k, H)
        v = repeat_kv(v, H)
        q, k, v = _hconstrain(q, ctx), _hconstrain(k, ctx), _hconstrain(v, ctx)
        # named for the "selective" remat policy: saving these avoids
        # re-running the sequence-parallel all-gathers during recompute
        q = checkpoint_name(q, "qkv")
        k = checkpoint_name(k, "qkv")
        v = checkpoint_name(v, "qkv")
    return q, k, v


def attn_mixer(p: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
               positions: jax.Array, ctx: DistContext,
               causal: bool = True) -> jax.Array:
    q, k, v = _qkv(p, x, cfg, spec, positions, ctx)
    out = attention(q, k, v, spec.attn, causal=causal)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"]


def cache_len(spec: LayerSpec, seq_len: int) -> int:
    if spec.attn.kind in ("window", "chunked") and spec.attn.window:
        return min(spec.attn.window, seq_len)
    return seq_len


def attn_mixer_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                      cfg: ModelConfig, spec: LayerSpec, ctx: DistContext):
    """x: (B, 1, d).  cache: {"k","v"}: (B, Sc, KH, hd).  pos: scalar int."""
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg, spec, pos[None, None].astype(jnp.int32)
                   * jnp.ones((B, 1), jnp.int32), ctx)
    Sc = cache["k"].shape[1]
    if spec.attn.kind == "window" and spec.attn.window and Sc == spec.attn.window:
        write = pos % Sc
        length = jnp.minimum(pos + 1, Sc) * jnp.ones((B,), jnp.int32)
    elif spec.attn.kind == "chunked" and spec.attn.window and Sc == spec.attn.window:
        write = pos % Sc
        length = (pos % Sc + 1) * jnp.ones((B,), jnp.int32)   # chunk-local context
    else:
        write = pos
        length = (pos + 1) * jnp.ones((B,), jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write, axis=1)
    out = decode_attention(q, k_cache, v_cache, length, spec.attn)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# whole layer
# ---------------------------------------------------------------------------

def apply_layer(params: dict, x: jax.Array, spec: LayerSpec, cfg: ModelConfig,
                ctx: DistContext, positions: jax.Array, *,
                causal: bool = True, enc_out: Optional[jax.Array] = None):
    """Train/prefill.  Returns (x, stats)."""

    def layer_fn(x):
        h = apply_norm(params["norm1"], x, cfg.norm)
        if spec.mixer == "attn":
            h = attn_mixer(params["mixer"], h, cfg, spec, positions, ctx, causal)
        else:
            h = ssm_mod.apply_ssm(params["mixer"], h, spec.ssm)
        x = x + h
        if "cross" in params and enc_out is not None:
            h = apply_norm(params["norm_x"], x, cfg.norm)
            q, k, v = _cross_qkv(params["cross"], h, enc_out, cfg)
            o = attention(q, k, v, spec.attn, causal=False)
            x = x + o.reshape(*x.shape[:2], -1) @ params["cross"]["wo"]
        stats = zero_stats(cfg)
        if spec.ffn != "none":
            h = apply_norm(params["norm2"], x, cfg.norm)
            if spec.ffn == "dense":
                h = apply_mlp(params["ffn"], h)
            else:
                h, stats = moe_ffn(params["ffn"], h, cfg.moe, ctx)
            x = x + h
        return x, stats

    if cfg.remat_policy in ("full", "memfine"):
        layer_fn = jax.checkpoint(layer_fn)
    elif cfg.remat_policy == "selective":
        # keep the all-gathered qkv tensors resident: recompute skips the
        # sequence-parallel gathers (collective term down, memory term up)
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.save_only_these_names("qkv"))
    return layer_fn(x)


def _cross_qkv(p: dict, x: jax.Array, enc_out: jax.Array, cfg: ModelConfig):
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, Se, KH, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, KH, hd)
    return q, k, v


def apply_layer_decode(params: dict, x: jax.Array, cache, spec: LayerSpec,
                       cfg: ModelConfig, ctx: DistContext, pos: jax.Array):
    """Single-token decode.  cache: layer cache pytree.  Returns (x, cache)."""
    h = apply_norm(params["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        h, new_attn = attn_mixer_decode(params["mixer"], h, cache["attn"], pos,
                                        cfg, spec, ctx)
        cache = {**cache, "attn": new_attn}
    else:
        h, new_state = ssm_mod.decode_ssm(params["mixer"], h,
                                          ssm_mod.SSMState(**cache["ssm"]),
                                          spec.ssm)
        cache = {**cache, "ssm": new_state._asdict()}
    x = x + h
    if "cross" in params and "cross_k" in cache:
        h = apply_norm(params["norm_x"], x, cfg.norm)
        B = x.shape[0]
        H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = (h @ params["cross"]["wq"]).reshape(B, 1, H, hd)
        Se = cache["cross_k"].shape[1]
        o = decode_attention(q, cache["cross_k"], cache["cross_v"],
                             Se * jnp.ones((B,), jnp.int32), spec.attn)
        x = x + o.reshape(B, 1, -1) @ params["cross"]["wo"]
    if spec.ffn != "none":
        h = apply_norm(params["norm2"], x, cfg.norm)
        if spec.ffn == "dense":
            h = apply_mlp(params["ffn"], h)
        else:
            h, _ = moe_ffn(params["ffn"], h, cfg.moe, ctx)
        x = x + h
    return x, cache


def init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     seq_len: int, dtype, enc_out: Optional[jax.Array] = None,
                     cross_params: Optional[dict] = None) -> dict:
    """Decode cache for one layer (static shapes; window layers ring-bounded)."""
    cache: dict = {}
    if spec.mixer == "attn":
        Sc = cache_len(spec, seq_len)
        KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["attn"] = {"k": jnp.zeros((batch, Sc, KH, hd), dtype),
                         "v": jnp.zeros((batch, Sc, KH, hd), dtype)}
    else:
        cache["ssm"] = ssm_mod.init_state(batch, cfg.d_model, spec.ssm,
                                          dtype)._asdict()
    if cross_params is not None and enc_out is not None:
        KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        Se = enc_out.shape[1]
        cache["cross_k"] = (enc_out @ cross_params["wk"]).reshape(batch, Se, KH, hd)
        cache["cross_v"] = (enc_out @ cross_params["wv"]).reshape(batch, Se, KH, hd)
    return cache
