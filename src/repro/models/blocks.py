"""One transformer layer: mixer (attention | mamba) + FFN (dense | MoE | none).

Remat policy (docs/DESIGN.md §2):
  * "none"    — store everything (m_g copies in the memory model).
  * "full"    — jax.checkpoint around the whole layer = Megatron full
                recomputation (paper Method 1 when moe_chunks=1).
  * "memfine" — same layer checkpoint, but the MoE inside additionally
                chunk-recomputes (Eq. 7); selected via ctx.moe_chunks > 1
                with remat_chunks=True.  Nested checkpoints compose: during
                a layer's backward, only ONE chunk's dispatch buffers live.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import LayerSpec, ModelConfig
from repro.core.chunking import ScheduleSpec
from repro.core.moe import DistContext, init_moe, moe_ffn
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, decode_attention, extend_attention
from repro.models.layers import (apply_mlp, apply_norm, apply_rope,
                                 init_attention, init_mlp, init_norm)


def zero_stats(cfg: ModelConfig) -> dict:
    E = cfg.moe.num_experts if cfg.moe else 1
    return {"aux_loss": jnp.float32(0), "load": jnp.zeros((E,), jnp.float32),
            "drops": jnp.float32(0)}


def layer_ctx(ctx: DistContext, moe_index: Optional[int]) -> DistContext:
    """The DistContext one MoE layer actually runs under.

    With a heterogeneous schedule vector (``ctx.layer_schedules``, adaptive
    MACT — docs/DESIGN.md §Adaptive) the layer at MoE position ``moe_index``
    gets its own (chunk bin, pipeline depth), and with a placement vector
    (``ctx.placements``, docs/DESIGN.md §Placement) its own expert->peer
    map; otherwise the global knobs apply unchanged.  The returned ctx drops
    the per-layer vectors so the MoE layer below sees exactly the static
    knobs it always did.
    """
    if moe_index is None or (ctx.layer_schedules is None
                             and ctx.placements is None):
        return ctx
    changes: dict = {}
    if ctx.layer_schedules is not None:
        spec = ScheduleSpec(*ctx.layer_schedules[moe_index])
        changes.update(moe_chunks=spec.chunks, pipeline_chunks=spec.depth,
                       layer_schedules=None)
    if ctx.placements is not None:
        changes.update(placement=ctx.placements[moe_index], placements=None)
    return dataclasses.replace(ctx, **changes)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key: jax.Array, spec: LayerSpec, cfg: ModelConfig,
               cross_attention: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": init_norm(cfg.d_model, cfg.norm)}
    if spec.mixer == "attn":
        p["mixer"] = init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    qk_norm=spec.attn.qk_norm, dtype=dtype)
    else:
        p["mixer"] = ssm_mod.init_ssm(ks[0], cfg.d_model, spec.ssm, dtype)
    if cross_attention:
        p["norm_x"] = init_norm(cfg.d_model, cfg.norm)
        p["cross"] = init_attention(ks[3], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.resolved_head_dim,
                                    dtype=dtype)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if spec.ffn == "dense":
            p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
    return p


# ---------------------------------------------------------------------------
# attention mixer (train/prefill and decode)
# ---------------------------------------------------------------------------

def _hconstrain(x: jax.Array, ctx: DistContext) -> jax.Array:
    """Pin (B, S, H, hd) tensors to head sharding — GSPMD cannot derive it
    through the (KH, G) reshape/repeat and otherwise replicates the score
    tensors (observed 34 GB/device in the dry-run).  Uneven H pads."""
    if ctx.heads_pspec is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.heads_pspec)


def _qkv_base(p: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
              positions: jax.Array):
    """Projections + qk-norm + RoPE, KV still at KH heads (the cache layout)."""
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KH, hd)
    v = (x @ p["wv"]).reshape(B, S, KH, hd)
    if "q_norm" in p:
        q = apply_norm(p["q_norm"], q)
        k = apply_norm(p["k_norm"], k)
    if spec.attn.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _qkv(p: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
         positions: jax.Array, ctx: DistContext, return_raw: bool = False):
    from repro.models.attention import repeat_kv
    S = x.shape[1]
    H = cfg.num_heads
    q, k, v = _qkv_base(p, x, cfg, spec, positions)
    raw = (k, v)
    if S > 1:  # train/prefill: repeat KV to H so every score dim shards
        k = repeat_kv(k, H)
        v = repeat_kv(v, H)
        q, k, v = _hconstrain(q, ctx), _hconstrain(k, ctx), _hconstrain(v, ctx)
        # named for the "selective" remat policy: saving these avoids
        # re-running the sequence-parallel all-gathers during recompute
        q = checkpoint_name(q, "qkv")
        k = checkpoint_name(k, "qkv")
        v = checkpoint_name(v, "qkv")
    if return_raw:
        return q, k, v, raw
    return q, k, v


def attn_mixer(p: dict, x: jax.Array, cfg: ModelConfig, spec: LayerSpec,
               positions: jax.Array, ctx: DistContext,
               causal: bool = True, return_kv: bool = False):
    """Train/prefill attention.  ``return_kv`` additionally returns the
    pre-repeat (B, S, KH, hd) K/V — what single-pass prefill writes into the
    decode cache (docs/DESIGN.md §Serving)."""
    B, S = x.shape[:2]
    if return_kv:
        q, k, v, raw = _qkv(p, x, cfg, spec, positions, ctx, return_raw=True)
    else:
        q, k, v = _qkv(p, x, cfg, spec, positions, ctx)
    out = attention(q, k, v, spec.attn, causal=causal)
    y = out.reshape(B, S, -1) @ p["wo"]
    return (y, raw) if return_kv else y


def cache_len(spec: LayerSpec, seq_len: int) -> int:
    if spec.attn.kind in ("window", "chunked") and spec.attn.window:
        return min(spec.attn.window, seq_len)
    return seq_len


def attn_mixer_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                      cfg: ModelConfig, spec: LayerSpec, ctx: DistContext):
    """x: (B, 1, d).  cache: {"k","v"}: (B, Sc, KH, hd).  pos: scalar int."""
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg, spec, pos[None, None].astype(jnp.int32)
                   * jnp.ones((B, 1), jnp.int32), ctx)
    Sc = cache["k"].shape[1]
    if spec.attn.kind == "window" and spec.attn.window and Sc == spec.attn.window:
        write = pos % Sc
        length = jnp.minimum(pos + 1, Sc) * jnp.ones((B,), jnp.int32)
    elif spec.attn.kind == "chunked" and spec.attn.window and Sc == spec.attn.window:
        write = pos % Sc
        length = (pos % Sc + 1) * jnp.ones((B,), jnp.int32)   # chunk-local context
    else:
        write = pos
        length = (pos + 1) * jnp.ones((B,), jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write, axis=1)
    out = decode_attention(q, k_cache, v_cache, length, spec.attn)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# cache layout: single-pass prefill + chunked extension (docs/DESIGN.md §Serving)
# ---------------------------------------------------------------------------

def _is_ring(spec: LayerSpec, num_slots: int) -> bool:
    """The decode path rings exactly when the cache is window-sized."""
    return (spec.attn.kind in ("window", "chunked") and bool(spec.attn.window)
            and num_slots == spec.attn.window)


def slot_positions(spec: LayerSpec, num_slots: int, filled) -> jax.Array:
    """Token position held by each cache slot after ``filled`` writes
    (-1 = never written).  Linear caches hold position i at slot i; ring
    caches hold the newest position p < filled with p % num_slots == i."""
    i = jnp.arange(num_slots)
    if _is_ring(spec, num_slots):
        pos = i + ((filled - 1 - i) // num_slots) * num_slots
    else:
        pos = i
    return jnp.where(i < filled, jnp.maximum(pos, i), -1)


def build_attn_cache(k: jax.Array, v: jax.Array, spec: LayerSpec,
                     total_len: int, dtype) -> dict:
    """Lay a prompt's (B, S, KH, hd) K/V out as the decode cache the replay
    loop would have produced, bit-for-bit: linear caches get the prompt at
    slots 0..S-1, ring caches the last ``window`` tokens at slots p % W."""
    B, S = k.shape[:2]
    Sc = cache_len(spec, total_len)
    ring = _is_ring(spec, Sc)
    if S > Sc and not ring:
        raise ValueError(f"prompt length {S} exceeds the {Sc}-slot linear "
                         f"cache (cache_len={total_len})")

    def lay(t):
        t = t.astype(dtype)
        if ring and S >= Sc:
            return jnp.roll(t[:, S - Sc:], (S - Sc) % Sc, axis=1)
        buf = jnp.zeros((B, Sc) + t.shape[2:], dtype)
        return jax.lax.dynamic_update_slice_in_dim(buf, t, 0, axis=1)

    return {"k": lay(k), "v": lay(v)}


def write_attn_cache(cache: dict, k: jax.Array, v: jax.Array, pos0,
                     spec: LayerSpec) -> dict:
    """Write a C-token chunk starting at position ``pos0`` into the cache,
    ring or linear — the multi-token generalisation of the decode write."""
    Sc = cache["k"].shape[1]
    C = k.shape[1]
    if _is_ring(spec, Sc):
        if C >= Sc:           # only the last Sc tokens survive a full wrap
            k, v, pos0, C = k[:, C - Sc:], v[:, C - Sc:], pos0 + C - Sc, Sc
        idx = (pos0 + jnp.arange(C)) % Sc
        return {"k": cache["k"].at[:, idx].set(k.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))}
    return {"k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos0, axis=1)}


def gather_paged_tokens(pool: jax.Array, table: jax.Array, token_axis: int,
                        length: int) -> jax.Array:
    """Assemble a dense token cache from page-pool rows.

    ``pool``: (P, *page_shape) where ``page_shape[token_axis]`` is the page
    size; ``table``: (..., n_blocks) int32 page ids (page 0 is the shared
    zero page, so never-filled blocks read as the zero-initialised cache —
    docs/DESIGN.md §Paging).  Returns (..., *dense_shape) with the token
    axis merged to ``n_blocks * page`` and sliced to ``length`` (ragged
    layouts pad the last page).
    """
    lead = table.ndim - 1
    x = pool[table]                       # (..., n_blocks, *page_shape)
    a = lead + token_axis
    x = jnp.moveaxis(x, lead, a)          # block axis next to its page axis
    sh = x.shape
    x = x.reshape(sh[:a] + (sh[a] * sh[a + 1],) + sh[a + 2:])
    return jax.lax.slice_in_dim(x, 0, length, axis=a)


def scatter_paged_tokens(pool: jax.Array, table: jax.Array, dense: jax.Array,
                         token_axis: int, page: int) -> jax.Array:
    """Inverse of ``gather_paged_tokens``: split a dense token cache into
    page rows and scatter them at ``table``'s ids.  Ragged token axes are
    zero-padded into the last page's tail (never gathered back).  Duplicate
    ids (CoW-shared pages gathered by several slots) carry bit-identical
    rows, so scatter order cannot matter; scratch-page ids (1) absorb
    writes from unallocated blocks and inactive slots."""
    lead = table.ndim - 1
    a = lead + token_axis
    nb = table.shape[-1]
    pad = nb * page - dense.shape[a]
    if pad:
        width = [(0, 0)] * dense.ndim
        width[a] = (0, pad)
        dense = jnp.pad(dense, width)
    sh = dense.shape
    dense = dense.reshape(sh[:a] + (nb, page) + sh[a + 1:])
    dense = jnp.moveaxis(dense, a, lead)  # (..., n_blocks, *page_shape)
    return pool.at[table].set(dense)


def _extend_mask(spec: LayerSpec, key_pos: jax.Array,
                 q_pos: jax.Array) -> jax.Array:
    """(C, Skv) visibility: causal over key *positions* (-1 = empty slot),
    window-banded / chunk-local per the attention kind."""
    m = (key_pos[None, :] >= 0) & (key_pos[None, :] <= q_pos[:, None])
    if spec.attn.kind == "window" and spec.attn.window:
        m &= key_pos[None, :] > q_pos[:, None] - spec.attn.window
    elif spec.attn.kind == "chunked" and spec.attn.window:
        m &= (key_pos[None, :] // spec.attn.window
              == q_pos[:, None] // spec.attn.window)
    return m


def attn_mixer_extend(p: dict, x: jax.Array, cache: dict, pos0,
                      cfg: ModelConfig, spec: LayerSpec, ctx: DistContext):
    """x: (B, C, d) chunk at positions pos0..pos0+C-1.  Attends over the
    cache-before-this-chunk plus the chunk's own K/V (so ring overwrites
    within the chunk cannot clobber still-visible keys), then writes the
    chunk into the cache.  Returns (y, new {"k","v"})."""
    B, C, _ = x.shape
    positions = pos0 + jnp.arange(C)
    q, k, v = _qkv_base(p, x, cfg, spec,
                        jnp.broadcast_to(positions, (B, C)))
    Sc = cache["k"].shape[1]
    key_pos = jnp.concatenate([slot_positions(spec, Sc, pos0), positions])
    mask = _extend_mask(spec, key_pos, positions)
    k_cat = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
    v_cat = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
    out = extend_attention(q, k_cat, v_cat, mask)
    y = out.reshape(B, C, -1) @ p["wo"]
    return y, write_attn_cache(cache, k, v, pos0, spec)


# ---------------------------------------------------------------------------
# whole layer
# ---------------------------------------------------------------------------

def apply_layer(params: dict, x: jax.Array, spec: LayerSpec, cfg: ModelConfig,
                ctx: DistContext, positions: jax.Array, *,
                causal: bool = True, enc_out: Optional[jax.Array] = None,
                cache_len: Optional[int] = None, cache_dtype=None):
    """Train/prefill.  Returns (x, stats), or (x, stats, cache) when
    ``cache_len`` is given — the single-pass-prefill path (docs/DESIGN.md
    §Serving): the layer's decode cache is built from the same forward pass
    (K/V as computed, ring-laid for window/chunked layers; SSD final state +
    conv tail for mamba; precomputed cross K/V for enc-dec).  Prefill is
    never differentiated, so the cache path skips the remat wrapper."""
    build_cache = cache_len is not None
    if cache_dtype is None:
        cache_dtype = x.dtype

    def layer_fn(x):
        cache: dict = {}
        h = apply_norm(params["norm1"], x, cfg.norm)
        if spec.mixer == "attn":
            if build_cache:
                h, (k_raw, v_raw) = attn_mixer(params["mixer"], h, cfg, spec,
                                               positions, ctx, causal,
                                               return_kv=True)
                cache["attn"] = build_attn_cache(k_raw, v_raw, spec,
                                                 cache_len, cache_dtype)
            else:
                h = attn_mixer(params["mixer"], h, cfg, spec, positions, ctx,
                               causal)
        else:
            if build_cache:
                h, state = ssm_mod.apply_ssm(params["mixer"], h, spec.ssm,
                                             return_state=True)
                cache["ssm"] = jax.tree.map(lambda a: a.astype(cache_dtype),
                                            state._asdict())
            else:
                h = ssm_mod.apply_ssm(params["mixer"], h, spec.ssm)
        x = x + h
        if "cross" in params and enc_out is not None:
            h = apply_norm(params["norm_x"], x, cfg.norm)
            q, k, v = _cross_qkv(params["cross"], h, enc_out, cfg)
            o = attention(q, k, v, spec.attn, causal=False)
            x = x + o.reshape(*x.shape[:2], -1) @ params["cross"]["wo"]
            if build_cache:
                cache["cross_k"] = k.astype(cache_dtype)
                cache["cross_v"] = v.astype(cache_dtype)
        stats = zero_stats(cfg)
        if spec.ffn != "none":
            h = apply_norm(params["norm2"], x, cfg.norm)
            if spec.ffn == "dense":
                h = apply_mlp(params["ffn"], h)
            else:
                h, stats = moe_ffn(params["ffn"], h, cfg.moe, ctx)
            x = x + h
        if build_cache:
            return x, stats, cache
        return x, stats

    if build_cache:
        return layer_fn(x)
    if cfg.remat_policy in ("full", "memfine"):
        layer_fn = jax.checkpoint(layer_fn)
    elif cfg.remat_policy == "selective":
        # keep the all-gathered qkv tensors resident: recompute skips the
        # sequence-parallel gathers (collective term down, memory term up)
        layer_fn = jax.checkpoint(
            layer_fn,
            policy=jax.checkpoint_policies.save_only_these_names("qkv"))
    return layer_fn(x)


def _cross_qkv(p: dict, x: jax.Array, enc_out: jax.Array, cfg: ModelConfig):
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"]).reshape(B, Se, KH, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, KH, hd)
    return q, k, v


def apply_layer_decode(params: dict, x: jax.Array, cache, spec: LayerSpec,
                       cfg: ModelConfig, ctx: DistContext, pos: jax.Array, *,
                       return_load: bool = False):
    """Single-token decode.  cache: layer cache pytree.  Returns (x, cache).

    ``return_load=True`` additionally returns this layer's (E,) routed-load
    histogram (zeros for dense/none FFNs) — the per-step telemetry the
    expert-aware serving path consumes (docs/DESIGN.md §Residency).  The
    default path is unchanged."""
    h = apply_norm(params["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        h, new_attn = attn_mixer_decode(params["mixer"], h, cache["attn"], pos,
                                        cfg, spec, ctx)
        cache = {**cache, "attn": new_attn}
    else:
        h, new_state = ssm_mod.decode_ssm(params["mixer"], h,
                                          ssm_mod.SSMState(**cache["ssm"]),
                                          spec.ssm)
        cache = {**cache, "ssm": new_state._asdict()}
    x = x + h
    if "cross" in params and "cross_k" in cache:
        h = apply_norm(params["norm_x"], x, cfg.norm)
        B = x.shape[0]
        H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = (h @ params["cross"]["wq"]).reshape(B, 1, H, hd)
        Se = cache["cross_k"].shape[1]
        o = decode_attention(q, cache["cross_k"], cache["cross_v"],
                             Se * jnp.ones((B,), jnp.int32), spec.attn)
        x = x + o.reshape(B, 1, -1) @ params["cross"]["wo"]
    load = None
    if spec.ffn != "none":
        h = apply_norm(params["norm2"], x, cfg.norm)
        if spec.ffn == "dense":
            h = apply_mlp(params["ffn"], h)
        else:
            h, st = moe_ffn(params["ffn"], h, cfg.moe, ctx)
            load = st["load"].astype(jnp.float32)
        x = x + h
    if return_load:
        E = cfg.moe.num_experts if cfg.moe is not None else 1
        if load is None:
            load = jnp.zeros((E,), jnp.float32)
        return x, cache, load
    return x, cache


def apply_layer_extend(params: dict, x: jax.Array, cache, spec: LayerSpec,
                       cfg: ModelConfig, ctx: DistContext, pos0, *,
                       return_load: bool = False):
    """C-token cache extension (serving chunked prefill, docs/DESIGN.md
    §Serving).  x: (B, C, d) at positions pos0..pos0+C-1.  Returns
    (x, cache) — the multi-token generalisation of ``apply_layer_decode``,
    with the same optional (E,) load output under ``return_load``."""
    B, C, _ = x.shape
    h = apply_norm(params["norm1"], x, cfg.norm)
    if spec.mixer == "attn":
        h, new_attn = attn_mixer_extend(params["mixer"], h, cache["attn"],
                                        pos0, cfg, spec, ctx)
        cache = {**cache, "attn": new_attn}
    else:
        h, new_state = ssm_mod.apply_ssm(
            params["mixer"], h, spec.ssm, return_state=True,
            initial_state=ssm_mod.SSMState(**cache["ssm"]))
        cache = {**cache,
                 "ssm": jax.tree.map(lambda a, o: a.astype(o.dtype),
                                     new_state._asdict(), cache["ssm"])}
    x = x + h
    if "cross" in params and "cross_k" in cache:
        h = apply_norm(params["norm_x"], x, cfg.norm)
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        q = (h @ params["cross"]["wq"]).reshape(B, C, H, hd)
        Se = cache["cross_k"].shape[1]
        mask = jnp.ones((C, Se), bool)          # cross attention: non-causal
        o = extend_attention(q, cache["cross_k"], cache["cross_v"], mask)
        x = x + o.reshape(B, C, -1) @ params["cross"]["wo"]
    load = None
    if spec.ffn != "none":
        h = apply_norm(params["norm2"], x, cfg.norm)
        if spec.ffn == "dense":
            h = apply_mlp(params["ffn"], h)
        else:
            h, st = moe_ffn(params["ffn"], h, cfg.moe, ctx)
            load = st["load"].astype(jnp.float32)
        x = x + h
    if return_load:
        E = cfg.moe.num_experts if cfg.moe is not None else 1
        if load is None:
            load = jnp.zeros((E,), jnp.float32)
        return x, cache, load
    return x, cache


def init_layer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     seq_len: int, dtype, enc_out: Optional[jax.Array] = None,
                     cross_params: Optional[dict] = None) -> dict:
    """Decode cache for one layer (static shapes; window layers ring-bounded)."""
    cache: dict = {}
    if spec.mixer == "attn":
        Sc = cache_len(spec, seq_len)
        KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["attn"] = {"k": jnp.zeros((batch, Sc, KH, hd), dtype),
                         "v": jnp.zeros((batch, Sc, KH, hd), dtype)}
    else:
        cache["ssm"] = ssm_mod.init_state(batch, cfg.d_model, spec.ssm,
                                          dtype)._asdict()
    if cross_params is not None and enc_out is not None:
        KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        Se = enc_out.shape[1]
        cache["cross_k"] = (enc_out @ cross_params["wk"]).reshape(batch, Se, KH, hd)
        cache["cross_v"] = (enc_out @ cross_params["wv"]).reshape(batch, Se, KH, hd)
    return cache
