"""Mamba-2 (SSD, state-space duality) mixer — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute *within* length-``chunk`` blocks plus a linear inter-chunk state
recurrence (lax.scan), i.e. sub-quadratic overall — which is what makes
mamba2/jamba eligible for the long_500k shape.  Decode is the constant-size
recurrent step on a (H, P, N) state plus a width-(w-1) conv tail.

Single B/C group (G=1), scalar A per head, following the 130m reference.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMSpec
from repro.models.layers import apply_norm, init_linear, init_norm


class SSMState(NamedTuple):
    ssm: jax.Array       # (B, H, P, N) recurrent state
    conv: jax.Array      # (B, w-1, d_conv) conv tail


def dims(d_model: int, spec: SSMSpec):
    d_in = spec.expand * d_model
    heads = d_in // spec.head_dim
    d_conv = d_in + 2 * spec.state_dim
    return d_in, heads, d_conv


def init_ssm(key: jax.Array, d_model: int, spec: SSMSpec, dtype=jnp.float32) -> dict:
    d_in, heads, d_conv = dims(d_model, spec)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], d_model, 2 * d_in + 2 * spec.state_dim + heads, dtype),
        "conv_w": jax.random.normal(ks[1], (spec.conv_width, d_conv), dtype) * 0.1,
        "conv_b": jnp.zeros((d_conv,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads).astype(jnp.float32)),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": init_norm(d_in),
        "out_proj": init_linear(ks[2], d_in, d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, T, C), w: (K, C).  ``tail``: (B, K-1, C)
    prepended history (decode); zeros for training."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., L, H) -> (..., H, L, L) with s[i, j] = sum_{j<k<=i} dA_k
    (lower-triangular; -inf above the diagonal)."""
    L = dA.shape[-2]
    cs = jnp.cumsum(dA, axis=-2)                                  # (..., L, H)
    csh = jnp.moveaxis(cs, -1, -2)                                # (..., H, L)
    s = csh[..., :, None] - csh[..., None, :]                     # (..., H, L, L)
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int, initial_state: jax.Array | None = None):
    """Chunked SSD.  x: (b, T, H, P); dt: (b, T, H); A: (H,) negative;
    B, C: (b, T, N).  Returns (y: (b, T, H, P), final_state: (b, H, P, N))."""
    b, T, H, Pd = x.shape
    N = B.shape[-1]
    T0 = T
    if T % chunk:                                                 # pad: dt=0 -> no-op steps
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        T = T + pad
    nc, cl = T // chunk, chunk
    xd = x * dt[..., None]                                        # dt-weighted input
    dA = dt * A                                                   # (b, T, H)

    xc = xd.reshape(b, nc, cl, H, Pd)
    dAc = dA.reshape(b, nc, cl, H)
    Bc = B.reshape(b, nc, cl, N)
    Cc = C.reshape(b, nc, cl, N)

    dA_cum = jnp.cumsum(dAc, axis=2)                              # (b, nc, cl, H)
    L = jnp.exp(_segsum(dAc))                                     # (b, nc, H, cl, cl)
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", Cc, Bc, L, xc)

    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)         # (b, nc, cl, H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_states, xc)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                    # (b, nc, H)

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, H, Pd, N), x.dtype))

    def scan_fn(s_prev, inp):
        st, dec = inp                                             # (b,H,P,N), (b,H)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev

    states_t = jnp.moveaxis(states, 1, 0)                         # (nc, b, H, P, N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)                     # (nc, b, H)
    final, prev_states = jax.lax.scan(scan_fn, s0, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                 # (b, nc, H, P, N)

    decay_out = jnp.exp(dA_cum)                                   # (b, nc, cl, H)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, decay_out)
    y = (y_diag + y_off).reshape(b, T, H, Pd)
    return y[:, :T0], final


def apply_ssm(params: dict, x: jax.Array, spec: SSMSpec,
              return_state: bool = False,
              initial_state: SSMState | None = None):
    """Training/prefill.  x: (B, T, d_model) -> (B, T, d_model).

    ``initial_state`` continues from a prior prefix (serving chunked
    prefill, docs/DESIGN.md §Serving): the conv tail is the prefix's pre-conv
    history and the SSD scan starts from the prefix's recurrent state.
    """
    d_model = x.shape[-1]
    d_in, heads, d_conv = dims(d_model, spec)
    N = spec.state_dim
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_conv], axis=-1)
    hist = xBC if initial_state is None else jnp.concatenate(
        [initial_state.conv.astype(xBC.dtype), xBC], axis=1)
    conv_tail = hist[:, max(0, hist.shape[1] - (spec.conv_width - 1)):, :]  # pre-conv history
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"],
                       tail=None if initial_state is None else
                       initial_state.conv.astype(xBC.dtype))
    xs, B, C = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(params["A_log"]).astype(x.dtype)
    xh = xs.reshape(*xs.shape[:-1], heads, spec.head_dim)
    y, final = ssd_scan(xh, dt, A, B, C, min(spec.chunk, x.shape[1]),
                        initial_state=None if initial_state is None else
                        initial_state.ssm.astype(x.dtype))
    y = y + params["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(*x.shape[:-1], d_in)
    y = apply_norm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if return_state:
        pad = spec.conv_width - 1 - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, SSMState(ssm=final, conv=conv_tail)
    return out


def init_state(batch: int, d_model: int, spec: SSMSpec, dtype) -> SSMState:
    d_in, heads, d_conv = dims(d_model, spec)
    return SSMState(
        ssm=jnp.zeros((batch, heads, spec.head_dim, spec.state_dim), dtype),
        conv=jnp.zeros((batch, spec.conv_width - 1, d_conv), dtype),
    )


def decode_ssm(params: dict, x: jax.Array, state: SSMState, spec: SSMSpec):
    """Single-token decode.  x: (B, 1, d_model) -> (y, new_state)."""
    d_model = x.shape[-1]
    d_in, heads, d_conv = dims(d_model, spec)
    N = spec.state_dim
    zxbcdt = x @ params["in_proj"]                                # (B, 1, ...)
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + d_conv], axis=-1)
    new_conv = jnp.concatenate([state.conv[:, 1:], xBC], axis=1) if \
        spec.conv_width > 1 else state.conv
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"], tail=state.conv)
    xs, B, C = jnp.split(xBC, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"]).astype(x.dtype)
    A = -jnp.exp(params["A_log"]).astype(x.dtype)
    xh = xs.reshape(x.shape[0], heads, spec.head_dim)             # (B, H, P)
    dt1 = dt[:, 0]                                                # (B, H)
    dec = jnp.exp(dt1 * A)                                        # (B, H)
    upd = jnp.einsum("bn,bhp->bhpn", B[:, 0], xh * dt1[..., None])
    s_new = state.ssm * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0], s_new)
    y = y + params["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(x.shape[0], 1, d_in)
    y = apply_norm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"], SSMState(ssm=s_new, conv=new_conv)
