"""AdamW with global-norm gradient clipping, from scratch (no optax here)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return AdamWState(step=jnp.int32(0), mu=zeros(params), nu=zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm}
