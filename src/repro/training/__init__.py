from repro.training.step import TrainState, init_train_state, make_train_step
from repro.training.trainer import Trainer
