"""Training loop with the MACT dynamic chunk controller in the driver seat.

Each step:
  1. MACT chooses the FCDA schedule from the previous step's router load
     (s''), via the theoretical memory model (Eq. 8-9, extended with the
     pipeline's extra live chunk) — cold-starting from the worst case
     `s' -> e*s*k`.  Global mode picks one (chunk bin, pipeline depth);
     adaptive mode (``adaptive_mact=True``, docs/DESIGN.md §Adaptive)
     resolves a *per-layer* ScheduleSpec vector from the telemetry EMA of
     per-layer expert loads, re-planned every ``replan_interval`` steps with
     load-margin hysteresis.
  2. The step function compiled for that schedule key runs.  Compiled
     variants live in a bounded LRU cache keyed by the schedule — the
     global (bin, depth) pair, or the full per-layer vector (uniform
     vectors collapse to the global key, so the adaptive path reuses the
     static compilations bit-for-bit).
  3. Router loads feed back to MACT/telemetry; metrics/chunk trace are
     recorded (benchmarks/fig5 reads the trace).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import HardwareProfile, ModelConfig, TPU_V5E
from repro.core.chunking import ScheduleSpec
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.core.telemetry import LoadTelemetry
from repro.data.pipeline import SyntheticLMData
from repro.models.transformer import num_moe_layers
from repro.training.step import TrainState, init_train_state, make_train_step
from repro import checkpointing


@dataclass
class Trainer:
    cfg: ModelConfig
    ctx: DistContext
    seq_len: int
    global_batch: int
    lr: float = 3e-4
    seed: int = 0
    hw: HardwareProfile = TPU_V5E
    par: Optional[Parallelism] = None
    mact_bins: tuple = (1, 2, 4, 8)
    use_mact: bool = True
    max_pipeline_depth: int = 2          # MACT may pick depth in [1, this]
    mact_ep_view: Optional[int] = None   # group experts per hypothetical device
    static_override: Optional[float] = None
    adaptive_mact: bool = False          # per-layer schedules from telemetry
    replan_interval: int = 1             # steps between adaptive re-plans
    mact_hysteresis: float = 0.1         # load-margin band for schedule moves
    mact_headroom: float = 0.2           # plan for (1+this)*EMA: covers the
                                         # drift a plan must survive between
                                         # re-plans (EMA lag + replan_interval)
    telemetry_decay: float = 0.6         # per-layer load EMA retention
    max_compiled_steps: int = 8          # LRU bound on cached compiled steps
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    log: list = field(default_factory=list)
    chunk_trace: list = field(default_factory=list)
    pipeline_trace: list = field(default_factory=list)
    schedule_trace: list = field(default_factory=list)  # adaptive: full vectors

    def __post_init__(self):
        if self.par is None:
            ep = data = 1
            if self.ctx.mesh is not None:
                shape = dict(zip(self.ctx.mesh.axis_names,
                                 self.ctx.mesh.devices.shape))
                if self.cfg.moe is not None:
                    ep = shape.get(self.ctx.ep_axis, 1)
                data = shape.get("data", 1) * shape.get("pod", 1)
            self.par = Parallelism(e=max(ep, 1),
                                   b=max(1, self.global_batch // data))
        self.mact = MACTController(
            self.cfg, self.par, self.hw, self.seq_len, bins=self.mact_bins,
            static_override=self.static_override)
        self.data = SyntheticLMData(self.cfg, self.seq_len, self.global_batch,
                                    self.seed)
        self._steps: OrderedDict[tuple, object] = OrderedDict()
        self._last_load: Optional[np.ndarray] = None
        self._n_moe = num_moe_layers(self.cfg)
        self.telemetry = LoadTelemetry(
            self._n_moe, self.cfg.moe.num_experts if self.cfg.moe else 1,
            decay=self.telemetry_decay)
        self._layer_schedules: Optional[tuple] = None
        self._plan_age = 0
        self.compile_count = 0
        self.evicted_recompile_count = 0
        self._evicted_keys: set = set()

    # -- bounded compiled-step cache -------------------------------------------
    # Keyed by the schedule: a global (chunk bin, pipeline depth) pair of
    # ints, or the full per-layer ScheduleSpec vector (adaptive MACT).  Every
    # vector component comes from MACTController.schedule_space, so the key
    # space is bucketed and finite; the LRU cap bounds resident compilations
    # regardless (docs/DESIGN.md §Adaptive).
    def _step_for(self, chunks: int, pipeline: int = 1):
        return self._compiled((chunks, pipeline))

    def _compiled(self, key: tuple):
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        if key and isinstance(key[0], tuple):        # per-layer vector
            ctx = dataclasses.replace(
                self.ctx, layer_schedules=tuple(ScheduleSpec(*s) for s in key))
        else:
            # clear any caller-supplied vector: the global key IS the schedule
            ctx = dataclasses.replace(self.ctx, moe_chunks=key[0],
                                      pipeline_chunks=key[1],
                                      layer_schedules=None)
        fn = jax.jit(make_train_step(self.cfg, ctx, lr=self.lr))
        self._steps[key] = fn
        self.compile_count += 1
        if key in self._evicted_keys:
            # the schedule working set exceeds the cache: every round trip
            # re-traces the step graph — raise max_compiled_steps (or the
            # hysteresis) if this fires often
            self.evicted_recompile_count += 1
            warnings.warn(
                f"recompiling previously-evicted schedule key {key}; "
                f"{self.evicted_recompile_count} evict-recompiles so far "
                f"(max_compiled_steps={self.max_compiled_steps})")
        while len(self._steps) > self.max_compiled_steps:
            evicted, _ = self._steps.popitem(last=False)
            self._evicted_keys.add(evicted)
        return fn

    def _plan_params(self) -> tuple:
        """(ep_view, max_depth) both planning modes share."""
        ep_view = self.mact_ep_view or max(self.par.e, 1)
        # local path has no all-to-all to overlap: plan sequential-only so
        # the bin is not sized for a depth that will never run
        max_depth = self.max_pipeline_depth if self.ctx.mesh is not None else 1
        return ep_view, max_depth

    def choose_schedule(self) -> tuple:
        """(chunks, pipeline depth) for the next step — MACT-selected.

        Note the feedback scale: the global path plans from ``stats["load"]``
        summed over every MoE layer, so its s'' overestimates the per-layer
        received-token count by up to L_moe — conservative on memory (more
        chunks than strictly needed), and the historical behavior fig5/
        table4 track.  The adaptive path (``adaptive_mact=True``) plans from
        the per-layer telemetry rows, which is the memory model's native
        granularity.
        """
        if not self.use_mact or self.cfg.moe is None:
            return self.ctx.moe_chunks, self.ctx.pipeline_chunks
        ep_view, max_depth = self._plan_params()
        return self.mact.choose_schedule(self._last_load, ep_size=ep_view,
                                         max_depth=max_depth)

    def choose_chunks(self) -> int:
        return self.choose_schedule()[0]

    def choose_layer_schedules(self) -> tuple:
        """Per-layer ScheduleSpec vector for the next step (adaptive MACT).

        Re-plans from the telemetry EMA every ``replan_interval`` steps (and
        at cold start, from the worst case); between re-plans the vector in
        force is reused, so the compiled step does not even change identity.
        """
        if self._layer_schedules is None or self._plan_age >= self.replan_interval:
            ep_view, max_depth = self._plan_params()
            self._layer_schedules = self.mact.choose_layer_schedules(
                self.telemetry.loads, self._n_moe, ep_size=ep_view,
                max_depth=max_depth, current=self._layer_schedules,
                hysteresis=self.mact_hysteresis,
                headroom=self.mact_headroom)
            self._plan_age = 0
        self._plan_age += 1
        return self._layer_schedules

    @staticmethod
    def _vector_key(vec: tuple) -> tuple:
        vec = tuple(ScheduleSpec(*s) for s in vec)
        if len(set(vec)) == 1:           # uniform: collapse to the global
            return (vec[0].chunks, vec[0].depth)   # path (scan + reuse)
        return vec

    def _next_schedule_key(self) -> tuple:
        """The compiled-step cache key for the next step."""
        if (self.adaptive_mact and self.use_mact and self.cfg.moe is not None
                and self._n_moe > 0):
            return self._vector_key(self.choose_layer_schedules())
        if self.ctx.layer_schedules and not self.use_mact:
            # hand-picked per-layer schedule, no controller: honor it
            return self._vector_key(self.ctx.layer_schedules)
        return tuple(self.choose_schedule())

    # -- main loop ---------------------------------------------------------------
    def fit(self, steps: int, state: Optional[TrainState] = None,
            verbose: bool = False) -> TrainState:
        if state is None:
            state = init_train_state(jax.random.PRNGKey(self.seed), self.cfg)
        for i in range(steps):
            key = self._next_schedule_key()
            if key and isinstance(key[0], tuple):      # per-layer vector
                chunks = max(s[0] for s in key)        # memory-binding layer
                pipeline = max(s[1] for s in key)
            else:
                chunks, pipeline = key
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(int(state.step)).items()}
            t0 = time.perf_counter()
            state, metrics = self._compiled(key)(state, batch)
            loss = float(metrics["loss"])          # sync point
            dt = time.perf_counter() - t0
            load = np.asarray(metrics["load"])
            self._last_load = load
            if (self.adaptive_mact and self._n_moe
                    and "load_per_layer" in metrics):
                self.telemetry.update(np.asarray(metrics["load_per_layer"]))
            tgs = self.global_batch * self.seq_len / max(dt, 1e-9)
            rec = {"step": int(state.step), "loss": loss,
                   "ce": float(metrics["ce"]), "aux": float(metrics["aux"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "chunks": chunks, "pipeline": pipeline, "time_s": dt,
                   "tgs": tgs, "max_load": float(load.max()),
                   "drops": float(metrics["drops"])}
            self.log.append(rec)
            self.chunk_trace.append(chunks)
            self.pipeline_trace.append(pipeline)
            if self.adaptive_mact and self._layer_schedules is not None:
                self.schedule_trace.append(self._layer_schedules)
            if verbose:
                print(f"step {rec['step']:4d} loss {rec['loss']:.4f} "
                      f"c={chunks} tgs={tgs:,.0f}")
            if (self.checkpoint_dir and self.checkpoint_every
                    and int(state.step) % self.checkpoint_every == 0):
                checkpointing.save(self.checkpoint_dir, int(state.step), state)
        return state
