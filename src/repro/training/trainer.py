"""Training loop with the MACT dynamic chunk controller in the driver seat.

Each step:
  1. MACT chooses the FCDA schedule from the previous step's router load
     (s''), via the theoretical memory model (Eq. 8-9, extended with the
     pipeline's extra live chunk) — cold-starting from the worst case
     `s' -> e*s*k`.  Global mode picks one (chunk bin, pipeline depth);
     adaptive mode (``adaptive_mact=True``, docs/DESIGN.md §Adaptive)
     resolves a *per-layer* ScheduleSpec vector from the telemetry EMA of
     per-layer expert loads, re-planned every ``replan_interval`` steps with
     load-margin hysteresis.
  2. The step function compiled for that schedule key runs.  Compiled
     variants live in a bounded LRU cache keyed by the schedule — the
     global (bin, depth) pair, or the full per-layer vector (uniform
     vectors collapse to the global key, so the adaptive path reuses the
     static compilations bit-for-bit).
  3. Router loads feed back to MACT/telemetry; metrics/chunk trace are
     recorded (benchmarks/fig5 reads the trace).

Resilience (docs/DESIGN.md §Resilience): compiled-step execution runs under
the ``OOMGuard`` degradation ladder — an out-of-memory failure (real
RESOURCE_EXHAUSTED or injected) rolls back to the pre-step state and
retries strictly more conservative schedules (deeper chunking -> depth 1 ->
full recompute) with bounded retries, then audits the memory model
(modeled vs HLO-derived bytes via launch/hlo_analysis.py) and widens
``mact_headroom`` when the model under-predicted.  ``resume=True`` makes
``fit`` self-healing: it restores the newest *valid* checkpoint (corrupt or
torn saves are skipped by the manifest checksum) along with the warm
telemetry EMA and MACT hysteresis state, and trains on to the target step —
bit-identical to a run that never died.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import HardwareProfile, ModelConfig, TPU_V5E
from repro.core.chunking import ScheduleSpec
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.core import placement as plc
from repro.core.placement import PlacementSpec
from repro.core.telemetry import LoadTelemetry
from repro.data.pipeline import SyntheticLMData
from repro.models.transformer import num_moe_layers
from repro.runtime.faults import FaultInjector
from repro.runtime.guard import FULL_REMAT, DegradationLadder, OOMGuard
from repro.training.step import TrainState, init_train_state, make_train_step
from repro import checkpointing


@dataclass
class Trainer:
    cfg: ModelConfig
    ctx: DistContext
    seq_len: int
    global_batch: int
    lr: float = 3e-4
    seed: int = 0
    hw: HardwareProfile = TPU_V5E
    par: Optional[Parallelism] = None
    mact_bins: tuple = (1, 2, 4, 8)
    use_mact: bool = True
    max_pipeline_depth: int = 2          # MACT may pick depth in [1, this]
    mact_ep_view: Optional[int] = None   # group experts per hypothetical device
    static_override: Optional[float] = None
    adaptive_mact: bool = False          # per-layer schedules from telemetry
    replan_interval: int = 1             # steps between adaptive re-plans
    mact_hysteresis: float = 0.1         # load-margin band for schedule moves
    mact_headroom: float = 0.2           # plan for (1+this)*EMA: covers the
                                         # drift a plan must survive between
                                         # re-plans (EMA lag + replan_interval)
    telemetry_decay: float = 0.6         # per-layer load EMA retention
    use_placement: bool = False          # telemetry-driven expert placement:
                                         # re-home/replicate experts at replan
                                         # boundaries (docs/DESIGN.md
                                         # §Placement)
    placement_replicas: int = 0          # extra hot-expert weight slots per
                                         # EP peer (0 = pure permutation)
    placement_hysteresis: float = 0.1    # min fractional bottleneck gain
                                         # before a layer's placement moves
    max_compiled_steps: int = 8          # LRU bound on cached compiled steps
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    resume: bool = False                 # fit() restores the newest valid
                                         # checkpoint and treats `steps` as
                                         # the TARGET step count
    injector: Optional[FaultInjector] = None   # chaos hooks (runtime/faults)
    max_oom_retries: int = 4             # ladder bound per step
    headroom_widen: float = 1.5          # audit: multiply mact_headroom by
                                         # this when the model under-predicts
    log: list = field(default_factory=list)
    chunk_trace: list = field(default_factory=list)
    pipeline_trace: list = field(default_factory=list)
    schedule_trace: list = field(default_factory=list)  # adaptive: full vectors
    placement_trace: list = field(default_factory=list)  # per-replan records:
                                         # imbalance, slots migrated, bytes

    def __post_init__(self):
        if self.par is None:
            ep = data = 1
            if self.ctx.mesh is not None:
                shape = dict(zip(self.ctx.mesh.axis_names,
                                 self.ctx.mesh.devices.shape))
                if self.cfg.moe is not None:
                    ep = shape.get(self.ctx.ep_axis, 1)
                data = shape.get("data", 1) * shape.get("pod", 1)
            self.par = Parallelism(e=max(ep, 1),
                                   b=max(1, self.global_batch // data))
        self.mact = MACTController(
            self.cfg, self.par, self.hw, self.seq_len, bins=self.mact_bins,
            static_override=self.static_override, fused=self.ctx.moe_fused,
            replica_slots=(self.placement_replicas if self.use_placement
                           else 0))
        self.data = SyntheticLMData(self.cfg, self.seq_len, self.global_batch,
                                    self.seed)
        self._steps: OrderedDict[tuple, object] = OrderedDict()
        self._last_load: Optional[np.ndarray] = None
        self._n_moe = num_moe_layers(self.cfg)
        self.telemetry = LoadTelemetry(
            self._n_moe, self.cfg.moe.num_experts if self.cfg.moe else 1,
            decay=self.telemetry_decay)
        self._layer_schedules: Optional[tuple] = None
        self._plan_age = 0
        self._placements: Optional[tuple] = None
        self._placement_age = 0
        self.compile_count = 0
        self.evicted_recompile_count = 0
        self._evicted_keys: set = set()
        self.guard = OOMGuard(
            DegradationLadder(self.mact.schedule_space(self.max_pipeline_depth)),
            max_retries=self.max_oom_retries, on_oom=self._oom_audit)
        self._audit_args: Optional[tuple] = None   # (state, batch) of the
        self.headroom_widenings: list = []         # attempt being audited
        self.resumed_from: Optional[int] = None

    # -- bounded compiled-step cache -------------------------------------------
    # Keyed by the schedule: a global (chunk bin, pipeline depth) pair of
    # ints, or the full per-layer ScheduleSpec vector (adaptive MACT).  Every
    # vector component comes from MACTController.schedule_space, so the key
    # space is bucketed and finite; the LRU cap bounds resident compilations
    # regardless (docs/DESIGN.md §Adaptive).
    def _step_for(self, chunks: int, pipeline: int = 1):
        return self._compiled((chunks, pipeline))

    def _compiled(self, key: tuple):
        if key in self._steps:
            self._steps.move_to_end(key)
            return self._steps[key]
        # placement-composite key: (schedule_key, placements vector).  The
        # schedule half keeps its exact historical form so placement-off runs
        # reuse the same cache keys (and the same compiled steps) as before.
        sched_key, placements = key, None
        if (len(key) == 2 and isinstance(key[1], tuple) and key[1]
                and isinstance(key[1][0], PlacementSpec)):
            sched_key, placements = key
        cfg = self.cfg
        if sched_key and sched_key[0] == FULL_REMAT:  # ladder floor: largest
            cfg = dataclasses.replace(self.cfg, remat_policy="full")
            ctx = dataclasses.replace(self.ctx, moe_chunks=sched_key[1],
                                      pipeline_chunks=1,
                                      layer_schedules=None)
        elif sched_key and isinstance(sched_key[0], tuple):  # per-layer vector
            ctx = dataclasses.replace(
                self.ctx,
                layer_schedules=tuple(ScheduleSpec(*s) for s in sched_key))
        else:
            # clear any caller-supplied vector: the global key IS the schedule
            ctx = dataclasses.replace(self.ctx, moe_chunks=sched_key[0],
                                      pipeline_chunks=sched_key[1],
                                      layer_schedules=None)
        if placements is not None:
            ctx = dataclasses.replace(ctx, placements=placements)
        fn = jax.jit(make_train_step(cfg, ctx, lr=self.lr))
        self._steps[key] = fn
        self.compile_count += 1
        if key in self._evicted_keys:
            # the schedule working set exceeds the cache: every round trip
            # re-traces the step graph — raise max_compiled_steps (or the
            # hysteresis) if this fires often
            self.evicted_recompile_count += 1
            warnings.warn(
                f"recompiling previously-evicted schedule key {key}; "
                f"{self.evicted_recompile_count} evict-recompiles so far "
                f"(max_compiled_steps={self.max_compiled_steps})")
        while len(self._steps) > self.max_compiled_steps:
            evicted, _ = self._steps.popitem(last=False)
            self._evicted_keys.add(evicted)
        return fn

    def _plan_params(self) -> tuple:
        """(ep_view, max_depth) both planning modes share."""
        ep_view = self.mact_ep_view or max(self.par.e, 1)
        # local path has no all-to-all to overlap: plan sequential-only so
        # the bin is not sized for a depth that will never run
        max_depth = self.max_pipeline_depth if self.ctx.mesh is not None else 1
        return ep_view, max_depth

    def choose_schedule(self) -> tuple:
        """(chunks, pipeline depth) for the next step — MACT-selected.

        Note the feedback scale: the global path plans from ``stats["load"]``
        summed over every MoE layer, so its s'' overestimates the per-layer
        received-token count by up to L_moe — conservative on memory (more
        chunks than strictly needed), and the historical behavior fig5/
        table4 track.  The adaptive path (``adaptive_mact=True``) plans from
        the per-layer telemetry rows, which is the memory model's native
        granularity.
        """
        if not self.use_mact or self.cfg.moe is None:
            return self.ctx.moe_chunks, self.ctx.pipeline_chunks
        ep_view, max_depth = self._plan_params()
        return self.mact.choose_schedule(self._last_load, ep_size=ep_view,
                                         max_depth=max_depth)

    def choose_chunks(self) -> int:
        return self.choose_schedule()[0]

    def choose_layer_schedules(self) -> tuple:
        """Per-layer ScheduleSpec vector for the next step (adaptive MACT).

        Re-plans from the telemetry EMA every ``replan_interval`` steps (and
        at cold start, from the worst case); between re-plans the vector in
        force is reused, so the compiled step does not even change identity.
        """
        if self._layer_schedules is None or self._plan_age >= self.replan_interval:
            ep_view, max_depth = self._plan_params()
            self._layer_schedules = self.mact.choose_layer_schedules(
                self.telemetry.loads, self._n_moe, ep_size=ep_view,
                max_depth=max_depth, current=self._layer_schedules,
                hysteresis=self.mact_hysteresis,
                headroom=self.mact_headroom,
                placements=self._placements)
            self._plan_age = 0
        self._plan_age += 1
        return self._layer_schedules

    # -- expert placement (docs/DESIGN.md §Placement) --------------------------
    def _placement_peers(self) -> int:
        """EP peers the placement maps over: the real mesh group when one
        exists, else the MACT planning view (lets single-device runs plan —
        and price — placements the same way they plan schedules)."""
        if self.ctx.mesh is not None:
            return max(self.par.e, 1)
        return self.mact_ep_view or max(self.par.e, 1)

    def choose_placements(self) -> Optional[tuple]:
        """Per-MoE-layer PlacementSpec vector, re-planned from the telemetry
        EMA at the same ``replan_interval`` cadence as the schedules (the
        placement replan runs FIRST so MACT prices schedules through the new
        map).  Each replan appends a record to ``placement_trace`` with the
        per-layer imbalance it acted on and the migration volume (weight
        slots + bytes the replan boundary's all-to-all moves)."""
        peers = self._placement_peers()
        E = self.cfg.moe.num_experts if self.cfg.moe else 0
        if (not self.use_placement or self._n_moe == 0 or peers <= 1
                or E % peers):
            return None
        if self._placements is None or self._placement_age >= self.replan_interval:
            old = self._placements
            self._placements = plc.choose_placements(
                self.telemetry.loads, self._n_moe, peers, num_experts=E,
                replicas=self.placement_replicas, current=old,
                hysteresis=self.placement_hysteresis)
            self._placement_age = 0
            moved = sum(
                plc.migrated_slots(old[j] if old is not None else None,
                                   self._placements[j])
                for j in range(self._n_moe)) if old != self._placements else 0
            imb = self.telemetry.imbalance()
            slot_bytes = (3 * self.cfg.d_model * self.cfg.moe.d_ff_expert
                          / self.par.t * 4)          # fp32 training weights
            self.placement_trace.append({
                "step": len(self.log),
                "imbalance": None if imb is None else [float(v) for v in imb],
                "migrated_slots": int(moved),
                "migrated_bytes": float(moved * slot_bytes),
                "identity": all(p.is_identity for p in self._placements),
            })
        self._placement_age += 1
        return self._placements

    def _with_placements(self, sched_key: tuple) -> tuple:
        """Attach the placement vector to a schedule cache key.  Identity
        (or disabled) placement keeps the bare schedule key, so those runs
        share compiled steps with the pre-placement path bit-for-bit."""
        p = self._placements
        if p is None or all(s.is_identity for s in p):
            return sched_key
        return (sched_key, p)

    @staticmethod
    def _vector_key(vec: tuple) -> tuple:
        vec = tuple(ScheduleSpec(*s) for s in vec)
        if len(set(vec)) == 1:           # uniform: collapse to the global
            return (vec[0].chunks, vec[0].depth)   # path (scan + reuse)
        return vec

    def _next_schedule_key(self) -> tuple:
        """The SCHEDULE half of the compiled-step cache key for the next
        step (the placement half is attached by ``_with_placements`` inside
        the attempt, so the OOM ladder escalates over pure schedule keys).
        The placement replan runs first: MACT then prices each layer's s''
        through the placement map it will actually run under."""
        self.choose_placements()
        if (self.adaptive_mact and self.use_mact and self.cfg.moe is not None
                and self._n_moe > 0):
            return self._vector_key(self.choose_layer_schedules())
        if self.ctx.layer_schedules and not self.use_mact:
            # hand-picked per-layer schedule, no controller: honor it
            return self._vector_key(self.ctx.layer_schedules)
        return tuple(self.choose_schedule())

    # -- resilience (docs/DESIGN.md §Resilience) -------------------------------

    @staticmethod
    def _key_summary(key: tuple) -> tuple:
        """(chunks, pipeline) actually run for a compiled-step cache key."""
        if key and key[0] == FULL_REMAT:
            return key[1], 1
        if key and isinstance(key[0], tuple):          # per-layer vector
            return (max(s[0] for s in key),            # memory-binding layer
                    max(s[1] for s in key))
        return key

    def _oom_audit(self, key: tuple, exc: Exception, step: int) -> dict:
        """Post-hoc memory-model audit after an OOM: log modeled-vs-actual
        bytes and widen the planning headroom when the model said the
        failed schedule fit — i.e. it under-predicted the peak."""
        chunks, depth = self._key_summary(key)
        if self._last_load is not None:
            s_pp = self.mact.observed_s_pp(self._last_load,
                                           self._plan_params()[0])
        else:
            import repro.core.memory_model as mm
            s_pp = mm.worst_case_s_prime(self.seq_len, self.par,
                                         self.mact.dims.topk)
        report = self.mact.memory_report(s_pp, chunks, depth)
        audit = {"step": step, "key": key, "s_pp": float(s_pp),
                 "modeled_total_gb": report["total_gb"],
                 "modeled_fits": bool(report["fits"]), "error": str(exc)}
        if self._audit_args is not None:               # HLO-derived actuals
            try:                                       # (best-effort: the
                from repro.launch import hlo_analysis  # failed step may not
                fn = self._compiled(self._with_placements(key))  # even lower)
                text = fn.lower(*self._audit_args).compile().as_text()
                audit["hlo_hbm_gb"] = (
                    hlo_analysis.analyse_module(text)["hbm_bytes"] / 2**30)
            except Exception:                          # noqa: BLE001
                audit["hlo_hbm_gb"] = None
        if report["fits"]:
            # the model admitted a schedule that OOMed: plan with more margin
            before = self.mact_headroom
            self.mact_headroom = before * self.headroom_widen + 1e-2
            self._layer_schedules = None               # force a fresh plan
            self._plan_age = 0
            audit["headroom"] = (before, self.mact_headroom)
            self.headroom_widenings.append(audit["headroom"])
        return audit

    def _runtime_extra(self) -> dict:
        """Host-side planner state a checkpoint must carry for a resumed
        run to replan warm (and bit-identically)."""
        return {
            "telemetry": self.telemetry.state_dict(),
            "last_load": (None if self._last_load is None
                          else np.asarray(self._last_load).tolist()),
            "layer_schedules": (None if self._layer_schedules is None
                                else [list(s) for s in self._layer_schedules]),
            "plan_age": self._plan_age,
            "mact_headroom": self.mact_headroom,
            "placements": (None if self._placements is None
                           else [[p.num_experts, p.num_peers,
                                  list(p.slot_to_expert)]
                                 for p in self._placements]),
            "placement_age": self._placement_age,
        }

    def _apply_extra(self, extra: dict) -> None:
        if not extra:
            return
        if extra.get("telemetry"):
            self.telemetry.load_state_dict(extra["telemetry"])
        if extra.get("last_load") is not None:
            self._last_load = np.asarray(extra["last_load"])
        if extra.get("layer_schedules") is not None:
            self._layer_schedules = tuple(
                ScheduleSpec(*s) for s in extra["layer_schedules"])
        self._plan_age = int(extra.get("plan_age", 0))
        self.mact_headroom = float(extra.get("mact_headroom",
                                             self.mact_headroom))
        if extra.get("placements") is not None:
            self._placements = tuple(
                PlacementSpec(int(e), int(p), tuple(int(s) for s in slots))
                for e, p, slots in extra["placements"])
        self._placement_age = int(extra.get("placement_age", 0))

    def _resume_state(self) -> Optional[TrainState]:
        """Restore the newest VALID checkpoint (corrupt ones are skipped by
        the manifest checksum) plus the warm planner state; None if the
        directory holds nothing restorable."""
        step = checkpointing.latest_step(self.checkpoint_dir)
        if step is None:
            return None
        like = init_train_state(jax.random.PRNGKey(self.seed), self.cfg)
        state = checkpointing.restore(self.checkpoint_dir, step, like)
        self._apply_extra(checkpointing.load_extra(self.checkpoint_dir, step))
        self.resumed_from = step
        return state

    # -- main loop ---------------------------------------------------------------
    def fit(self, steps: int, state: Optional[TrainState] = None,
            verbose: bool = False) -> TrainState:
        """Run the training loop.

        ``steps`` counts iterations from the given state — except under
        ``resume=True``, where it is the TARGET total step count: fit
        restores the newest valid checkpoint and trains the remainder, so
        crash + re-run converges on the same final step as an uninterrupted
        run.
        """
        if state is None and self.resume and self.checkpoint_dir:
            state = self._resume_state()
        if state is None:
            state = init_train_state(jax.random.PRNGKey(self.seed), self.cfg)
        n = steps - int(state.step) if self.resume else steps
        for i in range(max(n, 0)):
            step_idx = int(state.step)
            key = self._next_schedule_key()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(step_idx).items()}

            def attempt(k, _state=state, _batch=batch, _step=step_idx):
                if self.injector is not None:
                    self.injector.maybe_fail_step(_step)   # oom/crash hooks
                    self.injector.maybe_stall(_step)
                new_state, metrics = self._compiled(
                    self._with_placements(k))(_state, _batch)
                loss = float(metrics["loss"])          # sync point: a real
                return new_state, metrics, loss        # OOM surfaces here

            t0 = time.perf_counter()
            self._audit_args = (state, batch)
            n_esc = len(self.guard.escalations)
            (state, metrics, loss), used = self.guard.run(key, attempt,
                                                          step_idx)
            self._audit_args = None
            dt = time.perf_counter() - t0
            chunks, pipeline = self._key_summary(used)
            burst = (self.injector.burst_factor(step_idx)
                     if self.injector is not None else 1.0)
            load = np.asarray(metrics["load"]) * burst
            self._last_load = load
            if (self.adaptive_mact and self._n_moe
                    and "load_per_layer" in metrics):
                self.telemetry.update(
                    np.asarray(metrics["load_per_layer"]) * burst)
            tgs = self.global_batch * self.seq_len / max(dt, 1e-9)
            rec = {"step": int(state.step), "loss": loss,
                   "ce": float(metrics["ce"]), "aux": float(metrics["aux"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "chunks": chunks, "pipeline": pipeline, "time_s": dt,
                   "tgs": tgs, "max_load": float(load.max()),
                   "drops": float(metrics["drops"]),
                   "oom_retries": len(self.guard.escalations) - n_esc}
            imb = self.telemetry.imbalance()
            if imb is not None:
                rec["imbalance"] = float(imb.max())
            self.log.append(rec)
            self.chunk_trace.append(chunks)
            self.pipeline_trace.append(pipeline)
            if self.adaptive_mact and self._layer_schedules is not None:
                self.schedule_trace.append(self._layer_schedules)
            if verbose:
                imb_s = (f" imb={rec['imbalance']:.2f}"
                         if "imbalance" in rec else "")
                plc_s = ""
                if (self.placement_trace
                        and self.placement_trace[-1]["step"] == len(self.log) - 1):
                    last = self.placement_trace[-1]
                    plc_s = (f" replan[moved={last['migrated_slots']} slots,"
                             f" {last['migrated_bytes'] / 2**20:.1f} MiB]")
                print(f"step {rec['step']:4d} loss {rec['loss']:.4f} "
                      f"c={chunks} tgs={tgs:,.0f}{imb_s}{plc_s}")
            if (self.checkpoint_dir and self.checkpoint_every
                    and int(state.step) % self.checkpoint_every == 0):
                checkpointing.save(self.checkpoint_dir, int(state.step),
                                   state, extra=self._runtime_extra())
                if self.injector is not None:
                    self.injector.maybe_truncate_checkpoint(
                        step_idx, self.checkpoint_dir)
        return state
