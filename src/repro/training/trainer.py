"""Training loop with the MACT dynamic chunk controller in the driver seat.

Each step:
  1. MACT chooses the FCDA schedule — chunk bin AND pipeline depth — from the
     previous step's router load (s''), via the theoretical memory model
     (Eq. 8-9, extended with the pipeline's extra live chunk) — cold-starting
     from the worst case `s' -> e*s*k`.
  2. The step function compiled for that (bin, depth) runs (compiled variants
     are cached; <= 2 * len(bins) compilations ever happen).
  3. Router loads feed back to MACT; metrics/chunk trace are recorded
     (benchmarks/fig5 reads the trace).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.configs.base import HardwareProfile, ModelConfig, TPU_V5E
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.data.pipeline import SyntheticLMData
from repro.training.step import TrainState, init_train_state, make_train_step
from repro import checkpointing


@dataclass
class Trainer:
    cfg: ModelConfig
    ctx: DistContext
    seq_len: int
    global_batch: int
    lr: float = 3e-4
    seed: int = 0
    hw: HardwareProfile = TPU_V5E
    par: Optional[Parallelism] = None
    mact_bins: tuple = (1, 2, 4, 8)
    use_mact: bool = True
    max_pipeline_depth: int = 2          # MACT may pick depth in [1, this]
    mact_ep_view: Optional[int] = None   # group experts per hypothetical device
    static_override: Optional[float] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0
    log: list = field(default_factory=list)
    chunk_trace: list = field(default_factory=list)
    pipeline_trace: list = field(default_factory=list)

    def __post_init__(self):
        if self.par is None:
            ep = data = 1
            if self.ctx.mesh is not None:
                shape = dict(zip(self.ctx.mesh.axis_names,
                                 self.ctx.mesh.devices.shape))
                if self.cfg.moe is not None:
                    ep = shape.get(self.ctx.ep_axis, 1)
                data = shape.get("data", 1) * shape.get("pod", 1)
            self.par = Parallelism(e=max(ep, 1),
                                   b=max(1, self.global_batch // data))
        self.mact = MACTController(
            self.cfg, self.par, self.hw, self.seq_len, bins=self.mact_bins,
            static_override=self.static_override)
        self.data = SyntheticLMData(self.cfg, self.seq_len, self.global_batch,
                                    self.seed)
        self._steps: dict[tuple[int, int], object] = {}
        self._last_load: Optional[np.ndarray] = None

    # -- compiled step per (chunk bin, pipeline depth) -------------------------
    def _step_for(self, chunks: int, pipeline: int = 1):
        key = (chunks, pipeline)
        if key not in self._steps:
            ctx = dataclasses.replace(self.ctx, moe_chunks=chunks,
                                      pipeline_chunks=pipeline)
            self._steps[key] = jax.jit(make_train_step(self.cfg, ctx,
                                                       lr=self.lr))
        return self._steps[key]

    def choose_schedule(self) -> tuple:
        """(chunks, pipeline depth) for the next step — MACT-selected."""
        if not self.use_mact or self.cfg.moe is None:
            return self.ctx.moe_chunks, self.ctx.pipeline_chunks
        ep_view = self.mact_ep_view or max(self.par.e, 1)
        # local path has no all-to-all to overlap: plan sequential-only so
        # the bin is not sized for a depth that will never run
        max_depth = self.max_pipeline_depth if self.ctx.mesh is not None else 1
        return self.mact.choose_schedule(self._last_load, ep_size=ep_view,
                                         max_depth=max_depth)

    def choose_chunks(self) -> int:
        return self.choose_schedule()[0]

    # -- main loop ---------------------------------------------------------------
    def fit(self, steps: int, state: Optional[TrainState] = None,
            verbose: bool = False) -> TrainState:
        if state is None:
            state = init_train_state(jax.random.PRNGKey(self.seed), self.cfg)
        for i in range(steps):
            chunks, pipeline = self.choose_schedule()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(int(state.step)).items()}
            t0 = time.perf_counter()
            state, metrics = self._step_for(chunks, pipeline)(state, batch)
            loss = float(metrics["loss"])          # sync point
            dt = time.perf_counter() - t0
            load = np.asarray(metrics["load"])
            self._last_load = load
            tgs = self.global_batch * self.seq_len / max(dt, 1e-9)
            rec = {"step": int(state.step), "loss": loss,
                   "ce": float(metrics["ce"]), "aux": float(metrics["aux"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "chunks": chunks, "pipeline": pipeline, "time_s": dt,
                   "tgs": tgs, "max_load": float(load.max()),
                   "drops": float(metrics["drops"])}
            self.log.append(rec)
            self.chunk_trace.append(chunks)
            self.pipeline_trace.append(pipeline)
            if verbose:
                print(f"step {rec['step']:4d} loss {rec['loss']:.4f} "
                      f"c={chunks} tgs={tgs:,.0f}")
            if (self.checkpoint_dir and self.checkpoint_every
                    and int(state.step) % self.checkpoint_every == 0):
                checkpointing.save(self.checkpoint_dir, int(state.step), state)
        return state
