"""Loss and the train-step factory.

``make_train_step(cfg, ctx, ...)`` closes over a *static* FCDA schedule —
the global chunk count, or the full per-layer ``ScheduleSpec`` vector under
adaptive MACT (XLA requires it); the trainer keeps one compiled step per
schedule key and switches between them from the router-load feedback
(docs/DESIGN.md §2, §Adaptive).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.moe import DistContext
from repro.core.router import update_bias
from repro.models import transformer
from repro.optim.adamw import AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     dtype=jnp.float32) -> TrainState:
    params = transformer.init_params(key, cfg, dtype)
    return TrainState(params=params, opt=adamw_init(params), step=jnp.int32(0))


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over valid positions (labels < 0 are masked out)."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * valid
    return ce.sum() / jnp.maximum(valid.sum(), 1)


def loss_fn(params: dict, cfg: ModelConfig, ctx: DistContext, batch: dict):
    logits, stats = transformer.forward(params, cfg, ctx, batch)
    ce = cross_entropy(logits, batch["labels"])
    aux_coef = cfg.moe.router_aux_coef if cfg.moe else 0.0
    n_moe = max(1, transformer.num_moe_layers(cfg))
    aux = stats["aux_loss"] / n_moe
    loss = ce + aux_coef * aux
    m = {"ce": ce, "aux": aux, "load": stats["load"],
         "drops": stats["drops"]}
    if "load_per_layer" in stats:
        # (L_moe, E) per-layer routed-token histograms — the adaptive MACT
        # telemetry stream (core/telemetry.py)
        m["load_per_layer"] = stats["load_per_layer"]
    return loss, m


def make_train_step(cfg: ModelConfig, ctx: DistContext, *, lr=3e-4):
    """Returns step(state, batch) -> (state, metrics).  Jit separately with
    the desired in/out shardings."""

    def train_step(state: TrainState, batch: dict):
        (loss, m), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, ctx, batch)
        lr_val = lr if not callable(lr) else lr(state.step)
        params, opt, om = adamw_update(grads, state.opt, state.params, lr=lr_val)
        # DeepSeek-style loss-free bias balancing runs outside the gradient
        if cfg.moe is not None and cfg.moe.loss_free_bias:
            params = _update_router_biases(params, m["load"], cfg)
        metrics = {"loss": loss, **{k: v for k, v in m.items() if k != "load"},
                   "load": m["load"], **om, "lr": jnp.float32(lr_val)}
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def _update_router_biases(params: dict, load: jax.Array, cfg: ModelConfig):
    """Apply the loss-free bias update to every router in the tree (the summed
    global load is a shared signal — per-layer loads would need per-layer
    stats; adequate for balancing and matches the paper's 'untouched routing'
    constraint since biases only affect selection)."""

    def upd(path, leaf):
        keys = tuple(str(p) for p in path)
        if any("router" in k for k in keys) and any("bias" in k for k in keys):
            return update_bias(leaf, load, cfg.moe)
        return leaf

    return jax.tree_util.tree_map_with_path(upd, params)
