"""yi-9b [dense] — llama-architecture GQA decoder. [arXiv:2403.04652]

48L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    source="arXiv:2403.04652 (Yi)",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense", attn=AttentionSpec(kind="full")),),
    subquadratic=False,  # full attention -> long_500k skipped
)
