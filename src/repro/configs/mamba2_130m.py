"""mamba2-130m [ssm] — attention-free SSD (state-space duality). [arXiv:2405.21060]

24L, d_model=768, ssm_state=128, vocab=50280, no FFN (d_ff=0): each layer is a
single Mamba-2 mixer.  MemFine's MoE chunking is inapplicable (no MoE) — see
docs/DESIGN.md §Arch-applicability; the memory model + remat scheduling still apply.
"""

from repro.configs.base import LayerSpec, ModelConfig, SSMSpec

_SSM = SSMSpec(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=128)

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
    num_layers=24,
    d_model=768,
    num_heads=1,            # unused by the mamba mixer
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(mixer="mamba", ffn="none", ssm=_SSM),),
    tie_embeddings=True,
    subquadratic=True,      # constant-size state -> long_500k eligible
)
