"""starcoder2-3b [dense] — GQA + RoPE decoder. [arXiv:2402.19173]

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288, vocab=49152.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173 (StarCoder2)",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense", attn=AttentionSpec(kind="full")),),
    rope_theta=100000.0,
    subquadratic=False,  # full attention -> long_500k skipped (docs/DESIGN.md §4)
)
