"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave with MoE.

72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536,
MoE 16 experts top-2 on every second layer.  [arXiv:2403.19887 / Jamba-1.5]
Period-8 block: attention at index 3, Mamba elsewhere; MoE on odd layers.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig, MoEConfig, SSMSpec

_SSM = SSMSpec(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=128)
_ATT = AttentionSpec(kind="full", rope=False)  # Jamba attention layers use no RoPE

_PERIOD = tuple(
    LayerSpec(
        mixer="attn" if i == 3 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
        attn=_ATT,
        ssm=_SSM,
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887 (Jamba); Jamba-1.5-Large model card",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    pattern=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, strategy="auto"),
    subquadratic=True,   # mamba-dominated; attention is 1/8 of layers
    smoke_pattern=(
        LayerSpec(mixer="mamba", ffn="moe", attn=_ATT, ssm=_SSM),
        LayerSpec(mixer="attn", ffn="dense", attn=_ATT, ssm=_SSM),
    ),
)
