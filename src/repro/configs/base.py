"""Config system: model architectures, input shapes, hardware profiles.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` named ``CONFIG`` built with the exact numbers from its source
paper / model card (cited in the module docstring).  ``registry()`` collects
them; ``--arch <id>`` in the launchers resolves through it.

Layer structure is expressed as a *period pattern*: a short list of
``LayerSpec`` that repeats down the stack (e.g. jamba's 8-layer
mamba/attention interleave, gemma3's 5 local + 1 global).  The transformer
stack scans over whole periods, keeping HLO size O(period) instead of
O(layers), which matters for the 512-device dry-run compiles.
"""

from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# layer / block specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttentionSpec:
    """Self-attention mixer variant for one layer."""
    kind: str = "full"          # "full" | "window" | "chunked"  (chunked = llama4 iRoPE local)
    window: int = 0             # window size for "window", chunk size for "chunked"
    rope: bool = True
    qk_norm: bool = False


@dataclass(frozen=True)
class SSMSpec:
    """Mamba-2 (SSD) mixer."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64             # SSD intra-chunk block length


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating period: a mixer plus an FFN kind."""
    mixer: str = "attn"         # "attn" | "mamba"
    ffn: str = "dense"          # "dense" | "moe" | "none"
    attn: AttentionSpec = AttentionSpec()
    ssm: SSMSpec = SSMSpec()


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0            # expert hidden size (g_e in the paper)
    num_shared_experts: int = 0     # always-on shared expert(s) (llama4/deepseek style)
    router_aux_coef: float = 0.01   # Switch-style auxiliary load-balance loss weight
    loss_free_bias: bool = False    # DeepSeek auxiliary-loss-free bias balancing
    bias_update_rate: float = 0.001
    # MemFine knobs ---------------------------------------------------------
    strategy: str = "auto"          # "auto" | "ep_shardmap" | "tp_gspmd" | "dense"
    capacity_mode: str = "dropless" # "dropless" (worst-case static buffers) | "capacity"
    capacity_factor: float = 1.25   # only used by capacity_mode="capacity" baselines


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    source: str                     # citation for the numbers
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: tuple[LayerSpec, ...] = ()   # unrolled leading layers (e.g.
                                         # DeepSeek's d_l dense layers); the
                                         # pattern then scans over the rest
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    # encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0            # fixed encoder frame count (audio stub)
    # multimodal stubs ------------------------------------------------------
    num_patch_tokens: int = 0       # VLM: leading positions fed by patch embeddings
    learned_pos: int = 0            # learned position-embedding table size (whisper)
    # long-context eligibility (see docs/DESIGN.md §4)
    subquadratic: bool = False
    # MemFine scheduling ----------------------------------------------------
    remat_policy: str = "memfine"   # "none" | "full" | "memfine"
    moe_chunks: int = 1             # FCDA chunk count c (MACT overrides dynamically)
    # 2-layer representative pattern for the smoke tests (None -> derived)
    smoke_pattern: Optional[tuple[LayerSpec, ...]] = None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/logits vocab rounded up to a multiple of 256 so the vocab
        dim always shards over a 16-wide axis (Megatron-style padding; the
        real ``vocab_size`` stays the label space)."""
        return -(-self.vocab_size // 256) * 256

    def layer_specs(self) -> tuple[LayerSpec, ...]:
        """Full per-layer spec list (prefix, then pattern cycled)."""
        p = self.pattern
        body = self.num_layers - len(self.prefix)
        return self.prefix + tuple(p[i % len(p)] for i in range(body))

    @property
    def num_periods(self) -> int:
        return (self.num_layers - len(self.prefix)) // len(self.pattern)

    @property
    def remainder_layers(self) -> int:
        return (self.num_layers - len(self.prefix)) % len(self.pattern)

    def reduced(self, *, d_model: int = 256, max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, same family.

        The 2-layer pattern is ``smoke_pattern`` if given, else the first two
        distinct-mixer layers of the full pattern (so a hybrid keeps one mamba
        and one attention layer, an MoE arch keeps an MoE layer, etc.).
        """
        if self.smoke_pattern is not None:
            pat = self.smoke_pattern
        else:
            reps: list[LayerSpec] = []
            for ls in self.layer_specs():
                if not any(r.mixer == ls.mixer and r.ffn == ls.ffn for r in reps):
                    reps.append(ls)
                if len(reps) == 2:
                    break
            pat = tuple(reps) if len(reps) == 2 else (reps[0], reps[0])
        n_layers = 2
        heads = 4
        kv = max(1, min(self.num_kv_heads, 2))
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, max_experts),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=d_model * 2,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
            )
        ssm_small = SSMSpec(state_dim=16, head_dim=32, expand=2, conv_width=4, chunk=16)
        pat = tuple(replace(ls, ssm=ssm_small,
                            attn=replace(ls.attn, window=min(ls.attn.window, 64) if ls.attn.window else 0))
                    for ls in pat)
        return replace(
            self,
            name=self.name + "-smoke",
            prefix=(),
            num_layers=n_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=d_model * 3,
            vocab_size=512,
            pattern=pat,
            moe=moe,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            num_patch_tokens=min(self.num_patch_tokens, 8),
            dtype="float32",
        )


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}


# ---------------------------------------------------------------------------
# hardware profiles (for the memory model / MACT / roofline)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareProfile:
    name: str
    hbm_bytes: float
    peak_flops: float               # bf16
    hbm_bw: float                   # bytes/s
    ici_bw: float                   # bytes/s per link
    alpha: float = 0.9              # usable-memory fraction (paper's alpha)


TPU_V5E = HardwareProfile("tpu-v5e", 16e9, 197e12, 819e9, 50e9)
GPU_64G = HardwareProfile("gpu-64g", 64e9, 197e12, 819e9, 50e9)   # paper's 64 GB devices

PROFILES = {p.name: p for p in (TPU_V5E, GPU_64G)}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_SKIP_MODULES = {"base", "__init__"}


def registry() -> dict[str, ModelConfig]:
    """Import every config module in this package and collect CONFIG objects."""
    import repro.configs as pkg
    out: dict[str, ModelConfig] = {}
    for info in pkgutil.iter_modules(pkg.__path__):
        if info.name in _SKIP_MODULES:
            continue
        mod = importlib.import_module(f"repro.configs.{info.name}")
        cfg = getattr(mod, "CONFIG", None)
        if cfg is not None:
            out[cfg.name] = cfg
        extra = getattr(mod, "CONFIGS", ())
        for c in extra:
            out[c.name] = c
    return out


def get_config(name: str) -> ModelConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(reg)}")
    return reg[name]


def long_context_eligible(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (docs/DESIGN.md §4)."""
    return cfg.subquadratic


def decode_eligible(cfg: ModelConfig) -> bool:
    return True  # all assigned archs have a decoder; encoder-only would return False
