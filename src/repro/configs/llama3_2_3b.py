"""llama3.2-3b [dense] — small llama3. [hf:meta-llama/Llama-3.2-3B]

28L, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=128256.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-3B",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense", attn=AttentionSpec(kind="full")),),
    rope_theta=500000.0,
    tie_embeddings=True,
    subquadratic=False,  # full attention -> long_500k skipped
)
