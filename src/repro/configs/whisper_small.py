"""whisper-small [audio] — encoder-decoder transformer backbone. [arXiv:2212.04356]

12 encoder + 12 decoder layers, d_model=768, 12 heads, d_ff=3072, vocab=51865.
The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings (batch, 1500, d_model).
LayerNorm + learned-position style (no RoPE), MHA (kv == heads).
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356 (Whisper)",
    num_layers=12,              # decoder layers
    encoder_layers=12,
    encoder_seq=1500,           # 30 s of audio at 50 Hz after the conv frontend
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    norm="layernorm",
    pattern=(LayerSpec(mixer="attn", ffn="dense",
                       attn=AttentionSpec(kind="full", rope=False)),),
    learned_pos=32768,   # sized for the assigned decode_32k shape
    subquadratic=False,  # full-attention decoder -> long_500k skipped
)
