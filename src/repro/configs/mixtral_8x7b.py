"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention. [arXiv:2401.04088]

32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=14336, vocab=32000, SWA 4096.
8 experts do not divide the 16-wide model axis -> expert strategy falls back to
tp_gspmd (docs/DESIGN.md §2); FCDA chunking applies unchanged.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088 (Mixtral of Experts)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="moe",
                       attn=AttentionSpec(kind="window", window=4096)),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, strategy="auto"),
    rope_theta=1e6,
    subquadratic=True,  # SWA bounds the decode cache -> long_500k eligible
)
