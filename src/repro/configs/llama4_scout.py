"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE with a shared expert.

48L, d_model=5120, 40 heads (GQA kv=8), expert d_ff=8192, vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Attention follows the iRoPE layout: 3 chunked-local-attention layers
(chunk 8192, RoPE) then 1 global layer (NoPE) — which makes the arch
sub-quadratic in cache *compute* for local layers and long_500k eligible
with the chunked-local variant (docs/DESIGN.md §4).  Early fusion: multimodal
patches would enter as embeddings; the text backbone is what we build.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig, MoEConfig

_LOCAL = AttentionSpec(kind="chunked", window=8192, rope=True)
_GLOBAL = AttentionSpec(kind="full", rope=False)

_PERIOD = (
    LayerSpec(mixer="attn", ffn="moe", attn=_LOCAL),
    LayerSpec(mixer="attn", ffn="moe", attn=_LOCAL),
    LayerSpec(mixer="attn", ffn="moe", attn=_LOCAL),
    LayerSpec(mixer="attn", ffn="moe", attn=_GLOBAL),
)

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    pattern=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192,
                  num_shared_experts=1, strategy="auto"),
    rope_theta=500000.0,
    subquadratic=True,
)
