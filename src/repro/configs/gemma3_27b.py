"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

62L, d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144.
[hf:google/gemma-3-27b-pt]  Local layers use a 1024-token sliding window
(-> long_500k eligible via the sliding-window variant); every 6th layer is
global full attention.  QK-norm on, RoPE theta differs local/global (we use
the global theta; local window dominates positions anyway).
62 = 10 full periods of 6 + 2 remainder local layers (handled unrolled).
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

_LOCAL = AttentionSpec(kind="window", window=1024, rope=True, qk_norm=True)
_GLOBAL = AttentionSpec(kind="full", rope=True, qk_norm=True)

_PERIOD = tuple(
    LayerSpec(mixer="attn", ffn="dense", attn=_LOCAL if i < 5 else _GLOBAL)
    for i in range(6)
)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-27b-pt",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    pattern=_PERIOD,
    rope_theta=1e6,
    tie_embeddings=True,
    subquadratic=True,   # 5/6 of layers are window-1024
    smoke_pattern=(
        LayerSpec(mixer="attn", ffn="dense", attn=_LOCAL),
        LayerSpec(mixer="attn", ffn="dense", attn=_GLOBAL),
    ),
)
