"""internvl2-76b [vlm] — InternViT-6B + Llama-3-70B backbone. [arXiv:2404.16821]

We build the language backbone: 80L, d_model=8192, 64 heads (GQA kv=8),
d_ff=28672, vocab=128256.  The InternViT vision encoder + MLP projector are a
STUB per the assignment carve-out: ``input_specs`` supplies projected patch
embeddings (batch, 256, d_model) that occupy the leading sequence positions.
"""

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); LLM backbone = Llama-3-70B shape",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    pattern=(LayerSpec(mixer="attn", ffn="dense", attn=AttentionSpec(kind="full")),),
    num_patch_tokens=256,
    rope_theta=500000.0,
    subquadratic=False,  # full attention -> long_500k skipped
)
