"""The paper's own experiment models (Table 3): layer-reduced DeepSeek-V3.

Model I : 16L, Model II: 8L — s=4096, h=7168, a=128 heads, g_d=18432 (dense
FFN), g_e=2048 (expert FFN), top-k=8, V=129280, d_l=3 leading dense layers.
DeepSeek-V3 routing shape: 256 routed experts + 1 shared expert, top-8,
auxiliary-loss-free bias balancing.  [paper Table 3; arXiv:2412.19437]

Adaptation note (docs/DESIGN.md §2): the paper trains with MLA; Table 2's memory
model parameterises attention as generic (a, k_a, h_d), so we instantiate
standard MHA with head_dim=128 and k_a=a.  256 % 16 == 0 -> ep_shardmap.
"""

from dataclasses import replace

from repro.configs.base import AttentionSpec, LayerSpec, ModelConfig, MoEConfig

_DENSE = LayerSpec(mixer="attn", ffn="dense", attn=AttentionSpec(kind="full"))
_MOE = LayerSpec(mixer="attn", ffn="moe", attn=AttentionSpec(kind="full"))

_MOE_CFG = MoEConfig(
    num_experts=256,
    top_k=8,
    d_ff_expert=2048,
    num_shared_experts=1,
    loss_free_bias=True,
    strategy="auto",
)


def _model(name: str, layers: int) -> ModelConfig:
    # 3 unrolled dense layers, then a scan over identical MoE layers: the
    # scan (an HLO while loop) also serialises per-layer buffer liveness,
    # which XLA-CPU's scheduler does not do for unrolled layers
    # (docs/DESIGN.md §Perf; trajectory in the BENCH_*.json artifacts).
    prefix, pattern = (_DENSE,) * 3, (_MOE,)
    return ModelConfig(
        name=name,
        family="moe",
        source="MemFine paper Table 3 (layer-reduced DeepSeek-V3); arXiv:2412.19437",
        num_layers=layers,
        d_model=7168,
        num_heads=128,
        num_kv_heads=8,   # GQA stand-in for MLA's compressed KV (docs/DESIGN.md §2)
        d_ff=18432,
        vocab_size=129280,
        head_dim=128,
        pattern=pattern,
        prefix=prefix,
        moe=_MOE_CFG,
        subquadratic=False,
        smoke_pattern=(_DENSE, _MOE),
    )


MODEL_I = _model("deepseek-mini-16l", 16)
MODEL_II = _model("deepseek-mini-8l", 8)

CONFIG = MODEL_I
CONFIGS = (MODEL_II,)
