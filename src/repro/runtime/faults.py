"""Deterministic fault injection for the resilience runtime.

MemFine plans memory for a *predicted* routed load (Eq. 8-9); production is
where the prediction is wrong: a skew burst past the EMA's headroom, a real
``RESOURCE_EXHAUSTED`` from the runtime, a crash mid-step, a checkpoint cut
short by a dying host.  The ``FaultInjector`` reproduces exactly those
failures on demand (docs/DESIGN.md §Resilience), so the degradation ladder
(runtime/guard.py), the self-healing resume path (training/trainer.py) and
the serving requeue invariants (serving/scheduler.py) are all testable on
the CPU container — and the chaos harness (benchmarks/chaos_harness.py) can
score them.

Fault kinds (``FaultSpec.kind``):

* ``oom``           — raise ``SimulatedOOM`` (walks and quacks like XLA's
                      RESOURCE_EXHAUSTED) before the step/wave runs.
* ``burst``         — multiply the observed router load by ``magnitude``
                      before it feeds back to MACT/telemetry: a routing skew
                      burst beyond the planned ``s_pp``.
* ``crash``         — raise ``SimulatedCrash``: a hard process death the
                      guard must NOT swallow (the resume path handles it).
* ``stall``         — sleep ``magnitude`` seconds (a stalled prefill /
                      straggler step).
* ``ckpt_truncate`` — truncate the newest checkpoint payload on disk, the
                      torn write a crash-consistent store must survive.

Each spec fires at ``at`` (a training step index or a serving scheduler
step) for ``times`` consecutive triggers.  Everything fired is recorded in
``injector.fired`` so tests and the chaos harness can assert exact fault
placement.
"""

from __future__ import annotations

import glob
import os
import time
from dataclasses import dataclass, field


class SimulatedOOM(MemoryError):
    """Stands in for jaxlib's XlaRuntimeError(RESOURCE_EXHAUSTED)."""

    def __init__(self, where: str = "step"):
        super().__init__(f"RESOURCE_EXHAUSTED: simulated out of memory "
                         f"while running {where}")


class SimulatedCrash(RuntimeError):
    """A hard failure the guard must re-raise (process death, not OOM)."""


@dataclass
class FaultSpec:
    kind: str                  # oom | burst | crash | stall | ckpt_truncate
    at: int                    # step index the fault arms at
    times: int = 1             # consecutive triggers before it disarms
    magnitude: float = 2.0     # burst load multiplier / stall seconds
    fired: int = 0             # how often this spec has gone off

    _KINDS = ("oom", "burst", "crash", "stall", "ckpt_truncate")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {self._KINDS}")

    def armed(self, step: int) -> bool:
        return step >= self.at and self.fired < self.times


def parse_spec(text: str) -> list[FaultSpec]:
    """``"oom@3,burst@2x1.5,ckpt_truncate@4"`` -> FaultSpec list.

    Grammar per item: ``kind@step[xMAGNITUDE][*TIMES]`` — the launcher-flag
    form of a chaos scenario (launch/train.py --inject).
    """
    specs = []
    for item in filter(None, (s.strip() for s in text.split(","))):
        kind, _, rest = item.partition("@")
        if not rest:
            raise ValueError(f"fault spec {item!r} needs '@step'")
        times = 1
        if "*" in rest:
            rest, _, t = rest.partition("*")
            times = int(t)
        magnitude = 2.0
        if "x" in rest:
            rest, _, m = rest.partition("x")
            magnitude = float(m)
        specs.append(FaultSpec(kind=kind, at=int(rest), times=times,
                               magnitude=magnitude))
    return specs


@dataclass
class FaultInjector:
    """Threaded through ``Trainer.fit`` and the serving scheduler's step.

    Every hook is a no-op unless a matching spec is armed for the current
    step, so a ``None`` injector and an empty one behave identically and
    the hot loop pays one list scan.
    """
    specs: list = field(default_factory=list)
    fired: list = field(default_factory=list)   # (kind, step) audit trail

    @classmethod
    def from_string(cls, text: str) -> "FaultInjector":
        return cls(specs=parse_spec(text))

    def _take(self, kind: str, step: int):
        for spec in self.specs:
            if spec.kind == kind and spec.armed(step):
                spec.fired += 1
                self.fired.append((kind, step))
                return spec
        return None

    # -- hooks ---------------------------------------------------------------

    def maybe_fail_step(self, step: int, where: str = "train_step") -> None:
        """Raise the armed failure for ``step`` (OOM before crash: a run
        with both scheduled at one step must exercise the ladder first)."""
        if self._take("oom", step) is not None:
            raise SimulatedOOM(where)
        if self._take("crash", step) is not None:
            raise SimulatedCrash(f"simulated crash at {where} step {step}")

    def maybe_stall(self, step: int) -> float:
        spec = self._take("stall", step)
        if spec is not None:
            time.sleep(spec.magnitude)
            return spec.magnitude
        return 0.0

    def burst_factor(self, step: int) -> float:
        """Routing-burst multiplier for this step's observed load (1.0 when
        nothing is armed).  One armed burst yields one factor the caller
        applies to both the global and the per-layer load views, so the
        telemetry stays internally consistent."""
        spec = self._take("burst", step)
        return 1.0 if spec is None else float(spec.magnitude)

    def maybe_truncate_checkpoint(self, step: int, ckpt_dir: str) -> str | None:
        """Tear the newest checkpoint payload in half — the torn write of a
        host dying mid-save.  Returns the mangled path, or None."""
        spec = self._take("ckpt_truncate", step)
        if spec is None or not ckpt_dir:
            return None
        payloads = sorted(glob.glob(os.path.join(ckpt_dir, "step_*.npz")))
        if not payloads:
            return None
        victim = payloads[-1]
        size = os.path.getsize(victim)
        with open(victim, "r+b") as f:
            f.truncate(size // 2)
        return victim
