from repro.runtime.faults import (FaultInjector, FaultSpec, SimulatedCrash,
                                  SimulatedOOM, parse_spec)
from repro.runtime.guard import (DegradationLadder, OOMGuard, is_oom_error)
