"""OOM degradation ladder + serving overload guard.

MemFine's memory model *plans* a schedule that should fit; this module is
what happens when the plan is wrong anyway (docs/DESIGN.md §Resilience).

Training — ``OOMGuard`` wraps the trainer's compiled-step execution.  An
out-of-memory failure (a real ``XlaRuntimeError: RESOURCE_EXHAUSTED`` or an
injected ``SimulatedOOM``) does not kill the run; the guard rolls back to
the pre-step ``TrainState`` (the step is functional, so the input state is
the rollback point) and retries down a **degradation ladder** of strictly
more memory-conservative schedules drawn from
``MACTController.schedule_space``:

    incumbent (bin, depth)
      -> same bin, depth 1        (drop the pipeline's extra live chunk)
      -> each larger bin, depth 1 (deeper FCDA chunking, Eq. 9)
      -> largest bin, depth 1, remat_policy="full"  (full recompute: the
         most memory-lean schedule the codebase can express)

Retries are bounded by ``max_retries``; exhausting the ladder re-raises so
a truly impossible step fails loudly instead of looping.  Every escalation
is recorded, and the trainer layers a post-hoc memory-model audit on top
(modeled-vs-HLO-derived bytes, headroom widening) via the ``on_oom``
callback.

Serving — ``ServingGuard`` holds the scheduler-side policy knobs: the
per-request deadline, the WAITING-queue overload bound, and the
retry-after estimate quoted to shed clients.  Accepted requests (PREFILL/
ACTIVE) are never shed — shedding applies only to requests still waiting
for admission; a faulted decode wave requeues its accepted requests
instead (serving/scheduler.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.chunking import ScheduleSpec
from repro.runtime.faults import SimulatedOOM

# the ladder's final rung: trainer compiles this key with
# remat_policy="full" on top of the largest chunk bin
FULL_REMAT = "full-remat"


def is_oom_error(exc: BaseException) -> bool:
    """Is ``exc`` an out-of-memory failure the ladder should absorb?

    Matches the injected ``SimulatedOOM`` (a MemoryError) and the messages
    jaxlib's ``XlaRuntimeError`` carries for allocator exhaustion — the
    exception class itself is version-dependent, so classify by content.
    """
    if isinstance(exc, (SimulatedOOM, MemoryError)):
        return True
    msg = str(exc)
    return ("RESOURCE_EXHAUSTED" in msg or "Out of memory" in msg
            or "out of memory" in msg)


def _conservatism(key: tuple) -> tuple:
    """(chunks, depth) summary of a schedule key, for ladder ordering: the
    *least* chunked / deepest component of a per-layer vector is what OOMs."""
    if key and key[0] == FULL_REMAT:
        return (key[1], 1)
    if key and isinstance(key[0], tuple):                  # per-layer vector
        specs = [ScheduleSpec(*s) for s in key]
        return (min(s.chunks for s in specs), max(s.depth for s in specs))
    return (int(key[0]), int(key[1]))


@dataclass
class DegradationLadder:
    """Rungs strictly more memory-conservative than an incumbent key.

    ``space`` is ``MACTController.schedule_space(max_depth)`` — the same
    bucketed emission set that bounds the trainer's compiled-step cache, so
    escalation can never mint a schedule the cache key space doesn't know.
    """
    space: tuple

    def rungs_after(self, key: tuple) -> list[tuple]:
        if key and key[0] == FULL_REMAT:
            return []                                      # already at the floor
        bins = sorted({ScheduleSpec(*s).chunks for s in self.space})
        c, d = _conservatism(key)
        rungs: list[tuple] = []
        if d > 1:
            rungs.append((c, 1))
        rungs += [(b, 1) for b in bins if b > c]
        rungs.append((FULL_REMAT, bins[-1]))
        return rungs


@dataclass
class OOMGuard:
    """Execute-with-ladder wrapper for the trainer's compiled step."""
    ladder: DegradationLadder
    max_retries: int = 4
    on_oom: Optional[Callable] = None     # (key, exc, step) -> audit dict
    escalations: list = field(default_factory=list)
    audits: list = field(default_factory=list)

    def run(self, key: tuple, attempt: Callable, step: int):
        """``attempt(key) -> result`` under the ladder.

        Returns ``(result, key_used)``.  Non-OOM exceptions (including
        ``SimulatedCrash``) propagate untouched — they are the resume
        path's job, not the ladder's.
        """
        rungs = [key] + self.ladder.rungs_after(key)
        last: Optional[BaseException] = None
        for retries, k in enumerate(rungs):
            if retries > self.max_retries:
                break
            try:
                return attempt(k), k
            except Exception as exc:                  # noqa: BLE001 — classified below
                if not is_oom_error(exc):
                    raise
                last = exc
                nxt = rungs[retries + 1] if retries + 1 < len(rungs) else None
                self.escalations.append(
                    {"step": step, "failed": k, "next": nxt,
                     "retries": retries + 1, "error": str(exc)})
                if self.on_oom is not None:
                    audit = self.on_oom(k, exc, step)
                    if audit:
                        self.audits.append(audit)
        raise RuntimeError(
            f"OOM ladder exhausted at step {step}: "
            f"{min(len(rungs), self.max_retries + 1)} schedules failed, "
            f"last {self.escalations[-1]['failed']!r}") from last


@dataclass
class ServingGuard:
    """Scheduler-side overload policy (docs/DESIGN.md §Resilience).

    * ``deadline_s`` — default admission deadline: a WAITING request not
      admitted within this many seconds of arrival is shed with a
      client-visible ``retry_after``.  Per-request deadlines override it.
    * ``max_waiting`` — overload bound on the WAITING queue; arrivals
      beyond it are shed immediately (0 = unbounded).
    * ``retry_after`` — the quote handed to shed clients: the current
      backlog drained at the observed request service rate, floored at
      one second so clients never hammer-retry.
    * ``admission_escalation`` — the ordered memory-pressure ladder a
      paged scheduler walks when admission would be refused
      (docs/DESIGN.md §Paging): first reclaim prefix-cache pages (loses
      only *recomputable* state), then preempt a lower-priority resident
      (its pages spill to host and restore losslessly on re-admission),
      and only then leave the request WAITING — where the existing
      deadline/overload shedding applies to never-accepted requests.
      Preemption before shedding is what preserves the no-accepted-loss
      invariant under pressure: shedding is terminal, preemption is not.
    """
    deadline_s: Optional[float] = None
    max_waiting: int = 0
    shed: list = field(default_factory=list)

    #: pressure-relief rungs, cheapest-to-reverse first
    ESCALATION = ("evict_prefix", "preempt", "wait_or_shed")

    def admission_escalation(self, prefix_cache: bool,
                             preemption: bool) -> tuple:
        """The rungs enabled by the scheduler's feature flags, in order."""
        return tuple(r for r in self.ESCALATION
                     if (r != "evict_prefix" or prefix_cache)
                     and (r != "preempt" or preemption))

    def deadline_for(self, req) -> Optional[float]:
        return req.deadline_s if req.deadline_s is not None else self.deadline_s

    def expired(self, req, now: float) -> bool:
        dl = self.deadline_for(req)
        return dl is not None and (now - req.arrival) > dl

    def overloaded(self, waiting: int) -> bool:
        return self.max_waiting > 0 and waiting >= self.max_waiting

    def retry_after(self, backlog: int, service_rate_hz: float) -> float:
        if service_rate_hz <= 0:
            return max(1.0, float(backlog))
        return max(1.0, backlog / service_rate_hz)
