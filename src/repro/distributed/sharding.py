"""Logical sharding rules -> NamedShardings, divisibility-guarded.

Rules (docs/DESIGN.md §5): vocab/heads/d_ff/experts shard over ``model``;
batch over ``("pod","data")``; long-context decode caches shard their
*sequence* dim over the data axes instead (batch=1).  Any dim that does not
divide its axis is replicated — exercised per arch by
tests/test_sharding_rules.py (docs/DESIGN.md §5) so the roofline table can
call out the fallbacks (e.g. mixtral's 8 experts on a 16-wide axis,
whisper's 51865 vocab).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def guarded(mesh: Mesh, dim: int, axes) -> Optional[object]:
    """Return ``axes`` if ``dim`` divides their product, else None (replicate)."""
    return axes if dim % axis_size(mesh, axes) == 0 else None


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _leaf_spec(path: str, shape: tuple, mesh: Mesh, cfg: ModelConfig) -> P:
    """Sharding rule for one parameter leaf, keyed on its tree path."""
    m = "model"

    def g(dim_idx: int, axes):
        return guarded(mesh, shape[dim_idx], axes)

    if "embed" in path or "pos_embed" in path:
        return P(g(0, m), None)
    if path.endswith("head"):
        return P(None, g(1, m))
    # MoE experts: (E, d, f) / (E, f, d) — expert dim over model when possible,
    # else fall back to sharding the ffn dim (tp_gspmd strategy).
    if any(f"'{w}'" in path for w in ("w1", "w2", "w3")) and len(shape) == 3:
        if shape[0] % axis_size(mesh, m) == 0:
            return P(m, None, None)
        big = 1 if shape[1] > shape[2] else 2
        return P(None, *((g(1, m), None) if big == 1 else (None, g(2, m))))
    if "router" in path:
        return P(None) if len(shape) == 1 else P(None, None)
    if "conv" in path:
        return P(*([None] * len(shape)))
    # attention / dense mlp / shared expert / ssm 2-D weights: shard the big dim
    if len(shape) == 2:
        if "wo" in path or "out_proj" in path or path.endswith("'w2'"):
            return P(g(0, m), None)            # row-parallel (input sharded)
        return P(None, g(1, m))                # column-parallel
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    return "/".join(str(p) for p in path)


def param_shardings(params, mesh: Mesh, cfg: ModelConfig):
    """NamedShardings for a parameter pytree (stacked period dims handled:
    leaves under 'periods' have a leading stack dim that stays replicated)."""

    def spec_for(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        if "periods" in p and len(shape) >= 1:
            inner = _leaf_spec(p, shape[1:], mesh, cfg)
            return P(None, *inner)
        return _leaf_spec(p, shape, mesh, cfg)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf)), params)


def act_pspec(mesh: Mesh, batch: int) -> P:
    ba = batch_axes(mesh)
    ba = guarded(mesh, batch, ba)
    return P(ba, None, None)


def logits_pspec(mesh: Mesh, batch: int, vocab: int) -> P:
    ba = guarded(mesh, batch, batch_axes(mesh))
    return P(ba, None, guarded(mesh, vocab, "model"))


def batch_pspec(mesh: Mesh, batch: int) -> P:
    ba = guarded(mesh, batch, batch_axes(mesh))
    return P(ba, None)


def cache_pspec(mesh: Mesh, leaf_shape: tuple, batch: int) -> P:
    """Decode caches: shard the batch dim over the data axes when divisible
    (handling the leading period-stack dim of scanned layers), else shard the
    largest (sequence) dim — the single-sequence long-context case."""
    ba = batch_axes(mesh)
    n = axis_size(mesh, ba)
    # normalise singleton axis tuples to bare names (new jax does this inside
    # PartitionSpec; old jax keeps the 1-tuple, breaking == comparisons)
    ba = ba[0] if isinstance(ba, tuple) and len(ba) == 1 else ba
    dims: list = [None] * len(leaf_shape)
    if n <= 1 or not leaf_shape:
        return P(*dims)
    for i, d in enumerate(leaf_shape[:2]):        # batch is dim 0, or dim 1
        if d == batch and batch % n == 0:         # after a period-stack dim
            dims[i] = ba
            return P(*dims)
    big = max(range(len(leaf_shape)), key=lambda i: leaf_shape[i])
    if leaf_shape[big] % n == 0 and leaf_shape[big] >= n:
        dims[big] = ba                             # long_500k: shard sequence
    return P(*dims)
