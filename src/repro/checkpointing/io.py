"""Crash-consistent pytree checkpointing: npz payload + json manifest.

Arrays are gathered to host (fine at the scales this container trains; a
real multi-host deployment would swap in per-shard writes behind the same
save/restore API).

Crash consistency (docs/DESIGN.md §Resilience): a checkpoint is *committed*
by its manifest.  ``save`` writes the npz payload to a temp file, fsyncs,
``os.replace``s it into place, then writes the manifest — carrying the
payload's sha256, the leaf count and the treedef string — the same way.
Readers (``latest_step``/``valid_steps``) only trust steps whose manifest
exists AND whose payload hashes to the recorded checksum, so a write torn
by a crash (or by the fault injector's ``ckpt_truncate``) is skipped, never
returned.  ``restore`` additionally validates the manifest structure
against the caller's ``like_tree`` — a stale tree fails loudly instead of
silently unflattening into the wrong pytree.

The manifest's ``extra`` dict carries small host-side runtime state the
self-healing resume needs warm — the telemetry EMA and the MACT hysteresis
vector (training/trainer.py) — as plain JSON.
"""

from __future__ import annotations

import hashlib
import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _base(path: str, step: int) -> str:
    return os.path.join(path, f"step_{step:08d}")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _replace_into(tmp: str, dst: str) -> None:
    with open(tmp, "rb") as f:
        os.fsync(f.fileno())
    os.replace(tmp, dst)


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Write a committed checkpoint; returns the payload path.

    ``extra`` is a small JSON-serializable dict stored in the manifest
    (numpy arrays are converted; restore hands it back via ``load_extra``).
    """
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    out = _base(path, step)
    tmp = out + ".npz.tmp"
    np.savez(tmp, **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    if os.path.exists(tmp + ".npz"):     # np.savez appends .npz to bare names
        tmp += ".npz"
    checksum = _sha256(tmp)
    _replace_into(tmp, out + ".npz")
    manifest = {"step": step, "treedef": str(treedef),
                "n_leaves": len(leaves), "sha256": checksum,
                "extra": _jsonable(extra or {})}
    with open(out + ".json.tmp", "w") as f:
        json.dump(manifest, f)
    _replace_into(out + ".json.tmp", out + ".json")
    return out + ".npz"


def _jsonable(obj):
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, (np.integer, np.floating)):
        return obj.item()
    return obj


def _manifest(path: str, step: int) -> dict | None:
    try:
        with open(_base(path, step) + ".json") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def verify(path: str, step: int) -> tuple[bool, str]:
    """Is checkpoint ``step`` committed and intact?  (ok, reason)."""
    man = _manifest(path, step)
    if man is None:
        return False, "manifest missing or unreadable"
    payload = _base(path, step) + ".npz"
    if not os.path.exists(payload):
        return False, "payload missing"
    if "sha256" in man:
        if _sha256(payload) != man["sha256"]:
            return False, "payload checksum mismatch (torn write?)"
    else:                                 # legacy manifest: loadability only
        try:
            with np.load(payload) as data:
                if len(data.files) != man.get("n_leaves", len(data.files)):
                    return False, "legacy payload leaf count mismatch"
        except Exception:                 # noqa: BLE001 — any decode failure
            return False, "legacy payload unreadable"
    return True, "ok"


def valid_steps(path: str) -> list[int]:
    """All committed-and-intact checkpoint steps, ascending."""
    if not os.path.isdir(path):
        return []
    steps = {int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"step_(\d+)\.(?:npz|json)$", f))}
    return [s for s in sorted(steps) if verify(path, s)[0]]


def latest_step(path: str) -> int | None:
    """Newest *valid* checkpoint step — partial/corrupt saves are skipped,
    so a resume after a torn write replays from the last good one."""
    steps = valid_steps(path)
    return steps[-1] if steps else None


def restore(path: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype preserved).

    The saved manifest's structure (leaf count, treedef string) must match
    ``like_tree`` — catching the stale-tree case where leaf shapes happen
    to line up but the pytree they unflatten into is wrong.
    """
    leaves, treedef = _flatten(like_tree)
    man = _manifest(path, step)
    if man is not None:
        if man.get("n_leaves", len(leaves)) != len(leaves):
            raise ValueError(
                f"checkpoint step {step} holds {man['n_leaves']} leaves but "
                f"like_tree has {len(leaves)} — restoring into a different "
                f"structure than was saved")
        saved_def = man.get("treedef")
        if saved_def is not None and saved_def != str(treedef):
            raise ValueError(
                f"checkpoint step {step} treedef does not match like_tree:\n"
                f"  saved:    {saved_def}\n  like_tree: {treedef}")
    data = np.load(_base(path, step) + ".npz")
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        if tuple(old.shape) != tuple(new.shape):
            raise ValueError(f"shape mismatch {old.shape} vs {new.shape}")
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def load_extra(path: str, step: int) -> dict:
    """The manifest's ``extra`` dict ({} for legacy checkpoints)."""
    man = _manifest(path, step)
    return (man or {}).get("extra", {})
