"""Pytree checkpointing: npz for arrays + a json manifest for the structure.

Arrays are gathered to host (fine at the scales this container trains; a
real multi-host deployment would swap in per-shard writes behind the same
save/restore API).
"""

from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    out = os.path.join(path, f"step_{step:08d}")
    np.savez(out + ".npz", **{f"leaf_{i}": np.asarray(l)
                              for i, l in enumerate(leaves)})
    with open(out + ".json", "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "n_leaves": len(leaves)}, f)
    return out + ".npz"


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(path: str, step: int, like_tree):
    """Restore into the structure of ``like_tree`` (shape/dtype preserved)."""
    data = np.load(os.path.join(path, f"step_{step:08d}.npz"))
    leaves, treedef = _flatten(like_tree)
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, new in zip(leaves, new_leaves):
        if tuple(old.shape) != tuple(new.shape):
            raise ValueError(f"shape mismatch {old.shape} vs {new.shape}")
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
