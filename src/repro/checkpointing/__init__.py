from repro.checkpointing.io import (latest_step, load_extra, restore, save,
                                    valid_steps, verify)
