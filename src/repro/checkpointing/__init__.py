from repro.checkpointing.io import latest_step, restore, save
