"""Dry-run machinery: abstract inputs, lowering, HLO analysis, roofline terms.

Used by launch/dryrun.py (CLI) and benchmarks/roofline.py.  Everything here
operates on ShapeDtypeStructs — no device allocation ever happens; the
``.lower().compile()`` succeeding per (arch x shape x mesh) is the deliverable.

Conventions:
  * ``cost_analysis()``/``memory_analysis()`` of the SPMD-partitioned module
    are PER DEVICE (verified on this backend); the roofline divides by
    per-chip peaks directly.
  * collective bytes = sum of output-shape bytes of every all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute in the
    optimized HLO, per device.
"""

from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import (InputShape, ModelConfig, SHAPES, TPU_V5E,
                                get_config, long_context_eligible)
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.data.pipeline import make_batch_specs
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.training.step import init_train_state, make_train_step
from repro.serving.engine import make_serve_step

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string, e.g. 'bf16[8,128]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind output bytes (per device) from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        if "-done" in line.split("(")[0]:
            continue  # avoid double counting start/done pairs
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


# ---------------------------------------------------------------------------
# context / abstract inputs per (arch, shape, mesh)
# ---------------------------------------------------------------------------

def mesh_dims(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def build_context(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                  chunks: Optional[int] = None, use_pallas: bool = False,
                  strategy: str = "auto",
                  flags: Optional[dict] = None) -> tuple[ModelConfig, DistContext]:
    """``flags`` are the beyond-paper optimization knobs (docs/DESIGN.md §Perf):
      seq_shard_acts   — shard inter-layer activations (B,S,d) on S over
                         'model' (sequence parallelism; cuts stored-x memory
                         and turns TP all-reduces into RS/AG pairs)
      prefill_chunks   — apply FCDA chunking to the MoE in *inference prefill*
                         (the paper only chunks training)
    """
    flags = flags or {}
    B = shape.global_batch
    if chunks is None:
        chunks = choose_chunks(cfg, shape, mesh)
    if shape.mode == "prefill":
        chunks = int(flags.get("prefill_chunks", 1))
    elif shape.mode != "train":
        chunks = 1
    seq_ax = "model" if flags.get("seq_shard_acts") and \
        shape.seq_len % shd.axis_size(mesh, "model") == 0 else None
    ctx = DistContext(
        mesh=mesh,
        batch_axes=shd.batch_axes(mesh),
        ep_axis="model",
        moe_chunks=chunks,
        remat_chunks=True,
        use_pallas=use_pallas or bool(flags.get("pallas_interpret")),
        moe_strategy=strategy,
        moe_ragged=bool(flags.get("moe_ragged")),
        moe_fused=bool(flags.get("moe_fused")),
        pallas_interpret=bool(flags.get("pallas_interpret")),
        act_pspec=NamedSharding(
            mesh, P(shd.guarded(mesh, B, shd.batch_axes(mesh)), seq_ax, None)),
        logits_pspec=NamedSharding(mesh, shd.logits_pspec(mesh, B, cfg.padded_vocab)),
        heads_pspec=NamedSharding(
            mesh, P(shd.guarded(mesh, B, shd.batch_axes(mesh)), None, "model",
                    None)),
    )
    return cfg, ctx


def choose_chunks(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> int:
    """MACT cold-start chunk choice for the paper-faithful baseline (worst
    case s' -> e*s*k against the TPU v5e profile)."""
    if cfg.moe is None or shape.mode != "train":
        return 1
    dims = mesh_dims(mesh)
    model_ax = dims.get("model", 1)
    batch_div = dims.get("data", 1) * dims.get("pod", 1)
    b = max(1, shape.global_batch // batch_div)
    if cfg.moe.num_experts % model_ax == 0:
        par = Parallelism(e=model_ax, b=b)      # ep_shardmap strategy
    else:
        par = Parallelism(t=model_ax, e=1, b=b) # tp_gspmd fallback
    mact = MACTController(cfg, par, TPU_V5E, seq_len=shape.seq_len)
    return mact.choose()


def _with_shardings(tree_sds, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_shardings)


def _zero1_shardings(p_shard, state_sds, mesh: Mesh):
    """ZeRO-1-style optimizer-state sharding: extend each param's spec with
    the data axes on the first unsharded, divisible dim (mu/nu are only
    touched at the optimizer step, so the extra gather cost is per-step)."""
    ba = shd.batch_axes(mesh)
    n = shd.axis_size(mesh, ba)

    def extend(sharding, leaf):
        spec = list(sharding.spec) + [None] * (len(leaf.shape) - len(sharding.spec))
        for i, (s, d) in enumerate(zip(spec, leaf.shape)):
            if s is None and d % n == 0 and d >= n:
                spec[i] = ba
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(extend, p_shard, state_sds.params)


def abstract_train_args(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                        dtype=jnp.bfloat16, flags: Optional[dict] = None):
    flags = flags or {}
    state_sds = jax.eval_shape(
        lambda k: init_train_state(k, cfg, dtype=dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = shd.param_shardings(state_sds.params, mesh, cfg)
    opt_shard = (_zero1_shardings(p_shard, state_sds, mesh)
                 if flags.get("opt_shard_data") else p_shard)
    state_shardings = type(state_sds)(
        params=p_shard,
        opt=type(state_sds.opt)(
            step=NamedSharding(mesh, P()),
            mu=opt_shard, nu=opt_shard),
        step=NamedSharding(mesh, P()),
    )
    state_abs = _with_shardings(state_sds, state_shardings)

    batch_sds = make_batch_specs(cfg, shape, dtype=jnp.bfloat16)
    B = shape.global_batch
    batch_shardings = {
        k: NamedSharding(mesh, shd.batch_pspec(mesh, B) if v.ndim == 2
                         else P(shd.guarded(mesh, B, shd.batch_axes(mesh)),
                                None, None))
        for k, v in batch_sds.items()}
    batch_abs = _with_shardings(batch_sds, batch_shardings)
    return state_abs, batch_abs


def abstract_params(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    p_sds = jax.eval_shape(
        lambda k: transformer.init_params(k, cfg, dtype=dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    return _with_shardings(p_sds, shd.param_shardings(p_sds, mesh, cfg))


def abstract_cache(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                   params_abs, dtype=jnp.bfloat16):
    B, S = shape.global_batch, shape.seq_len
    enc_abs = None
    if cfg.encoder_layers:
        enc_abs = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dtype)
    cache_sds = jax.eval_shape(
        functools.partial(transformer.init_cache, cfg=cfg, batch_size=B,
                          seq_len=S, dtype=dtype),
        params=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                            params_abs),
        enc_out=enc_abs)
    cache_shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, shd.cache_pspec(mesh, s.shape, B)),
        cache_sds)
    return _with_shardings(cache_sds, cache_shardings)


# ---------------------------------------------------------------------------
# lowering per mode
# ---------------------------------------------------------------------------

def lower_combo(arch: str, shape_name: str, mesh: Mesh, *,
                chunks: Optional[int] = None, strategy: str = "auto",
                dtype=jnp.bfloat16, extra_cfg: Optional[dict] = None,
                flags: Optional[dict] = None):
    """Lower the step for one (arch, shape) on ``mesh``; returns (lowered, meta)."""
    cfg = get_config(arch)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not long_context_eligible(cfg):
        raise SkipCombo(f"{arch} is full-attention — long_500k skipped "
                        f"(docs/DESIGN.md §4)")
    cfg, ctx = build_context(cfg, shape, mesh, chunks=chunks, strategy=strategy,
                             flags=flags)
    meta = {"arch": arch, "shape": shape_name, "mode": shape.mode,
            "mesh_dims": dict(mesh_dims(mesh)), "chunks": ctx.moe_chunks,
            "flags": dict(flags or {}),
            "dtype": str(dtype.__name__ if hasattr(dtype, '__name__') else dtype)}

    with compat.set_mesh(mesh):
        if shape.mode == "train":
            state_abs, batch_abs = abstract_train_args(cfg, shape, mesh, dtype,
                                                       flags=flags)
            step = make_train_step(cfg, ctx, lr=1e-4)
            lowered = jax.jit(step).lower(state_abs, batch_abs)
        elif shape.mode == "prefill":
            params_abs = abstract_params(cfg, mesh, dtype)
            batch_sds = make_batch_specs(cfg, shape, dtype=dtype)
            batch_sds.pop("labels")
            B = shape.global_batch
            batch_abs = _with_shardings(batch_sds, {
                k: NamedSharding(mesh, shd.batch_pspec(mesh, B) if v.ndim == 2
                                 else P(shd.guarded(mesh, B, shd.batch_axes(mesh)),
                                        None, None))
                for k, v in batch_sds.items()})

            def prefill_step(params, batch):
                logits, _ = transformer.forward(params, cfg, ctx, batch)
                return logits

            lowered = jax.jit(prefill_step).lower(params_abs, batch_abs)
        else:  # decode
            params_abs = abstract_params(cfg, mesh, dtype)
            cache_abs = abstract_cache(cfg, shape, mesh, params_abs, dtype)
            B = shape.global_batch
            tok_abs = jax.ShapeDtypeStruct(
                (B, 1), jnp.int32,
                sharding=NamedSharding(mesh, shd.batch_pspec(mesh, B)))
            step = make_serve_step(cfg, ctx)
            lowered = jax.jit(step).lower(params_abs, cache_abs, tok_abs)
    return lowered, meta


class SkipCombo(Exception):
    pass


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def analyse(lowered, compiled, hw=TPU_V5E, chips: int = 1) -> dict:
    from repro.launch import hlo_analysis
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):       # old jax: one dict per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    # scan-aware re-derivation: cost_analysis counts while bodies ONCE, which
    # under-reports layer-scanned models by the trip count (docs/DESIGN.md §7)
    scan = hlo_analysis.analyse_module(txt)
    flops = float(scan["flops"]) or float(ca.get("flops", 0.0))
    bytes_acc = float(scan["hbm_bytes"]) or float(ca.get("bytes accessed", 0.0))
    coll_total = float(scan["collective_total"]) or coll["total_bytes"]
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = coll_total / hw.ici_bw
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_gb": (ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes) / 1e9,
            "fits_v5e": (ma.argument_size_in_bytes + ma.temp_size_in_bytes)
                        < hw.alpha * hw.hbm_bytes,
        },
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc,
                 "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
                 "xla_cost_analysis_bytes": float(ca.get("bytes accessed", 0.0))},
        "collectives": {**coll, "scan_aware": scan["collective_bytes"],
                        "total_bytes": coll_total},
        "roofline": {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
        },
    }
