"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke \
      --steps 50 --seq-len 128 --global-batch 8 [--no-mact] [--chunks 4]

On this CPU container you train the ``--smoke`` reduced variants (the full
configs are exercised by the dry-run); on a TPU deployment the same launcher
drives the full config over ``make_production_mesh()`` with --mesh prod.
"""

from __future__ import annotations

import argparse
import dataclasses
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--chunks", type=int, default=1)
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="max FCDA schedule depth MACT may pick (>=2 overlaps "
                         "chunk all-to-alls with expert compute on the EP "
                         "path); with --no-mact, the fixed depth to run")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="force the sequential FCDA chunk loop")
    ap.add_argument("--no-mact", action="store_true")
    ap.add_argument("--adaptive-mact", action="store_true",
                    help="per-layer (bin, depth) schedules from the online "
                         "expert-load telemetry EMA (docs/DESIGN.md §Adaptive)")
    ap.add_argument("--replan-interval", type=int, default=1,
                    help="steps between adaptive MACT re-plans")
    ap.add_argument("--mact-hysteresis", type=float, default=0.1,
                    help="load-margin hysteresis band; a layer's schedule "
                         "only moves when the re-plan survives (1+h)x load "
                         "noise or memory safety forces it")
    ap.add_argument("--mact-headroom", type=float, default=0.2,
                    help="plan each layer for (1+this)*EMA load — the margin "
                         "that keeps a drifting layer's schedule ahead of "
                         "its load between re-plans")
    ap.add_argument("--placement", action="store_true",
                    help="telemetry-driven expert placement: re-home (and "
                         "with --placement-replicas, replicate) experts "
                         "across EP peers at replan boundaries "
                         "(docs/DESIGN.md §Placement)")
    ap.add_argument("--placement-replicas", type=int, default=0,
                    help="extra hot-expert weight slots per EP peer")
    ap.add_argument("--placement-hysteresis", type=float, default=0.1,
                    help="min fractional bottleneck improvement before a "
                         "layer's placement moves (anti-flapping)")
    ap.add_argument("--remat", default=None, choices=["none", "full", "memfine"])
    ap.add_argument("--mesh", default="local", choices=["local", "prod", "prod-mp"])
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--fused", action="store_true",
                    help="single-launch fused MoE expert leg over the ragged "
                         "layout (kernels/fused_moe.py); MACT plans with the "
                         "reduced Eq. 2 term")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true",
                    help="self-healing restart: restore the newest VALID "
                         "checkpoint in --checkpoint-dir (corrupt saves are "
                         "skipped) and train until --steps total steps")
    ap.add_argument("--max-oom-retries", type=int, default=4,
                    help="degradation-ladder bound per step (docs/DESIGN.md "
                         "§Resilience)")
    ap.add_argument("--inject", default=None,
                    help="chaos faults, e.g. 'oom@3,burst@2x1.5,"
                         "ckpt_truncate@4' (kind@step[xMAG][*TIMES])")
    ap.add_argument("--log-json", default=None)
    args = ap.parse_args()

    import jax
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.runtime.faults import FaultInjector
    from repro.training.trainer import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if args.remat:
        cfg = dataclasses.replace(cfg, remat_policy=args.remat)

    mesh = None
    if args.mesh != "local":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-mp")
    depth = 1 if args.no_pipeline else args.pipeline_depth
    ctx = DistContext(mesh=mesh, moe_chunks=args.chunks,
                      pipeline_chunks=depth if args.no_mact else 1,
                      use_pallas=args.use_pallas, moe_fused=args.fused)
    trainer = Trainer(cfg, ctx, seq_len=args.seq_len,
                      global_batch=args.global_batch, lr=args.lr,
                      use_mact=not args.no_mact,
                      max_pipeline_depth=depth,
                      adaptive_mact=args.adaptive_mact,
                      replan_interval=args.replan_interval,
                      mact_hysteresis=args.mact_hysteresis,
                      mact_headroom=args.mact_headroom,
                      use_placement=args.placement,
                      placement_replicas=args.placement_replicas,
                      placement_hysteresis=args.placement_hysteresis,
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every=args.checkpoint_every,
                      resume=args.resume,
                      max_oom_retries=args.max_oom_retries,
                      injector=(FaultInjector.from_string(args.inject)
                                if args.inject else None))
    state = trainer.fit(args.steps, verbose=True)
    if trainer.resumed_from is not None:
        print(f"resumed from checkpoint step {trainer.resumed_from}")
    if trainer.guard.escalations:
        print(f"OOM ladder: {len(trainer.guard.escalations)} escalation(s), "
              f"headroom now {trainer.mact_headroom:.2f}")
    if trainer.log:
        print(f"final loss {trainer.log[-1]['loss']:.4f} at step "
              f"{int(state.step)}; "
              f"chunk trace tail {trainer.chunk_trace[-8:]}; "
              f"pipeline trace tail {trainer.pipeline_trace[-8:]}")
    else:
        print(f"nothing to do: checkpoint already at step {int(state.step)} "
              f">= target {args.steps}")
    if args.placement and trainer.placement_trace:
        last = trainer.placement_trace[-1]
        imb = last["imbalance"]
        print(f"placement: {len(trainer.placement_trace)} replan(s), last "
              f"moved {last['migrated_slots']} slots "
              f"({last['migrated_bytes'] / 2**20:.1f} MiB), imbalance "
              f"{'n/a' if imb is None else f'{max(imb):.2f}'}")
    if args.adaptive_mact and trainer.schedule_trace:
        last = trainer.schedule_trace[-1]
        print(f"adaptive layer schedules (last plan): "
              f"{[tuple(s) for s in last]}; "
              f"compiles {trainer.compile_count}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(trainer.log, f, indent=1)


if __name__ == "__main__":
    main()
