"""Serving launcher: continuous batching over a synthetic Poisson trace.

Requests arrive as a Poisson process with per-request prompt/generation
lengths; the continuous-batching scheduler (docs/DESIGN.md §Serving) admits
them against the serving memory model, interleaves chunked prefill with
decode waves, and the run reports aggregate tok/s, p50/p99 request latency
and the modeled-peak-vs-budget memory headroom.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --smoke \
      --requests 16 --arrival-rate 4 --max-slots 4 --budget-gb 32
"""

from __future__ import annotations

import argparse


def make_trace(rng, n: int, rate_hz: float, prompt_lens, gen_range,
               vocab: int, chunk: int):
    """n Poisson arrivals; prompt lengths are drawn from ``prompt_lens``
    (multiples of the prefill chunk, so every chunk shape compiles once)."""
    import numpy as np
    from repro.serving.scheduler import Request

    for S in prompt_lens:
        if S % chunk and S > chunk:
            raise ValueError(
                f"--prompt-lens entry {S} is not a multiple of "
                f"--prefill-chunk {chunk}; chunk shapes would re-trace")
    t = 0.0
    out = []
    for i in range(n):
        t += rng.exponential(1.0 / rate_hz) if rate_hz > 0 else 0.0
        S = int(rng.choice(prompt_lens))
        out.append(Request(
            rid=i,
            tokens=rng.integers(0, vocab, S).astype(np.int32),
            max_new_tokens=int(rng.integers(gen_range[0], gen_range[1] + 1)),
            arrival=t))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (2 layers, small dims)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="Poisson arrival rate (requests/s); 0 = all at t=0")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="per-request cache length (0 = max prompt + gen)")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--prompt-lens", default="16,32,48,64",
                    help="comma list of prompt lengths to draw from")
    ap.add_argument("--gen", default="4,24", help="min,max generated tokens")
    ap.add_argument("--budget-gb", type=float, default=0.0,
                    help="override the hardware memory budget (GB)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="admission deadline: a request not admitted within "
                         "this many seconds of arrival is shed with a "
                         "retry-after quote (docs/DESIGN.md §Resilience)")
    ap.add_argument("--max-waiting", type=int, default=0,
                    help="overload bound on the WAITING queue (0 = off)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="paged cache: tokens per page (0 = monolithic "
                         "slot map; docs/DESIGN.md §Paging)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share common prompt prefixes through the "
                         "page-level trie (requires --page-size)")
    ap.add_argument("--preemption", action="store_true",
                    help="spill low-priority residents to host when "
                         "admission is refused (requires --page-size)")
    ap.add_argument("--placement-peers", type=int, default=0,
                    help="choose a static expert placement over this many EP "
                         "peers at engine build, from --placement-loads "
                         "(docs/DESIGN.md §Placement); 0 = identity")
    ap.add_argument("--placement-loads", default=None,
                    help="JSON file with a (L_moe, E) load matrix (e.g. a "
                         "training run's telemetry EMA) the placement is "
                         "solved from; omitted = identity")
    ap.add_argument("--placement-replicas", type=int, default=0,
                    help="extra hot-expert weight slots per peer; their "
                         "weight bytes are priced by admission control")
    ap.add_argument("--expert-batching", action="store_true",
                    help="group decode waves by predicted expert overlap "
                         "instead of FIFO age order (MoE archs only; "
                         "docs/DESIGN.md §Residency)")
    ap.add_argument("--wave-size", type=int, default=0,
                    help="max members per decode wave (0 = every resident); "
                         ">0 engages the masked subset step")
    ap.add_argument("--max-wave-wait", type=int, default=4,
                    help="starvation guard: a resident that skipped this "
                         "many waves is force-included in the next one")
    ap.add_argument("--resident-experts", type=int, default=0,
                    help="per-MoE-layer resident expert capacity; cold "
                         "experts are host-offloaded and prefetched ahead "
                         "of the wave (0 = all resident)")
    ap.add_argument("--probe-router", action="store_true",
                    help="router-only probe on prompt tokens seeds the "
                         "prefetch prediction before telemetry exists")
    ap.add_argument("--inject", default=None,
                    help="chaos faults on scheduler steps, e.g. 'oom@20' "
                         "(faulted decode waves requeue accepted requests)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.configs.base import GPU_64G
    from repro.core.moe import DistContext
    from repro.models import transformer
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    ctx = DistContext()
    replica_bytes = 0.0
    if args.placement_peers:
        import json as _json

        from repro.serving.engine import build_placements
        loads = None
        if args.placement_loads:
            with open(args.placement_loads) as f:
                loads = np.asarray(_json.load(f), dtype=np.float64)
        ctx, replica_bytes = build_placements(
            cfg, ctx, args.placement_peers, loads=loads,
            replicas=args.placement_replicas)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(args.seed)
    prompt_lens = [int(s) for s in args.prompt_lens.split(",")]
    gen_lo, gen_hi = (int(s) for s in args.gen.split(","))
    trace = make_trace(rng, args.requests, args.arrival_rate, prompt_lens,
                       (gen_lo, gen_hi), cfg.vocab_size, args.prefill_chunk)

    cache_len = args.cache_len or max(prompt_lens) + gen_hi
    hw = GPU_64G
    if args.budget_gb:
        # the flag names the admission budget itself, so alpha must not
        # discount it a second time
        hw = dataclasses.replace(hw, hbm_bytes=args.budget_gb * 1e9,
                                 alpha=1.0)
    if (args.prefix_cache or args.preemption) and not args.page_size:
        raise SystemExit("--prefix-cache/--preemption require --page-size")
    scfg = ServeConfig(max_slots=args.max_slots, cache_len=cache_len,
                       prefill_chunk=args.prefill_chunk, hw=hw,
                       temperature=args.temperature,
                       deadline_s=args.deadline_s,
                       max_waiting=args.max_waiting,
                       page_size=args.page_size,
                       prefix_cache=args.prefix_cache,
                       preemption=args.preemption,
                       replica_weight_bytes=replica_bytes,
                       expert_batching=args.expert_batching,
                       wave_size=args.wave_size,
                       max_wave_wait=args.max_wave_wait,
                       resident_experts=args.resident_experts,
                       probe_router=args.probe_router)

    injector = None
    if args.inject:
        from repro.runtime.faults import FaultInjector
        injector = FaultInjector.from_string(args.inject)
    if args.page_size:
        from repro.serving.paged_scheduler import PagedScheduler
        sched = PagedScheduler(params, cfg, ctx, scfg,
                               key=jax.random.PRNGKey(args.seed),
                               injector=injector)
    else:
        sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg,
                                            key=jax.random.PRNGKey(args.seed),
                                            injector=injector)
    mode = (f"paged(page={args.page_size}, prefix={args.prefix_cache}, "
            f"preempt={args.preemption})" if args.page_size else "slot-map")
    if args.placement_peers and ctx.placements is not None:
        placed = sum(1 for p in ctx.placements if not p.is_identity)
        print(f"placement: {placed}/{len(ctx.placements)} layers re-homed "
              f"over {args.placement_peers} peers, replica weights "
              f"{replica_bytes / 1e9:.3f} GB priced by admission")
    print(f"serving {cfg.name}: {args.requests} requests, "
          f"rate={args.arrival_rate}/s, slots={args.max_slots}, "
          f"cache_len={cache_len}, prefill_chunk={args.prefill_chunk}, "
          f"{mode}")
    m = sched.run(trace)

    budget_gb = m["budget_bytes"] / 1e9
    peak_gb = m["modeled_peak_bytes"] / 1e9
    print(f"served {m['requests']} requests, {m['generated_tokens']} tokens "
          f"in {m['elapsed_s']:.2f}s -> {m['tok_per_s']:.1f} tok/s")
    print(f"latency p50={m['latency_p50_s']:.2f}s p99={m['latency_p99_s']:.2f}s "
          f"(gen {gen_lo}-{gen_hi} tokens/request)")
    print(f"memory: modeled peak {peak_gb:.2f} GB <= budget {budget_gb:.2f} GB "
          f"(headroom {budget_gb - peak_gb:.2f} GB), "
          f"max occupancy {m['max_occupancy']}/{args.max_slots} slots")
    print(f"schedule: {m['decode_waves']} decode waves, "
          f"{m['prefill_chunks']} interleaved prefill chunks")
    if m["expert_waves"]:
        print(f"expert waves: {m['expert_waves']} waves, mean "
              f"{m['mean_distinct_experts']:.2f} distinct experts / "
              f"{m['mean_wave_occupancy']:.2f} members per wave, "
              f"{m['forced_includes']} starvation force-includes")
    if "residency" in m:
        r = m["residency"]
        print(f"residency: {args.resident_experts} resident experts/layer "
              f"(hwm {r['resident_experts_hwm']}), prefetch "
              f"{m['prefetch_hits']} hits / {m['prefetch_misses']} misses, "
              f"{r['restores']} restores ({r['demand_restores']} on demand, "
              f"{m['demand_reruns']} re-runs), {r['offloads']} offloads")
    if args.page_size:
        extra = ""
        if args.prefix_cache:
            extra = (f", prefix hit rate {m['prefix_hit_rate']:.2f} "
                     f"({m['prefix_tokens_reused']} tokens reused)")
        print(f"paging: page high-watermark {m['page_hwm_bytes'] / 1e9:.3f} GB"
              f", {m['preemptions']} preemptions{extra}")
    if m["shed"] or m["faults"]:
        print(f"resilience: {m['shed']} shed "
              f"(retry-after p50 {m['retry_after_p50_s']:.1f}s), "
              f"{m['faults']} faulted waves, {m['requeues']} requeues, "
              f"0 accepted requests lost")
    if sched.finished:
        sample = sched.finished[0]
        print(f"sample (rid {sample.rid}): {sample.out[:12]}")


if __name__ == "__main__":
    main()
