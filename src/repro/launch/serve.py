"""Serving launcher: batched prefill + decode on a (reduced) config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-27b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.core.moe import DistContext
    from repro.data.pipeline import SyntheticLMData
    from repro.models import transformer
    from repro.serving.engine import generate

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    ctx = DistContext()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLMData(cfg, args.prompt_len, args.batch)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()
             if k != "labels"}
    t0 = time.perf_counter()
    out = generate(params, cfg, ctx, batch, steps=args.gen,
                   cache_len=args.prompt_len + args.gen,
                   temperature=args.temperature,
                   key=jax.random.PRNGKey(1))
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
