"""Scan-aware analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
that scans over layers under-reports FLOPs/bytes/collectives by the trip
count (observed 80x on internvl2-76b).  This module re-derives the roofline
inputs from the optimized HLO text, weighting every computation by the
product of its enclosing loops' ``known_trip_count``s:

  * flops            — 2 * prod(output dims) * prod(contracted lhs dims) per
                       ``dot`` (matmul-dominated models; elementwise ignored)
  * hbm bytes        — operand + output bytes of top-level instructions
                       (fusion internals stay on-chip and are not counted;
                       the fusion call's operands/outputs are)
  * collective bytes — output bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute
                       (start/done pairs counted once)

All values are per device (the module is the SPMD-partitioned one).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INST_RE = re.compile(
    r"^\s+(?:ROOT )?%?([\w.\-]+) = (\([^)]*\)|\S+) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')


def shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Inst:
    name: str
    shape: str
    op: str
    rest: str
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    insts: dict = field(default_factory=dict)       # name -> Inst
    order: list = field(default_factory=list)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line and not line.startswith(" ") and "{" in line:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                # parameters carry shapes in the signature
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\],{}/ ]+?)(?:,|\)$|\)\s*->)",
                                      line):
                    cur.insts[pm.group(1)] = Inst(pm.group(1), pm.group(2),
                                                  "parameter", "")
            continue
        m = _INST_RE.match(line)
        if m and cur is not None:
            inst = Inst(m.group(1), m.group(2), m.group(3), m.group(4))
            inst.operands = re.findall(r"%([\w.\-]+)", m.group(4))
            cur.insts[inst.name] = inst
            cur.order.append(inst.name)
    return comps


def _dot_flops(inst: Inst, comp: Computation) -> float:
    dims = shape_dims(inst.shape)
    if not dims:
        return 0.0
    out_elems = 1
    for d in dims[0][1]:
        out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    contracted = 1
    if m and inst.operands:
        lhs = comp.insts.get(inst.operands[0])
        if lhs is not None:
            lhs_dims = shape_dims(lhs.shape)
            if lhs_dims:
                for idx in (int(i) for i in m.group(1).split(",") if i):
                    if idx < len(lhs_dims[0][1]):
                        contracted *= lhs_dims[0][1][idx]
    return 2.0 * out_elems * contracted


def _called(inst: Inst) -> list[str]:
    out = re.findall(r"(?:calls|body|to_apply|true_computation|"
                     r"false_computation)=%?([\w.\-]+)", inst.rest)
    m = re.search(r"branch_computations=\{([^}]*)\}", inst.rest)
    if m:  # pl.when predication lowers to conditionals; count all branches
        out += re.findall(r"%?([\w.\-]+)", m.group(1))
    return out


def analyse_module(text: str) -> dict:
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].order)) if comps else None
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0,
                "collective_bytes": {}, "collective_total": 0.0}

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def totals(comp_name: str, top_level: bool) -> tuple:
        comp = comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, ())
        flops = hbm = 0.0
        coll: dict[str, float] = {}
        for iname in comp.order:
            inst = comp.insts[iname]
            op = inst.op
            if op == "dot":
                flops += _dot_flops(inst, comp)
                if top_level:
                    hbm += shape_bytes(inst.shape) + sum(
                        shape_bytes(comp.insts[o].shape)
                        for o in inst.operands if o in comp.insts)
            elif op == "fusion":
                for c in _called(inst):
                    f, _, cc = totals(c, False)
                    flops += f
                    for k, v in cc:
                        coll[k] = coll.get(k, 0.0) + v
                if top_level:
                    hbm += shape_bytes(inst.shape) + sum(
                        shape_bytes(comp.insts[o].shape)
                        for o in inst.operands if o in comp.insts)
            elif op == "while":
                m = _TRIP_RE.search(inst.rest)
                trips = int(m.group(1)) if m else 1
                body = re.search(r"body=%?([\w.\-]+)", inst.rest)
                if body:
                    f, h, cc = totals(body.group(1), True)
                    flops += trips * f
                    hbm += trips * h
                    for k, v in cc:
                        coll[k] = coll.get(k, 0.0) + trips * v
            elif op in ("call", "conditional", "custom-call"):
                for c in _called(inst):
                    f, h, cc = totals(c, top_level)
                    flops += f
                    hbm += h
                    for k, v in cc:
                        coll[k] = coll.get(k, 0.0) + v
                if top_level:
                    hbm += shape_bytes(inst.shape)
            else:
                base = op.replace("-start", "").replace("-done", "")
                if base in COLLECTIVES:
                    if not op.endswith("-done"):
                        coll[base] = coll.get(base, 0.0) + shape_bytes(inst.shape)
                elif top_level and op in ("dynamic-slice", "gather"):
                    # reads only the sliced/gathered region, not the operand
                    hbm += 2 * shape_bytes(inst.shape)
                elif top_level and op == "dynamic-update-slice":
                    # read+write of the updated region (the full-tensor copy
                    # XLA sometimes emits is an implementation artifact)
                    upd = (shape_bytes(comp.insts[inst.operands[1]].shape)
                           if len(inst.operands) > 1
                           and inst.operands[1] in comp.insts else 0)
                    hbm += 2 * upd
                elif top_level and op not in ("parameter", "constant",
                                              "get-tuple-element", "tuple",
                                              "bitcast", "copy"):
                    # "copy" excluded: loop-carry copies are elided/in-place
                    # on TPU; counting them dominates interpret-mode kernels
                    hbm += shape_bytes(inst.shape) + sum(
                        shape_bytes(comp.insts[o].shape)
                        for o in inst.operands if o in comp.insts)
        return (flops, hbm, tuple(sorted(coll.items())))

    flops, hbm, coll = totals(entry, True)
    coll_d = dict(coll)
    return {"flops": flops, "hbm_bytes": hbm, "collective_bytes": coll_d,
            "collective_total": float(sum(coll_d.values()))}
