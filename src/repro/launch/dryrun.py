import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) combo.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.  Smoke
tests and benches do NOT import this module (they see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per combo it writes JSON with memory_analysis, cost_analysis, the collective
schedule and the roofline terms (docs/DESIGN.md §Dry-run / §Roofline read
these).
"""

import argparse
import json
import time
import traceback


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            chunks=None, strategy="auto", tag="", flags=None) -> dict:
    import jax
    from repro.configs.base import TPU_V5E
    from repro.launch import dryrun_lib as lib
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "status": "ok",
           "tag": tag}
    try:
        lowered, meta = lib.lower_combo(arch, shape_name, mesh, chunks=chunks,
                                        strategy=strategy, flags=flags)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec.update(meta)
        rec.update(lib.analyse(lowered, compiled, TPU_V5E, chips))
        rec["t_lower_s"] = round(t_lower, 1)
        rec["t_compile_s"] = round(t_compile, 1)
        mem = rec["memory"]
        print(f"[ok] {arch} x {shape_name} x {rec['mesh']}: "
              f"peak/device {mem['peak_device_gb']:.2f} GB, "
              f"dominant={rec['roofline']['dominant']}, "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s", flush=True)
        print(f"     memory_analysis: args {mem['argument_bytes']/1e9:.2f} GB + "
              f"temp {mem['temp_bytes']/1e9:.2f} GB", flush=True)
        print(f"     cost_analysis: {rec['cost']['flops_per_device']:.3e} "
              f"FLOPs/dev, {rec['cost']['bytes_per_device']:.3e} B/dev, "
              f"coll {rec['collectives']['total_bytes']/1e9:.3f} GB/dev", flush=True)
    except lib.SkipCombo as e:
        rec.update(status="skipped", reason=str(e))
        print(f"[skip] {arch} x {shape_name}: {e}", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[ERR] {arch} x {shape_name} x {rec['mesh']}: {e}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "_mp" if multi_pod else ""
        suffix += f"_{tag}" if tag else ""
        path = os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    from repro.configs.base import SHAPES, registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--chunks", type=int, default=None)
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--flag", action="append", default=[],
                    help="optimization knob, e.g. --flag seq_shard_acts=1 "
                         "--flag prefill_chunks=8 --flag opt_shard_data=1")
    args = ap.parse_args()
    flags = {}
    for kv in args.flag:
        k, _, v = kv.partition("=")
        flags[k] = int(v) if v.lstrip("-").isdigit() else v

    if args.all:
        combos = [(a, s) for a in sorted(registry()) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]
    n_ok = n_fail = 0
    for arch, shape in combos:
        rec = run_one(arch, shape, args.multi_pod, args.out,
                      chunks=args.chunks, strategy=args.strategy, tag=args.tag,
                      flags=flags)
        n_ok += rec["status"] in ("ok", "skipped")
        n_fail += rec["status"] == "error"
    print(f"done: {n_ok} ok/skipped, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
