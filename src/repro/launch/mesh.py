"""Production meshes.  A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) ("data", "model").
    Multi-pod: 2 pods = 512 chips (2, 16, 16) ("pod", "data", "model") —
    "pod" is an outer data-parallel axis crossing the inter-pod links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this itself)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over whatever local devices exist (tests)."""
    import numpy as np
    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
