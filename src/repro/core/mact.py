"""MACT — Memory-Aware Chunk Tuning (paper §4.2).

Before training, MACT models memory from the config (Eq. 1-2), inverts it for
the max admissible per-device token count s'_max (Eq. 8), and derives the
optimal chunk count c = ceil(s''/s'_max) (Eq. 9) from the predicted/observed
received tokens s''.  Because re-deriving c exactly each step is wasteful
(and, under XLA, each distinct c is a recompile), MACT snaps c to a bin from
a threshold set — we follow the paper's [1, 2, 4, 8] — and adjusts the bin
dynamically as the routing distribution evolves.

On host, between steps: the trainer feeds back the per-layer expert load
vector from the previous step; ``observed_s_pp`` turns it into the worst
per-device received-token count; ``choose`` returns the bin.  Compiled step
variants are cached per bin by the trainer (<= len(bins) compilations).

``choose_schedule`` extends the choice to the pipelined FCDA schedule
(docs/DESIGN.md §Pipeline): it picks (chunk bin, pipeline depth) jointly,
preferring the overlapped schedule when its extra live chunk still fits the
memory model and falling back to the sequential loop otherwise.

``choose_layer_schedules`` is the adaptive per-layer extension
(docs/DESIGN.md §Adaptive): fed the telemetry EMA of per-layer expert-load
histograms (core/telemetry.py), it resolves one ``ScheduleSpec`` per MoE
layer through the same Eq. 2/7/9 model, with load-margin hysteresis so a
layer's schedule only moves when the re-plan is either forced by memory
safety or stable under ``(1 + hysteresis)`` load noise — schedules never
flap on boundary jitter.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import HardwareProfile, ModelConfig
from repro.core import memory_model as mm
from repro.core.chunking import ScheduleSpec


@dataclass
class MACTController:
    cfg: ModelConfig
    par: mm.Parallelism
    hw: HardwareProfile
    seq_len: int
    bins: Sequence[int] = (1, 2, 4, 8)
    copies: int = 1                      # m_g: stored activation copies
    dtype_bytes: int = 2
    bytes_per_param: float = mm.TRAIN_STATE_BYTES
    static_override: Optional[float] = None   # use a *measured* M_sta instead
    fused: bool = False                  # fused expert leg: Eq. 2/8 lose the
                                         # 2h dispatch-buffer term, so s'_max
                                         # grows and the planner picks coarser
                                         # bins (docs/DESIGN.md §6)
    replica_slots: int = 0               # hot-expert replica weight slots per
                                         # peer (docs/DESIGN.md §Placement):
                                         # their weight bytes come off the
                                         # Eq. 8 budget, their load cut shows
                                         # up through observed_s_pp(placement)
    history: list = field(default_factory=list)

    def __post_init__(self):
        self.dims = mm.LayerDims.from_config(self.cfg)
        self.static = (self.static_override if self.static_override is not None
                       else mm.static_bytes(self.cfg, self.par, self.bytes_per_param))

    # -- Eq. 8 ---------------------------------------------------------------
    def s_prime_max(self) -> float:
        replica = mm.replica_weight_bytes(self.cfg, self.replica_slots,
                                          self.par)
        return mm.s_prime_max(self.dims, self.seq_len, self.par, self.hw,
                              self.static, copies=self.copies,
                              dtype_bytes=self.dtype_bytes, fused=self.fused,
                              replica_bytes=replica)

    # -- s'' from router statistics -------------------------------------------
    def observed_s_pp(self, load: np.ndarray, ep_size: Optional[int] = None,
                      placement=None) -> float:
        """Worst per-device received-token count from a global expert-load
        vector (token-slots per expert, summed over the step).

        With a ``PlacementSpec`` the per-peer reduction goes *through* the
        placement map (replicated experts' load split across their slots)
        instead of assuming the identity contiguous expert layout
        (docs/DESIGN.md §Placement)."""
        load = np.asarray(load, dtype=np.float64)
        if placement is not None:
            return float(placement.peer_loads(load).max())
        e = ep_size or self.par.e
        if load.size % e:
            raise ValueError(
                f"expert-load vector of size {load.size} does not divide "
                f"into ep_size={e} devices; pass the global per-expert load "
                f"(length a multiple of the EP group size) or the matching "
                f"ep_size")
        per_dev = load.reshape(e, -1).sum(axis=1)
        # normalise to a per-microbatch count on the hottest device
        return float(per_dev.max())

    # -- Eq. 9 + threshold binning --------------------------------------------
    def optimal_c(self, s_pp: float) -> int:
        return mm.optimal_chunks(s_pp, self.s_prime_max())

    def snap(self, c: int) -> int:
        """Paper: "select the large bin that is closest to c" — the smallest
        bin >= c (conservative on memory); the largest bin if none covers."""
        for b in sorted(self.bins):
            if b >= c:
                return b
        return max(self.bins)

    def choose(self, load: Optional[np.ndarray] = None,
               ep_size: Optional[int] = None) -> int:
        """Pick the chunk bin for the next step.

        With no observation yet (step 0) MACT plans for the theoretical worst
        case `s' -> e*s*k` (paper §3) — the safe cold-start the paper uses.
        """
        return self.choose_schedule(load, ep_size, max_depth=1)[0]

    def _schedule_for(self, s_pp: float, max_depth: int = 2) -> ScheduleSpec:
        """Pure Eq. 9 schedule choice for one load estimate (no history)."""
        s_max = self.s_prime_max()
        for depth in range(max(max_depth, 1), 1, -1):
            c = mm.optimal_chunks(s_pp, s_max, pipeline_depth=depth)
            b = self.snap(c)
            # the bin must cover the deeper schedule's chunks AND split into
            # whole waves — otherwise chunked_pipeline would silently run the
            # sequential loop while we charge the pipeline's memory
            if b >= c and b % depth == 0:
                return ScheduleSpec(b, depth)
        return ScheduleSpec(self.snap(self.optimal_c(s_pp)), 1)

    def choose_schedule(self, load: Optional[np.ndarray] = None,
                        ep_size: Optional[int] = None, *,
                        max_depth: int = 2) -> tuple:
        """Jointly pick (chunk bin, pipeline depth) for the next step.

        Eq. (9) extended with the pipeline's extra live chunk: depth d keeps
        d chunks' dispatch buffers resident, so fitting requires
        d * s''/c <= s'_max.  MACT prefers the deepest schedule (overlap =
        throughput) whose chunk requirement a bin still covers, and falls
        back to the sequential schedule when the extra in-flight copy would
        not fit — the paper's memory/throughput trade, second axis.
        """
        if load is None:
            s_pp = mm.worst_case_s_prime(self.seq_len, self.par, self.dims.topk)
        else:
            s_pp = self.observed_s_pp(load, ep_size)
        sched = self._schedule_for(s_pp, max_depth)
        c = mm.optimal_chunks(s_pp, self.s_prime_max(),
                              pipeline_depth=sched.depth)
        self.history.append({"s_pp": s_pp, "c_star": c, "bin": sched.chunks,
                             "depth": sched.depth})
        return tuple(sched)

    # -- adaptive per-layer scheduling (docs/DESIGN.md §Adaptive) --------------
    def schedule_space(self, max_depth: int = 2) -> tuple:
        """Every per-layer schedule the controller can ever emit — the
        bucketed key space that provably bounds the trainer's recompiles:
        a compiled step exists per distinct schedule *vector*, and each
        vector component comes from this set."""
        space = [ScheduleSpec(b, 1) for b in sorted(self.bins)]
        for depth in range(2, max(max_depth, 1) + 1):
            space += [ScheduleSpec(b, depth) for b in sorted(self.bins)
                      if b >= depth and b % depth == 0]
        return tuple(space)

    def _admissible(self, sched: ScheduleSpec, s_pp: float) -> bool:
        """Does ``sched`` still fit the memory model at load ``s_pp``?  True
        iff its bin covers the Eq. 9 chunk requirement at its depth."""
        c = mm.optimal_chunks(s_pp, self.s_prime_max(),
                              pipeline_depth=sched.depth)
        return sched.chunks >= c

    def choose_layer_schedules(self, loads: Optional[np.ndarray],
                               num_layers: int,
                               ep_size: Optional[int] = None, *,
                               max_depth: int = 2,
                               current: Optional[Sequence[ScheduleSpec]] = None,
                               hysteresis: float = 0.0,
                               headroom: float = 0.0,
                               placements: Optional[Sequence] = None) -> tuple:
        """Resolve one ``ScheduleSpec`` per MoE layer from per-layer loads.

        ``loads`` is the telemetry EMA matrix ``(num_layers, E)`` (or None at
        cold start, which plans every layer for the worst case — the same
        safe start as the global path).  ``headroom`` inflates every layer's
        load estimate to ``(1 + headroom) * s''`` before choosing: the EMA
        trails a drifting distribution and the plan stays in force for a
        whole re-plan interval, so the margin is what keeps a ramping layer's
        schedule ahead of its load between plans.  ``current`` is the vector
        in force; with it, load-margin hysteresis applies per layer:

        * memory safety — if the incumbent schedule no longer covers the
          layer's Eq. 9 chunk requirement, switch immediately;
        * stability — otherwise adopt the candidate only if it is also the
          choice at ``(1 + hysteresis) * s_pp``, i.e. the re-plan survives
          the hysteresis band of load noise.  The memory model is monotone
          in s'', so this is exactly "the predicted memory delta clears the
          threshold" expressed on the load axis.

        ``placements`` (one PlacementSpec per layer, docs/DESIGN.md
        §Placement) routes each layer's per-peer load reduction through its
        placement map: a placed/replicated layer sees a lower s'' and so
        prices a cheaper schedule — the MACT side of the placement trade.

        Returns a tuple of ``ScheduleSpec`` (hashable: the trainer's
        compiled-step cache key).
        """
        if loads is None:
            wc = mm.worst_case_s_prime(self.seq_len, self.par, self.dims.topk)
            s_pps = [float(wc)] * num_layers
        else:
            loads = np.asarray(loads, dtype=np.float64)
            if loads.ndim != 2 or loads.shape[0] != num_layers:
                raise ValueError(
                    f"per-layer load matrix of shape {loads.shape}, expected "
                    f"({num_layers}, E)")
            s_pps = [self.observed_s_pp(
                         loads[j], ep_size,
                         placements[j] if placements is not None else None)
                     * (1.0 + headroom)
                     for j in range(num_layers)]
        out = []
        for j, s_pp in enumerate(s_pps):
            cand = self._schedule_for(s_pp, max_depth)
            if current is not None and j < len(current):
                inc = ScheduleSpec(*current[j])
                if cand != inc and self._admissible(inc, s_pp) and (
                        hysteresis > 0.0
                        and self._schedule_for(s_pp * (1.0 + hysteresis),
                                               max_depth) != cand):
                    cand = inc           # inside the hysteresis band: hold
            out.append(cand)
        self.history.append({"s_pp": s_pps, "layer_schedules": tuple(out)})
        return tuple(out)

    # -- reporting -------------------------------------------------------------
    def memory_report(self, s_pp: float, chunks: int,
                      pipeline_depth: int = 1) -> dict:
        act = mm.activation_bytes(self.dims, self.seq_len, s_pp, self.par,
                                  copies=self.copies, chunks=chunks,
                                  dtype_bytes=self.dtype_bytes,
                                  pipeline_depth=pipeline_depth,
                                  fused=self.fused)
        return {
            "static_gb": self.static / 2**30,
            "activation_gb": act / 2**30,
            "total_gb": (self.static + act) / 2**30,
            "fits": mm.fits(self.static, act, self.hw),
            "s_prime_max": self.s_prime_max(),
            "chunks": chunks,
            "pipeline_depth": pipeline_depth,
        }
