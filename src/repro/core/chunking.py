"""FCDA — Fine-grained Chunk Distribution Algorithm (paper §4.1).

Forward (Eq. 6): tokens are split into ``c`` chunks; each chunk runs
dispatch -> expert compute -> combine *sequentially*; outputs concatenate.
Backward (Eq. 7): each chunk is recomputed independently — expressed here as
``jax.checkpoint`` around the chunk body under a sequential ``lax.scan``, so
both the live dispatch buffers and the saved residuals scale with one chunk,
not the whole token set.  Peak MoE activation drops by (c-1)/c (docs/DESIGN.md §2).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def chunked_map(fn: Callable, x: jax.Array, num_chunks: int, *,
                remat: bool = True):
    """Apply ``fn`` chunk-by-chunk over the leading (token) axis of ``x``.

    fn: (chunk_tokens, ...) -> (y_chunk, stats_pytree).  Stats are summed
    across chunks (router loads, aux losses, drop counts are all additive).
    Returns (y, stats) with y matching x's leading axis.
    """
    T = x.shape[0]
    if T % num_chunks:
        raise ValueError(f"token count {T} not divisible by c={num_chunks}")
    body = jax.checkpoint(fn) if remat else fn

    if num_chunks == 1:
        return body(x)

    xs = x.reshape(num_chunks, T // num_chunks, *x.shape[1:])
    # lax.map = sequential scan: only ONE chunk's dispatch buffers are ever
    # live; jax.checkpoint on the body makes the backward pass recompute each
    # chunk independently (Eq. 7).  Stats leaves are tiny — stack, then sum.
    ys, stats = jax.lax.map(body, xs)
    stats = jax.tree.map(lambda s: s.sum(axis=0), stats)
    return ys.reshape(T, *ys.shape[2:]), stats
