"""FCDA — Fine-grained Chunk Distribution Algorithm (paper §4.1).

Forward (Eq. 6): tokens are split into ``c`` chunks; each chunk runs
dispatch -> expert compute -> combine *sequentially*; outputs concatenate.
Backward (Eq. 7): each chunk is recomputed independently — expressed here as
``jax.checkpoint`` around the chunk body under a sequential ``lax.scan``, so
both the live dispatch buffers and the saved residuals scale with one chunk,
not the whole token set.  Peak MoE activation drops by (c-1)/c (docs/DESIGN.md §2).

``chunked_pipeline`` is the overlapped variant (docs/DESIGN.md §Pipeline): the
chunk body is split into explicit stages so consecutive chunks' communication
and compute are mutually data-independent, and chunk liveness is bounded to a
pipeline ``depth`` with ordering barriers instead of a sequential loop.  The
throughput/memory trade is one extra chunk's dispatch buffers live — the
second axis MACT tunes (core/mact.py).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class ScheduleSpec(NamedTuple):
    """One MoE layer's FCDA schedule: ``chunks`` is the MACT-snapped chunk
    bin, ``depth`` the pipeline depth (1 = sequential loop, >= 2 = the
    overlapped wave schedule below).  Hashable and static, so a tuple of
    these — one per MoE layer, threaded through ``DistContext`` — is a valid
    compiled-step cache key (docs/DESIGN.md §Adaptive)."""
    chunks: int
    depth: int = 1


class ChunkStages(NamedTuple):
    """The FCDA chunk body split at its communication boundaries.

    ``dispatch``: (chunk_tokens, ...) -> in-flight pytree.  Routing, dispatch
      planning and the dispatch all-to-all; its output is exactly the state
      that stays live while the chunk waits on expert compute.
    ``compute``: in-flight pytree -> computed pytree.  The expert FFN on the
      received rows (plus pass-through of whatever combine needs).
    ``combine``: computed pytree -> (y_chunk, stats pytree).  The combine
      all-to-all and the weighted reduction back to token order.

    Splitting here (rather than a monolithic chunk_fn) is what lets the
    pipelined schedule issue chunk i+1's dispatch all-to-all while chunk i's
    FFN computes and chunk i-1's combine all-to-all drains: the three calls
    in flight touch disjoint state, so the compiler's latency-hiding
    scheduler may overlap them.
    """
    dispatch: Callable
    compute: Callable
    combine: Callable


def chunk_spans(total: int, chunk: int) -> list[tuple[int, int]]:
    """(start, stop) spans splitting ``total`` tokens into <= ``chunk``-token
    pieces — the serving chunked-prefill decomposition (docs/DESIGN.md
    §Serving).  The same fine-grained-decomposition idea as ``chunked_map``,
    but host-side: the scheduler interleaves one span per decode wave, so a
    long prompt's prefill never holds more than one chunk's activations and
    never stalls running requests for the whole prompt."""
    if chunk <= 0:
        raise ValueError(f"prefill chunk must be positive, got {chunk}")
    return [(i, min(i + chunk, total)) for i in range(0, total, chunk)]


def compose(stages: ChunkStages) -> Callable:
    """The sequential chunk body: combine(compute(dispatch(xc)))."""
    def fn(xc):
        return stages.combine(stages.compute(stages.dispatch(xc)))
    return fn


def chunked_map(fn: Callable, x: jax.Array, num_chunks: int, *,
                remat: bool = True):
    """Apply ``fn`` chunk-by-chunk over the leading (token) axis of ``x``.

    fn: (chunk_tokens, ...) -> (y_chunk, stats_pytree).  Stats are summed
    across chunks (router loads, aux losses, drop counts are all additive).
    Returns (y, stats) with y matching x's leading axis.
    """
    T = x.shape[0]
    if T % num_chunks:
        raise ValueError(f"token count {T} not divisible by c={num_chunks}")
    body = jax.checkpoint(fn) if remat else fn

    if num_chunks == 1:
        return body(x)

    xs = x.reshape(num_chunks, T // num_chunks, *x.shape[1:])
    # lax.map = sequential scan: only ONE chunk's dispatch buffers are ever
    # live; jax.checkpoint on the body makes the backward pass recompute each
    # chunk independently (Eq. 7).  Stats leaves are tiny — stack, then sum.
    ys, stats = jax.lax.map(body, xs)
    stats = jax.tree.map(lambda s: s.sum(axis=0), stats)
    return ys.reshape(T, *ys.shape[2:]), stats


# ---------------------------------------------------------------------------
# pipelined schedule
# ---------------------------------------------------------------------------

def chunked_pipeline(stages: ChunkStages, x: jax.Array, num_chunks: int, *,
                     depth: int = 2, remat: bool = True):
    """Software-pipelined FCDA: same math as ``chunked_map(compose(stages))``
    with up to ``depth`` chunks in flight instead of one.

    The schedule is a wave pipeline: chunks are processed in waves of
    ``depth`` under a sequential ``lax.map``, and within a wave the member
    chunks are *mutually independent* computations — chunk i+1's route +
    single-sort plan + dispatch all-to-all can issue while chunk i's expert
    FFN computes and chunk i's combine all-to-all drains, because nothing
    orders them.  The compiler's latency-hiding scheduler gets a depth-wide
    window to overlap collectives with compute; the wave boundary is the
    liveness bound — never more than ``depth`` chunks' dispatch buffers in
    flight, the +1-copy term of the extended memory model
    (core/memory_model.py).  ``jax.checkpoint`` wraps the whole wave, so the
    backward pass recomputes wave-by-wave from the wave's tokens alone —
    Eq. 7 at wave granularity, residuals still one wave, not the token set.

    Two rejected emissions, for the record: a skewed ``lax.scan`` whose
    carry holds the in-flight buffers (dispatch i+1 / compute i / combine
    i-1 per iteration) double-buffers those carries every step and — worse —
    saves them ALL for the backward pass, reintroducing the full ``s'``
    blow-up FCDA exists to avoid; a fully unrolled chunk list with explicit
    ordering barriers preserves Eq. 7 but duplicates the chunk code
    ``num_chunks`` times.  The wave form compiles one wave body, reuses it
    ``num_chunks/depth`` times, and needs no barriers.

    Returns (y, stats), stats summed across chunks — the same contract as
    ``chunked_map``.  Falls back to the sequential loop when ``depth == 1``,
    there are fewer than 2 chunks, or ``depth`` does not divide the chunk
    count (bins are powers of two, so depth 2 always divides).
    """
    T = x.shape[0]
    if T % num_chunks:
        raise ValueError(f"token count {T} not divisible by c={num_chunks}")
    if depth < 1:
        raise ValueError(f"pipeline depth must be >= 1, got {depth}")
    depth = min(depth, num_chunks)
    if num_chunks < 2 or depth == 1 or num_chunks % depth:
        return chunked_map(compose(stages), x, num_chunks, remat=remat)

    fn = compose(stages)
    t_c = T // num_chunks

    def wave_fn(xw):
        # depth independent chunk bodies: the overlap window.  Stats are
        # summed within the wave (additive, same as across waves).
        outs = [fn(xw[i]) for i in range(depth)]
        y = jnp.stack([o[0] for o in outs])
        st = jax.tree.map(lambda *leaves: sum(leaves[1:], leaves[0]),
                          *[o[1] for o in outs])
        return y, st

    body = jax.checkpoint(wave_fn) if remat else wave_fn
    waves = x.reshape(num_chunks // depth, depth, t_c, *x.shape[1:])
    ys, stats = lax.map(body, waves)
    stats = jax.tree.map(lambda s: s.sum(axis=0), stats)
    return ys.reshape(T, *ys.shape[3:]), stats
