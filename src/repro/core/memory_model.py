"""MemFine's theoretical memory cost model (paper §3, Table 2, Eq. 1-3, 8-9).

Notation follows the paper's Table 1:
  s   sequence length            s'  tokens received by the MoE layer per GPU
  h   hidden size                a   attention heads       h_d  head dim
  k_a kv heads                   e_n router width (#experts; Table 2 row 10)
  g_d dense-FFN intermediate     g_e expert-FFN intermediate
  t/p/c/e/d  tensor/pipeline/context/expert/data parallel sizes
  b   micro batch                v   virtual pipeline stages per GPU
  D_t bytes per element (bf16 -> 2)

Eq. (2): M_act = m_g/(t*c) * D_t*b * [ s*(5h + a*h_d + 2*k_a*h_d + e_n)
                                       + s'*(2h + 2*g_e) ]
with m_g = v*p + p - 2*r_pp - 1 activation copies in flight for pipeline rank
r_pp, and m_g = 1 under full recomputation.

MemFine (FCDA) replaces the s' term's single buffer with the max over c
chunks; under a uniform chunk split that is s'/c — Eq. (6)-(7)'s memory
reduction.  Eq. (8) inverts the model for the max admissible s' and Eq. (9)
derives the optimal chunk count, which MACT snaps to a threshold bin.

The pipelined schedule (docs/DESIGN.md §Pipeline) keeps ``pipeline_depth``
chunks' dispatch buffers live instead of one, so the chunked MoE term
becomes s' * min(depth, c)/c and Eq. (9) generalises to
c = ceil(depth * s'' / s'_max) — the second axis MACT tunes jointly with c
(core/mact.py::choose_schedule).

The full derivation, with every symbol here mapped to its paper name and
every equation worked through (including the adaptive per-layer peak
M_sta + max_j M_act(s''_j)), lives in docs/MEMORY_MODEL.md.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import HardwareProfile, ModelConfig


@dataclass(frozen=True)
class Parallelism:
    """Paper Table 1 parallelism sizes (Megatron-style)."""
    t: int = 1      # tensor
    p: int = 1      # pipeline
    c: int = 1      # context
    e: int = 1      # expert
    d: int = 1      # data
    b: int = 1      # micro batch
    v: int = 1      # virtual pipeline stages per GPU


@dataclass(frozen=True)
class LayerDims:
    """Table 1 model dims for one transformer layer."""
    h: int
    a: int
    h_d: int
    k_a: int
    e_n: int        # router width = number of experts (Table 2 input 10)
    g_d: int
    g_e: int
    topk: int = 1

    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "LayerDims":
        moe = cfg.moe
        return cls(
            h=cfg.d_model,
            a=cfg.num_heads,
            h_d=cfg.resolved_head_dim,
            k_a=cfg.num_kv_heads,
            e_n=moe.num_experts if moe else 0,
            g_d=cfg.d_ff,
            g_e=moe.d_ff_expert if moe else 0,
            topk=moe.top_k if moe else 1,
        )


# ---------------------------------------------------------------------------
# activation memory (Eq. 2)
# ---------------------------------------------------------------------------

def m_g(par: Parallelism, r_pp: int = 0, full_recompute: bool = False) -> int:
    """Number of stored layer-activation copies (paper §3)."""
    if full_recompute:
        return 1
    return max(1, par.v * par.p + par.p - 2 * r_pp - 1)


def shared_act_bytes(dims: LayerDims, s: int, par: Parallelism,
                     dtype_bytes: int = 2) -> float:
    """The sequence-proportional (attention + router) term of Table 2."""
    per_tok = 5 * dims.h + dims.a * dims.h_d + 2 * dims.k_a * dims.h_d + dims.e_n
    return dtype_bytes * par.b * s * per_tok / (par.t * par.c)


def _moe_per_token(dims: LayerDims, fused: bool) -> float:
    """Per-received-token MoE activation width (Table 2's 2h + 2g_e).

    The 2h half is the (R, d) dispatch buffer's HBM round trip (dispatch
    output + FFN output awaiting combine).  The fused persistent kernel
    (kernels/fused_moe.py, docs/DESIGN.md §6) keeps those tiles in VMEM for
    the whole launch, so under ``fused`` that term vanishes and only the
    2g_e backward-recompute transient (h1/h3 inside the chunk's VJP)
    remains — which is what lets MACT choose coarser chunking."""
    return (0 if fused else 2 * dims.h) + 2 * dims.g_e


def moe_act_bytes(dims: LayerDims, s_prime: float, par: Parallelism,
                  dtype_bytes: int = 2, *, fused: bool = False) -> float:
    """The received-token-proportional MoE term of Table 2."""
    return (dtype_bytes * par.b * s_prime * _moe_per_token(dims, fused)
            / (par.t * par.c))


def activation_bytes(dims: LayerDims, s: int, s_prime: float, par: Parallelism,
                     *, copies: int = 1, chunks: int = 1,
                     dtype_bytes: int = 2, pipeline_depth: int = 1,
                     fused: bool = False) -> float:
    """Eq. (2) peak activation, with FCDA chunking dividing the MoE term.

    ``chunks=1`` is the standard (paper Method 1) layout; ``chunks=c`` models
    MemFine where only one chunk's dispatch buffers are live/stored at a time.
    ``pipeline_depth=d`` models the overlapped schedule where ``min(d, c)``
    chunks are in flight at once (docs/DESIGN.md §Pipeline) — the extra live
    copy the pipeline trades for all-to-all/compute overlap.  ``fused``
    models the single-launch expert leg, which removes the dispatch buffer's
    2h from the per-chunk term (see ``_moe_per_token``).
    """
    shared = shared_act_bytes(dims, s, par, dtype_bytes)
    live = min(max(pipeline_depth, 1), chunks)
    moe = moe_act_bytes(dims, s_prime, par, dtype_bytes,
                        fused=fused) * live / chunks
    return copies * (shared + moe)


def worst_case_s_prime(s: int, par: Parallelism, topk: int = 1) -> int:
    """Theoretical peak received tokens: every token-slot in the EP group lands
    on one GPU (paper §3: "s' approaches e*s"; with top-k slots, e*s*k)."""
    return par.e * par.b * s * topk


# ---------------------------------------------------------------------------
# static memory (Eq. 1)
# ---------------------------------------------------------------------------

#: bytes of training state per parameter.  Megatron-style BF16 mixed precision:
#: bf16 weight (2) + fp32 grad (4) + fp32 master (4) + Adam m, v (8).
TRAIN_STATE_BYTES = 18
WEIGHT_ONLY_BYTES = 2


def param_counts(cfg: ModelConfig, par: Parallelism) -> dict[str, float]:
    """Per-GPU parameter counts by module group (Eq. 1's S_i^para)."""
    h = cfg.d_model
    hd = cfg.resolved_head_dim
    counts: dict[str, float] = {}
    counts["embed"] = cfg.vocab_size * h / par.t
    counts["lm_head"] = 0.0 if cfg.tie_embeddings else cfg.vocab_size * h / par.t

    attn = dense_ffn = moe_experts = moe_shared = router = mamba = norms = 0.0
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            q = h * cfg.num_heads * hd
            kv = 2 * h * cfg.num_kv_heads * hd
            o = cfg.num_heads * hd * h
            attn += (q + kv + o) / par.t
        elif spec.mixer == "mamba":
            d_in = spec.ssm.expand * h
            nheads = d_in // spec.ssm.head_dim
            # in_proj (z, x, B, C, dt) + out_proj + conv + A/D/dt_bias
            in_proj = h * (2 * d_in + 2 * spec.ssm.state_dim * nheads + nheads)
            out_proj = d_in * h
            conv = spec.ssm.conv_width * (d_in + 2 * spec.ssm.state_dim * nheads)
            mamba += (in_proj + out_proj + conv + 3 * nheads) / par.t
        if spec.ffn == "dense":
            dense_ffn += 3 * h * cfg.d_ff / par.t
        elif spec.ffn == "moe":
            moe = cfg.moe
            local_experts = max(1, moe.num_experts // par.e)
            moe_experts += local_experts * 3 * h * moe.d_ff_expert / par.t
            moe_shared += moe.num_shared_experts * 3 * h * moe.d_ff_expert / par.t
            router += h * moe.num_experts
        norms += 2 * h
    if cfg.encoder_layers:
        q = h * cfg.num_heads * hd
        kv = 2 * h * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * h
        # encoder self-attn + ffn, decoder cross-attn
        attn += cfg.encoder_layers * (q + kv + o) / par.t
        dense_ffn += cfg.encoder_layers * 3 * h * cfg.d_ff / par.t
        attn += cfg.num_layers * (q + kv + o) / par.t   # cross attention
    counts.update(attn=attn, dense_ffn=dense_ffn, moe_experts=moe_experts,
                  moe_shared=moe_shared, router=router, mamba=mamba, norms=norms)
    return counts


def static_bytes(cfg: ModelConfig, par: Parallelism,
                 bytes_per_param: float = TRAIN_STATE_BYTES,
                 per_stage: bool = True) -> float:
    """Eq. (1): per-GPU static memory.  ``per_stage`` divides layer params by
    the pipeline size (embedding counted on the first stage)."""
    counts = param_counts(cfg, par)
    layer_params = sum(v for k, v in counts.items() if k not in ("embed", "lm_head"))
    if per_stage:
        layer_params /= par.p
    stage0 = counts["embed"] + layer_params
    return stage0 * bytes_per_param


def total_params(cfg: ModelConfig) -> float:
    """Global parameter count N (for MODEL_FLOPS = 6*N*D in the roofline)."""
    par = Parallelism()
    counts = param_counts(cfg, par)
    return sum(counts.values())


def active_params(cfg: ModelConfig) -> float:
    """Activated parameters per token (MoE: top-k + shared experts only)."""
    par = Parallelism()
    counts = param_counts(cfg, par)
    n = sum(v for k, v in counts.items() if k != "moe_experts")
    if cfg.moe is not None:
        frac = (cfg.moe.top_k / cfg.moe.num_experts)
        n += counts["moe_experts"] * frac
    return n


# ---------------------------------------------------------------------------
# MACT equations (Eq. 3, 8, 9)
# ---------------------------------------------------------------------------

def fits(static: float, act: float, hw: HardwareProfile) -> bool:
    """Eq. (3): M_sta + M_act <= alpha * M_GPU."""
    return static + act <= hw.alpha * hw.hbm_bytes


def replica_weight_bytes(cfg: ModelConfig, extra_slots_per_peer: int,
                         par: Parallelism,
                         bytes_per_param: float = WEIGHT_ONLY_BYTES) -> float:
    """Per-GPU weight bytes of hot-expert replica slots (docs/DESIGN.md
    §Placement, docs/MEMORY_MODEL.md replica weight term).

    Each MoE layer's placement may carve ``extra_slots_per_peer`` weight
    slots per peer beyond the identity e_local; a replica costs its expert's
    3*h*g_e/t parameters in weight-only bytes (gradients and optimizer state
    stay on the canonical copy — replicas are derived views refreshed at
    replan boundaries).  Divided by the pipeline size like ``static_bytes``:
    a stage only hosts replicas for its own MoE layers."""
    if cfg.moe is None or extra_slots_per_peer <= 0:
        return 0.0
    n_moe = sum(1 for spec in cfg.layer_specs() if spec.ffn == "moe")
    per_slot = 3 * cfg.d_model * cfg.moe.d_ff_expert / par.t
    return extra_slots_per_peer * per_slot * bytes_per_param * n_moe / par.p


def s_prime_max(dims: LayerDims, s: int, par: Parallelism, hw: HardwareProfile,
                static: float, *, copies: int = 1, dtype_bytes: int = 2,
                fused: bool = False, replica_bytes: float = 0.0) -> float:
    """Eq. (8): the max per-GPU received-token count that still fits.

    Under the fused expert leg the per-token denominator loses the 2h
    dispatch-buffer term, so s'_max grows by (1 + h/g_e) — the model-level
    statement of why fusion lets MACT pick coarser chunking (Eq. 9).

    ``replica_bytes`` (the hot-expert replica weight term) comes off the
    budget like any other static cost — replication trades a little weight
    memory for a lower observed s'' per peer, and both sides of that trade
    are priced here (docs/DESIGN.md §Placement)."""
    budget = (hw.alpha * hw.hbm_bytes - static - replica_bytes
              - copies * shared_act_bytes(dims, s, par, dtype_bytes))
    denom = (copies * dtype_bytes * par.b * _moe_per_token(dims, fused)
             / (par.t * par.c))
    return budget / denom


def optimal_chunks(s_pp: float, s_max: float, pipeline_depth: int = 1) -> int:
    """Eq. (9): c = ceil(s'' / s'_max).  Non-positive s_max means even one
    token per chunk cannot fit -> return a sentinel large value.

    With a pipelined schedule, ``pipeline_depth`` chunks of s''/c tokens are
    live at once, so the bound becomes depth * s''/c <= s'_max, i.e.
    c = ceil(depth * s''/s'_max) — and never fewer than ``depth`` chunks
    (with c < depth every chunk is live and chunking saves nothing)."""
    if s_max <= 0:
        return 1 << 30
    return max(pipeline_depth, 1, math.ceil(pipeline_depth * s_pp / s_max))


# ---------------------------------------------------------------------------
# serving variant (docs/DESIGN.md §Serving)
# ---------------------------------------------------------------------------
#
# The same Eq. 1-3 decomposition, re-read for inference: static memory is
# weight-only (no grads/optimizer, every stage resident on the serving
# host), the per-layer activation term is a single copy (nothing is kept
# for a backward pass), and a new state class appears that training does
# not have — per-request decode caches, which persist across steps and
# scale with the number of admitted requests.  The continuous-batching
# scheduler (repro/serving/scheduler.py) admits a request only when
#
#   M_weights + (n+1) * M_cache(L) + max(M_act_decode, M_act_prefill)
#       <= alpha * M_GPU                                   (Eq. 3, serving)
#
# with n the currently-admitted request count and L the per-request cache
# length.  M_act's MoE term uses the *structural* worst case of the
# dropless tp_gspmd dispatch: per-expert capacity is the full chunk
# (core/dispatch.py::dropless_capacity), so the scatter buffer holds
# e_n * tokens rows — the paper's "s' approaches e*s" realised by
# construction rather than by adversarial routing.

def expert_weight_bytes(cfg: ModelConfig,
                        dtype_bytes: float = WEIGHT_ONLY_BYTES) -> float:
    """Weight bytes of ONE routed expert in ONE MoE layer (w1 + w3 + w2 =
    3 * h * g_e params) — the unit the residency tier streams
    (docs/DESIGN.md §Residency) and the prefetch buffer is sized in."""
    if cfg.moe is None:
        return 0.0
    return 3 * cfg.d_model * cfg.moe.d_ff_expert * dtype_bytes


def serve_weight_bytes(cfg: ModelConfig,
                       dtype_bytes: float = WEIGHT_ONLY_BYTES, *,
                       resident_experts: Optional[int] = None) -> float:
    """Serving static memory: Eq. (1) with weight-only bytes per param and
    all stages (incl. the LM head) resident.

    ``resident_experts`` splits the total into dense-stage weights plus
    per-RESIDENT-expert weights (docs/DESIGN.md §Residency): with ``r`` of
    ``E`` experts resident per MoE layer, the ``E - r`` cold experts live
    host-side and their ``3 h g_e`` params come off the device total — the
    serving analogue of Eq. 2 dropping the 2h dispatch term under ``fused``.
    ``None`` (the default) keeps the historical all-resident model exactly.
    """
    total = total_params(cfg) * dtype_bytes
    if resident_experts is None or cfg.moe is None:
        return total
    E = cfg.moe.num_experts
    r = min(max(int(resident_experts), 0), E)
    n_moe = sum(1 for spec in cfg.layer_specs() if spec.ffn == "moe")
    return total - (E - r) * expert_weight_bytes(cfg, dtype_bytes) * n_moe


def decode_cache_bytes(cfg: ModelConfig, cache_len: int,
                       dtype_bytes: int = 2) -> float:
    """Per-request decode-cache bytes: KV at k_a * h_d per token per
    attention layer (ring-bounded by the window for window/chunked layers),
    constant SSM state + conv tail for mamba layers, and the precomputed
    cross-attention K/V for enc-dec archs."""
    from repro.models.ssm import dims as ssm_dims
    total = 0.0
    kv_row = 2 * cfg.num_kv_heads * cfg.resolved_head_dim
    for spec in cfg.layer_specs():
        if spec.mixer == "attn":
            Sc = cache_len
            if spec.attn.kind in ("window", "chunked") and spec.attn.window:
                Sc = min(spec.attn.window, cache_len)
            total += Sc * kv_row
        else:
            d_in, heads, d_conv = ssm_dims(cfg.d_model, spec.ssm)
            total += heads * spec.ssm.head_dim * spec.ssm.state_dim
            total += (spec.ssm.conv_width - 1) * d_conv
        if cfg.encoder_layers:
            total += cfg.encoder_seq * kv_row
    return total * dtype_bytes


def serve_act_bytes(dims: LayerDims, tokens: int, cfg: Optional[ModelConfig] = None,
                    dtype_bytes: int = 2) -> float:
    """Live activations for one serving wave of ``tokens`` tokens (decode:
    one per occupied slot; prefill: the chunk size): the Eq. (2) per-layer
    term at a single copy, plus the fp32 logits buffer, with the MoE term
    at the dropless structural worst case s' = e_n * tokens."""
    if tokens <= 0:
        return 0.0
    par = Parallelism()
    act = shared_act_bytes(dims, tokens, par, dtype_bytes)
    if dims.g_e:
        s_prime = dims.e_n * tokens          # (E, cap=tokens, ·) scatter buffer
        act += moe_act_bytes(dims, s_prime, par, dtype_bytes)
    if cfg is not None:
        act += tokens * cfg.padded_vocab * 4     # unembed emits fp32 logits
    return act


def serving_peak_bytes(cfg: ModelConfig, *, requests: int, cache_len: int,
                       decode_tokens: int, prefill_tokens: int = 0,
                       dtype_bytes: int = 2,
                       weight_bytes: float = WEIGHT_ONLY_BYTES,
                       replica_weight_bytes: float = 0.0,
                       resident_experts: Optional[int] = None,
                       prefetch_experts: int = 0) -> float:
    """Modeled peak serving memory with ``requests`` admitted requests:
    weights + per-request caches + the worse of the decode wave and the
    interleaved prefill chunk (they never run concurrently — the scheduler
    alternates them at step boundaries).

    The decode wave runs one token per *occupied* slot, so its activation
    term is clamped to ``requests``: an earlier revision charged the
    dropless s' = e_n * decode_tokens at the full slot-map width even for
    near-empty pools, overstating the decode term past the prefill chunk's
    (the true per-wave max at low occupancy — regression-pinned in
    tests/test_paging.py).

    ``replica_weight_bytes`` is the static cost of the engine-build expert
    placement's replica slots (docs/DESIGN.md §Placement) — the serving
    analogue of the training-side budget cut in ``s_prime_max``.

    ``resident_experts``/``prefetch_experts`` price the expert-weight
    residency tier (docs/DESIGN.md §Residency): only ``resident_experts``
    experts' weights per MoE layer are device-resident, plus an in-flight
    double-buffer of ``prefetch_experts`` experts being streamed ahead of
    the wave that needs them.  Defaults keep the all-resident model."""
    dims = LayerDims.from_config(cfg)
    act = max(serve_act_bytes(dims, min(decode_tokens, requests), cfg,
                              dtype_bytes),
              serve_act_bytes(dims, prefill_tokens, cfg, dtype_bytes))
    return (serve_weight_bytes(cfg, weight_bytes,
                               resident_experts=resident_experts)
            + prefetch_experts * expert_weight_bytes(cfg, weight_bytes)
            + replica_weight_bytes
            + requests * decode_cache_bytes(cfg, cache_len, dtype_bytes)
            + act)


def serving_fits(cfg: ModelConfig, hw: HardwareProfile, **kw) -> bool:
    """Eq. (3) for serving: admit only when the modeled peak fits."""
    return serving_peak_bytes(cfg, **kw) <= hw.alpha * hw.hbm_bytes


def serving_paged_peak_bytes(cfg: ModelConfig, *, page_bytes: float,
                             decode_tokens: int, prefill_tokens: int = 0,
                             dtype_bytes: int = 2,
                             weight_bytes: float = WEIGHT_ONLY_BYTES,
                             replica_weight_bytes: float = 0.0,
                             resident_experts: Optional[int] = None,
                             prefetch_experts: int = 0) -> float:
    """Paged-serving form of Eq. (3) (docs/DESIGN.md §Paging): the cache
    term counts ``page_bytes`` — bytes of pages *actually allocated* (or
    reserved: the scheduler passes allocated + outstanding worst-case
    reservations at admission, and the allocator's high-watermark when
    reporting the realised peak) — instead of requests * M_cache(L_max).
    Everything else is the slot-map model unchanged, so paged and
    monolithic admission differ exactly by their cache terms.  The
    ``resident_experts``/``prefetch_experts`` weight split composes the
    same way it does in ``serving_peak_bytes``."""
    dims = LayerDims.from_config(cfg)
    act = max(serve_act_bytes(dims, decode_tokens, cfg, dtype_bytes),
              serve_act_bytes(dims, prefill_tokens, cfg, dtype_bytes))
    return (serve_weight_bytes(cfg, weight_bytes,
                               resident_experts=resident_experts)
            + prefetch_experts * expert_weight_bytes(cfg, weight_bytes)
            + replica_weight_bytes + page_bytes + act)


def serving_paged_fits(cfg: ModelConfig, hw: HardwareProfile, **kw) -> bool:
    """Paged admission: allocated + reserved pages must keep the modeled
    peak within alpha * M_GPU."""
    return serving_paged_peak_bytes(cfg, **kw) <= hw.alpha * hw.hbm_bytes
