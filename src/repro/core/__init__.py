from repro.core.chunking import chunked_map
from repro.core.mact import MACTController
from repro.core.moe import DistContext, init_moe, moe_ffn, resolve_strategy
from repro.core.router import init_router, route, update_bias
