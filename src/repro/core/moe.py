"""The MemFine MoE layer: router + FCDA chunking + selectable expert strategy.

Strategies (docs/DESIGN.md §2):
  * ``ep_shardmap`` — experts sharded over the model axis, explicit
    all-to-all dispatch/combine per chunk (core/ep.py).  Requires the expert
    count, batch and sequence to divide the mesh axes.
  * ``tp_gspmd``    — experts replicated, expert FFN tensor-parallel on d_ff
    via GSPMD; dispatch is per-sequence-row (vmapped), so the sort never
    crosses devices.  Works for any expert count and for tiny decode batches.
  * ``dense``       — every expert on every token, masked combine.  O(E)
    compute; only used as a numerical oracle in tests.

All strategies share the same router and the same FCDA chunk loop, so Method
1/2/3 comparisons (paper §5) are pure config switches.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core.chunking import chunked_map
from repro.core.ep import moe_ffn_ep
from repro.core.router import init_router, route
from repro.kernels.ops import expert_ffn


@dataclass(frozen=True)
class DistContext:
    """How the current step is distributed; plumbed through the model."""
    mesh: Optional[object] = None          # jax.sharding.Mesh or None (local)
    batch_axes: tuple = ("data",)
    ep_axis: str = "model"
    moe_chunks: int = 1                    # FCDA chunk count (MACT-selected)
    pipeline_chunks: int = 1               # FCDA schedule depth: 1 = sequential
                                           # loop, >= 2 = overlapped chunks with
                                           # that many live at once (EP path,
                                           # docs/DESIGN.md §Pipeline)
    remat_chunks: bool = True              # Eq. (7) per-chunk recomputation
    use_pallas: bool = False
    pallas_interpret: bool = False         # lower kernels in interpret mode
                                           # (CPU dry-run of the kernel path)
    moe_strategy: str = "auto"             # overrides MoEConfig.strategy
    moe_ragged: bool = False               # MegaBlocks-style flat expert buffers
    moe_fused: bool = False                # single-launch fused expert leg over
                                           # the ragged layout (implies it):
                                           # kernels/fused_moe.py; Eq. 2 loses
                                           # the dispatch-buffer term
    ragged_block: int = 128                # ragged-layout row-block size
    layer_schedules: Optional[tuple] = None  # adaptive MACT: one ScheduleSpec
                                           # (chunks, depth) per MoE layer, in
                                           # layer order; overrides moe_chunks/
                                           # pipeline_chunks per layer
                                           # (docs/DESIGN.md §Adaptive)
    placement: Optional[object] = None     # PlacementSpec for THIS layer's EP
                                           # expert->peer map + replicas
                                           # (core/placement.py); None =
                                           # identity contiguous mapping
    placements: Optional[tuple] = None     # one PlacementSpec per MoE layer,
                                           # resolved to ``placement`` by
                                           # blocks.layer_ctx
                                           # (docs/DESIGN.md §Placement)
    act_pspec: Optional[object] = None     # PartitionSpec for (B, S, d) activations
    logits_pspec: Optional[object] = None  # PartitionSpec for (B, S, V) logits
    heads_pspec: Optional[object] = None   # PartitionSpec for (B, S, H, hd) q/k/v


def init_moe(key: jax.Array, d_model: int, cfg: MoEConfig,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    E, f = cfg.num_experts, cfg.d_ff_expert
    scale_in = d_model ** -0.5
    scale_out = f ** -0.5
    params = {
        "router": init_router(ks[0], d_model, E),
        "w1": jax.random.normal(ks[1], (E, d_model, f), dtype) * scale_in,
        "w3": jax.random.normal(ks[2], (E, d_model, f), dtype) * scale_in,
        "w2": jax.random.normal(ks[3], (E, f, d_model), dtype) * scale_out,
    }
    if cfg.num_shared_experts:
        fs = cfg.num_shared_experts * f
        params["shared"] = {
            "w1": jax.random.normal(ks[4], (d_model, fs), dtype) * scale_in,
            "w3": jax.random.normal(ks[5], (d_model, fs), dtype) * scale_in,
            "w2": jax.random.normal(ks[6], (fs, d_model), dtype) * scale_out,
        }
    return params


def resolve_strategy(cfg: MoEConfig, x_shape: tuple, ctx: DistContext) -> str:
    """Pick the expert strategy for this (config, shape, mesh)."""
    want = ctx.moe_strategy if ctx.moe_strategy != "auto" else cfg.strategy
    if want not in ("auto", "ep_shardmap"):
        return want
    if ctx.mesh is None:
        return "tp_gspmd"
    shape = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    P = shape.get(ctx.ep_axis, 1)
    batch_div = 1
    for a in ctx.batch_axes:
        batch_div *= shape.get(a, 1)
    B, S = x_shape[0], x_shape[1]
    ok = (cfg.num_experts % P == 0 and B % batch_div == 0 and S % P == 0
          and (B // batch_div) * (S // P) % ctx.moe_chunks == 0
          and (B // batch_div) * (S // P) >= ctx.moe_chunks)
    if ok:
        return "ep_shardmap"
    if want == "ep_shardmap":
        raise ValueError(
            f"ep_shardmap requested but E={cfg.num_experts}, B={B}, S={S} "
            f"do not divide mesh axes {shape}")
    return "tp_gspmd"


# ---------------------------------------------------------------------------
# tp_gspmd / local path: per-row dispatch, replicated experts, TP FFN
# ---------------------------------------------------------------------------

def _moe_ffn_rows(params: dict, x: jax.Array, cfg: MoEConfig,
                  ctx: DistContext):
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k

    def row_fn(xrow):
        def chunk_fn(xc):
            t_c = xc.shape[0]
            r = route(params["router"], xc, cfg)
            if cfg.capacity_mode == "dropless":
                cap = dsp.dropless_capacity(t_c)
            else:
                cap = dsp.balanced_capacity(t_c, k, E, cfg.capacity_factor)
            # single-sort planner (num_peers=1: the expert layout IS the
            # device layout) — same plan the EP path derives per chunk.
            # Dispatch stays on the jnp scatter here: this path is vmapped
            # over batch rows and the Pallas dispatch kernels want the
            # un-vmapped flat layout (the EP path is where chunked dispatch
            # overhead actually bites); the expert FFN honors use_pallas.
            uplan = dsp.make_unified_plan(r.expert_idx, E, 1, cap_expert=cap)
            plan = dsp.DispatchPlan(uplan.expert_slots, uplan.expert_load,
                                    uplan.drops_expert)
            buf = dsp.scatter_rows(xc, plan, E, cap)
            h = expert_ffn(buf, params["w1"], params["w3"], params["w2"],
                           use_pallas=ctx.use_pallas)
            y = dsp.gather_rows(h, plan, r.weights)
            stats = {"aux_loss": r.aux_loss,
                     "load": r.load.astype(jnp.float32),
                     "drops": plan.drops.astype(jnp.float32)}
            return y, stats

        return chunked_map(chunk_fn, xrow, ctx.moe_chunks, remat=ctx.remat_chunks)

    y, stats = jax.vmap(row_fn)(x)
    stats = {
        "aux_loss": stats["aux_loss"].mean() / ctx.moe_chunks,
        "load": stats["load"].sum(0),
        "drops": stats["drops"].sum(),
    }
    return y, stats


# ---------------------------------------------------------------------------
# dense oracle: compute every expert on every token (tests only)
# ---------------------------------------------------------------------------

def _moe_ffn_dense(params: dict, x: jax.Array, cfg: MoEConfig,
                   ctx: DistContext):
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    r = route(params["router"], x2, cfg)
    xe = jnp.broadcast_to(x2[None], (cfg.num_experts,) + x2.shape)
    h = expert_ffn(xe, params["w1"], params["w3"], params["w2"],
                   use_pallas=False)                       # (E, T, d)
    onehot = jax.nn.one_hot(r.expert_idx, cfg.num_experts, dtype=h.dtype)
    w = (onehot * r.weights[..., None].astype(h.dtype)).sum(1)   # (T, E)
    y = jnp.einsum("te,etd->td", w, h)
    stats = {"aux_loss": r.aux_loss, "load": r.load.astype(jnp.float32),
             "drops": jnp.float32(0)}
    return y.reshape(B, S, d), stats


# ---------------------------------------------------------------------------
# public layer
# ---------------------------------------------------------------------------

def _shared_expert(params: dict, x: jax.Array) -> jax.Array:
    s = params["shared"]
    h = jax.nn.silu(x @ s["w1"]) * (x @ s["w3"])
    return h @ s["w2"]


def moe_ffn(params: dict, x: jax.Array, cfg: MoEConfig, ctx: DistContext):
    """x: (B, S, d) -> (y, stats).

    Stats contract (identical across strategies, asserted by
    tests/test_moe_stats.py):

    * ``load``  — (E,) float32, the TOTAL routed token-slot demand per expert
      for the whole step (pre-capacity-clip), summed over batch rows, chunks
      and devices — never a per-row or per-chunk mean.
    * ``drops`` — float32 scalar, the TOTAL token-slots dropped this step
      (send-side peer-capacity + receive-side expert-capacity on the EP
      path); exactly 0.0 under ``capacity_mode="dropless"``.
    * ``aux_loss`` — float32 scalar, the MEAN per-chunk Switch auxiliary
      loss (averaged over chunks and over whatever granularity routed
      independently: EP devices for ep_shardmap, batch rows for tp_gspmd —
      aux is nonlinear, so these can differ across strategies even though
      load/drops match exactly).
    """
    strategy = resolve_strategy(cfg, x.shape, ctx)
    if strategy == "ep_shardmap":
        y, stats = moe_ffn_ep(params, x, cfg, ctx.mesh,
                              batch_axes=ctx.batch_axes, ep_axis=ctx.ep_axis,
                              chunks=ctx.moe_chunks, remat=ctx.remat_chunks,
                              use_pallas=ctx.use_pallas,
                              interpret=ctx.pallas_interpret,
                              ragged=ctx.moe_ragged,
                              pipeline=ctx.pipeline_chunks,
                              ragged_block=ctx.ragged_block,
                              fused=ctx.moe_fused,
                              placement=ctx.placement)
        stats = dict(stats)
        stats["aux_loss"] = stats["aux_loss"] / ctx.moe_chunks
    elif strategy == "tp_gspmd":
        y, stats = _moe_ffn_rows(params, x, cfg, ctx)
    elif strategy == "dense":
        y, stats = _moe_ffn_dense(params, x, cfg, ctx)
    else:
        raise ValueError(f"unknown MoE strategy {strategy!r}")
    if "shared" in params:
        y = y + _shared_expert(params, x)
    return y, stats
