"""Online expert-load telemetry for the adaptive MACT controller.

The model already reports, through the ``moe_ffn`` stats contract
(docs/DESIGN.md §Perf), the per-expert routed-token demand of every step.
``transformer.forward`` additionally stacks the per-MoE-layer rows into a
``load_per_layer`` matrix of shape ``(L_moe, E)``.  This module keeps the
*host-side* running view of that stream: a per-layer exponential moving
average of the routed-token histograms, which ``MACTController.
choose_layer_schedules`` reads each re-plan interval to resolve a
heterogeneous per-layer (chunk bin, pipeline depth) schedule
(docs/DESIGN.md §Adaptive).

Everything here is tiny numpy on host — O(L_moe * E) floats per step, no
device transfers beyond the metrics the trainer already fetches.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class LoadTelemetry:
    """Per-layer EMA of the routed-token histograms.

    ``decay`` is the EMA retention: ``ema <- decay * ema + (1-decay) * obs``.
    The first observation initialises the EMA directly (no zero-bias warmup:
    MACT must not under-plan memory while the average ramps).
    """
    num_layers: int
    num_experts: int
    decay: float = 0.6
    steps: int = 0
    _ema: Optional[np.ndarray] = field(default=None, repr=False)

    def update(self, load_per_layer) -> np.ndarray:
        obs = np.asarray(load_per_layer, dtype=np.float64)
        if obs.shape != (self.num_layers, self.num_experts):
            raise ValueError(
                f"telemetry update of shape {obs.shape}, expected "
                f"({self.num_layers}, {self.num_experts})")
        if self._ema is None:
            self._ema = obs.copy()
        else:
            self._ema = self.decay * self._ema + (1.0 - self.decay) * obs
        self.steps += 1
        return self._ema

    @property
    def loads(self) -> Optional[np.ndarray]:
        """(L_moe, E) EMA load matrix, or None before the first update."""
        return None if self._ema is None else self._ema.copy()

    def imbalance(self) -> Optional[np.ndarray]:
        """(L_moe,) per-layer max/mean ratio of the EMA (1.0 = balanced).

        The signal the placement hysteresis gates on (core/placement.py) and
        the trainer surfaces in its per-replan log line; None before the
        first update.  All-zero layers report 1.0 (nothing to balance).
        """
        if self._ema is None:
            return None
        mean = self._ema.mean(axis=1)
        peak = self._ema.max(axis=1)
        return np.where(mean > 0.0, peak / np.maximum(mean, 1e-30), 1.0)

    def reset(self) -> None:
        self._ema = None
        self.steps = 0

    # -- checkpoint round-trip (docs/DESIGN.md §Resilience) -------------------
    # A resumed run replans from the warm EMA instead of cold-starting the
    # worst-case safety schedule; the dict is small JSON the checkpoint
    # manifest carries verbatim.
    def state_dict(self) -> dict:
        return {"steps": self.steps,
                "ema": None if self._ema is None else self._ema.tolist()}

    def load_state_dict(self, state: dict) -> None:
        # validate BEFORE assigning: a failed restore must leave the live
        # EMA/steps untouched (the trainer keeps planning from the warm view)
        ema = state.get("ema")
        restored = None if ema is None else np.asarray(ema, dtype=np.float64)
        if restored is not None and restored.shape != (self.num_layers,
                                                       self.num_experts):
            raise ValueError(
                f"restored telemetry EMA of shape {restored.shape}, expected "
                f"({self.num_layers}, {self.num_experts})")
        self.steps = int(state.get("steps", 0))
        self._ema = restored


@dataclass
class ExpertTelemetry:
    """Per-REQUEST EMA of the per-MoE-layer activated-expert histograms.

    The serving-side twin of ``LoadTelemetry`` (docs/DESIGN.md §Residency):
    where the trainer keeps one EMA per layer over the whole batch, the
    expert-aware scheduler keeps one ``(L_moe, E)`` EMA per *resident
    request*, fed from the load rows its prefill chunks and decode steps
    report.  Wave formation reads ``support``/``expert_set`` to group
    requests by predicted expert overlap, and the residency tier reads the
    per-layer union to prefetch cold experts ahead of the wave.

    ``support_rel`` prunes the prediction: an expert whose EMA weight has
    decayed below that fraction of the request's hottest entry is dropped
    from the predicted set (a pure-EMA support would grow monotonically —
    every expert ever activated stays > 0 forever under float decay).
    """
    num_layers: int
    num_experts: int
    decay: float = 0.5
    support_rel: float = 0.02
    _ema: dict = field(default_factory=dict, repr=False)   # rid -> (L_moe, E)

    def update(self, rid: int, load_per_layer) -> np.ndarray:
        obs = np.asarray(load_per_layer, dtype=np.float64)
        if obs.shape != (self.num_layers, self.num_experts):
            raise ValueError(
                f"expert telemetry update of shape {obs.shape}, expected "
                f"({self.num_layers}, {self.num_experts})")
        prev = self._ema.get(rid)
        if prev is None:
            self._ema[rid] = obs.copy()
        else:
            self._ema[rid] = self.decay * prev + (1.0 - self.decay) * obs
        return self._ema[rid]

    def loads(self, rid: int) -> Optional[np.ndarray]:
        ema = self._ema.get(rid)
        return None if ema is None else ema.copy()

    def support(self, rid: int) -> Optional[np.ndarray]:
        """(L_moe, E) bool predicted-activation mask, or None before the
        first observation for this request."""
        ema = self._ema.get(rid)
        if ema is None:
            return None
        return ema > self.support_rel * max(float(ema.max()), 1e-30)

    def expert_set(self, rid: int) -> frozenset:
        """Predicted activated expert ids, unioned over layers (what the
        greedy wave grouping minimises the union of).  Empty for unseen
        requests — they cost nothing to add to any wave."""
        sup = self.support(rid)
        if sup is None:
            return frozenset()
        return frozenset(int(e) for e in np.flatnonzero(sup.any(axis=0)))

    def forget(self, rid: int) -> None:
        self._ema.pop(rid, None)

    def clear(self) -> None:
        self._ema.clear()
