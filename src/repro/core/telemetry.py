"""Online expert-load telemetry for the adaptive MACT controller.

The model already reports, through the ``moe_ffn`` stats contract
(docs/DESIGN.md §Perf), the per-expert routed-token demand of every step.
``transformer.forward`` additionally stacks the per-MoE-layer rows into a
``load_per_layer`` matrix of shape ``(L_moe, E)``.  This module keeps the
*host-side* running view of that stream: a per-layer exponential moving
average of the routed-token histograms, which ``MACTController.
choose_layer_schedules`` reads each re-plan interval to resolve a
heterogeneous per-layer (chunk bin, pipeline depth) schedule
(docs/DESIGN.md §Adaptive).

Everything here is tiny numpy on host — O(L_moe * E) floats per step, no
device transfers beyond the metrics the trainer already fetches.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class LoadTelemetry:
    """Per-layer EMA of the routed-token histograms.

    ``decay`` is the EMA retention: ``ema <- decay * ema + (1-decay) * obs``.
    The first observation initialises the EMA directly (no zero-bias warmup:
    MACT must not under-plan memory while the average ramps).
    """
    num_layers: int
    num_experts: int
    decay: float = 0.6
    steps: int = 0
    _ema: Optional[np.ndarray] = field(default=None, repr=False)

    def update(self, load_per_layer) -> np.ndarray:
        obs = np.asarray(load_per_layer, dtype=np.float64)
        if obs.shape != (self.num_layers, self.num_experts):
            raise ValueError(
                f"telemetry update of shape {obs.shape}, expected "
                f"({self.num_layers}, {self.num_experts})")
        if self._ema is None:
            self._ema = obs.copy()
        else:
            self._ema = self.decay * self._ema + (1.0 - self.decay) * obs
        self.steps += 1
        return self._ema

    @property
    def loads(self) -> Optional[np.ndarray]:
        """(L_moe, E) EMA load matrix, or None before the first update."""
        return None if self._ema is None else self._ema.copy()

    def reset(self) -> None:
        self._ema = None
        self.steps = 0

    # -- checkpoint round-trip (docs/DESIGN.md §Resilience) -------------------
    # A resumed run replans from the warm EMA instead of cold-starting the
    # worst-case safety schedule; the dict is small JSON the checkpoint
    # manifest carries verbatim.
    def state_dict(self) -> dict:
        return {"steps": self.steps,
                "ema": None if self._ema is None else self._ema.tolist()}

    def load_state_dict(self, state: dict) -> None:
        self.steps = int(state.get("steps", 0))
        ema = state.get("ema")
        self._ema = None if ema is None else np.asarray(ema, dtype=np.float64)
        if self._ema is not None and self._ema.shape != (self.num_layers,
                                                         self.num_experts):
            raise ValueError(
                f"restored telemetry EMA of shape {self._ema.shape}, expected "
                f"({self.num_layers}, {self.num_experts})")
