"""Top-k softmax router with the two balancing schemes the paper discusses:

* Switch-style auxiliary load-balance loss (soft constraint), and
* DeepSeek auxiliary-loss-free bias balancing (`loss_free_bias=True`): a
  per-expert bias added to the routing *scores only* (selection), updated
  outside the gradient path from observed loads.

MemFine explicitly does NOT touch routing (that is its selling point), so the
router here is deliberately standard; MemFine consumes its *load statistics*
(max tokens per device, per layer) to drive MACT.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


class RouterOut(NamedTuple):
    expert_idx: jax.Array     # (T, K) int32 — chosen experts per token
    weights: jax.Array        # (T, K) combine weights (renormalised probs)
    aux_loss: jax.Array       # scalar — Switch-style auxiliary loss
    load: jax.Array           # (E,) int32 — tokens routed to each expert


def init_router(key: jax.Array, d_model: int, num_experts: int,
                dtype=jnp.float32) -> dict:
    w = jax.random.normal(key, (d_model, num_experts), dtype) * (d_model ** -0.5)
    return {"w": w, "bias": jnp.zeros((num_experts,), jnp.float32)}


def route(params: dict, x: jax.Array, cfg: MoEConfig) -> RouterOut:
    """x: (T, d) -> top-k routing decisions.  Router math in fp32."""
    logits = jnp.asarray(x, jnp.float32) @ jnp.asarray(params["w"], jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    scores = probs + params["bias"][None, :] if cfg.loss_free_bias else probs
    _, expert_idx = jax.lax.top_k(scores, cfg.top_k)             # (T, K)
    gate = jnp.take_along_axis(probs, expert_idx, axis=-1)       # (T, K)
    weights = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    E = cfg.num_experts
    T = x.shape[0]
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)    # (T, K, E)
    load = onehot.sum((0, 1)).astype(jnp.int32)                  # (E,)
    # Switch aux loss: E * sum_e f_e * P_e
    f = onehot.sum(1).mean(0)                                    # fraction dispatched
    p_mean = probs.mean(0)
    aux = E * jnp.sum(f * p_mean) * (1.0 / max(cfg.top_k, 1))
    return RouterOut(expert_idx.astype(jnp.int32), weights.astype(x.dtype),
                     aux.astype(jnp.float32), load)


def update_bias(bias: jax.Array, load: jax.Array, cfg: MoEConfig) -> jax.Array:
    """DeepSeek loss-free balancing: nudge under-loaded experts' bias up and
    over-loaded experts' bias down.  Runs outside the gradient path."""
    load = load.astype(jnp.float32)
    err = load.mean() - load                                     # >0 if under-loaded
    return bias + cfg.bias_update_rate * jnp.sign(err)
