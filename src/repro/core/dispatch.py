"""Sort-based static-shape token dispatch / combine (TPU-idiomatic).

GPU MemFine permutes tokens with dynamic ``index_select``; on TPU all shapes
are static, so we rank token-slots within their target group via a stable
argsort + exclusive-cumsum and scatter into fixed ``(groups, capacity)``
buffers (scatter mode='drop' discards capacity overflow, which is impossible
under dropless capacity but counted for the GShard-style capacity baseline).

The same machinery serves two layers of the stack:
  * grouping by *expert* for local expert compute, and
  * grouping by *target device* for the all-to-all EP path (core/ep.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DispatchPlan(NamedTuple):
    slots: jax.Array      # (T, K) int32 — flat position in (G*capacity), -1 = dropped
    load: jax.Array       # (G,) int32 — demand per group (before capacity clip)
    drops: jax.Array      # scalar int32 — token-slots that exceeded capacity


def make_plan(group_idx: jax.Array, num_groups: int, capacity: int) -> DispatchPlan:
    """group_idx: (T, K) int32 in [0, num_groups) -> scatter plan."""
    T, K = group_idx.shape
    flat = group_idx.reshape(-1)
    order = jnp.argsort(flat, stable=True)                # token-slots grouped
    sorted_g = flat[order]
    load = jnp.zeros((num_groups,), jnp.int32).at[flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(load)[:-1]])
    ranks = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_g]
    ok = ranks < capacity
    slot_sorted = jnp.where(ok, sorted_g * capacity + ranks, -1)
    slots = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted)
    drops = (T * K - ok.sum()).astype(jnp.int32)
    return DispatchPlan(slots.reshape(T, K), load, drops)


def scatter_rows(x: jax.Array, plan: DispatchPlan, num_groups: int,
                 capacity: int) -> jax.Array:
    """x: (T, d) -> buffer (G, capacity, d); each token copied to its K slots."""
    T, d = x.shape
    K = plan.slots.shape[1]
    flat_slots = plan.slots.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = jnp.zeros((num_groups * capacity, d), x.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, num_groups * capacity)
    buf = buf.at[idx].add(x[tok], mode="drop")
    return buf.reshape(num_groups, capacity, d)


def scatter_values(vals: jax.Array, plan: DispatchPlan, num_groups: int,
                   capacity: int, fill=0) -> jax.Array:
    """vals: (T, K) per-slot payload (e.g. expert ids) -> (G, capacity)."""
    flat_slots = plan.slots.reshape(-1)
    flat_vals = vals.reshape(-1)
    out = jnp.full((num_groups * capacity,), fill, vals.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, num_groups * capacity)
    out = out.at[idx].set(flat_vals, mode="drop")
    return out.reshape(num_groups, capacity)


def gather_rows(buf: jax.Array, plan: DispatchPlan,
                weights: jax.Array | None = None) -> jax.Array:
    """Inverse of scatter_rows: buffer (G, C, d) -> (T, d), summing the K slots
    (optionally weighted by the router combine weights)."""
    G, C, d = buf.shape
    flat = buf.reshape(G * C, d)
    slots = plan.slots                                     # (T, K)
    valid = (slots >= 0).astype(flat.dtype)[..., None]     # (T, K, 1)
    rows = jnp.take(flat, jnp.maximum(slots, 0), axis=0)   # (T, K, d)
    if weights is not None:
        rows = rows * weights[..., None].astype(flat.dtype)
    return (rows * valid).sum(axis=1)


class RaggedPlan(NamedTuple):
    slots: jax.Array            # (T, K) int32 — flat row index, -1 dropped
    block_to_expert: jax.Array  # (R//bm,) int32
    total_rows: jax.Array       # scalar int32 (bm-aligned occupied rows)
    load: jax.Array             # (G,) int32
    drops: jax.Array            # scalar int32


def make_ragged_plan(group_idx: jax.Array, num_groups: int, rows: int,
                     block_m: int, valid: jax.Array | None = None) -> RaggedPlan:
    """MegaBlocks-style flat layout: rows grouped by expert, every group
    padded to a block_m multiple so each row-block maps to ONE expert.

    group_idx: (T, K); ``rows`` is the static buffer size (worst case +
    num_groups*block_m padding).  ``valid`` masks slots to exclude."""
    T, K = group_idx.shape
    flat = group_idx.reshape(-1)
    if valid is not None:
        flat = jnp.where(valid.reshape(-1), flat, num_groups)
    order = jnp.argsort(flat, stable=True)
    sorted_g = flat[order]
    ext_load = jnp.zeros((num_groups + 1,), jnp.int32).at[
        jnp.minimum(flat, num_groups)].add(1)
    load = ext_load[:num_groups]
    aligned = -(-load // block_m) * block_m                # per-group padded
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(aligned)])        # (G+1,)
    ranks = jnp.arange(T * K, dtype=jnp.int32) - jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(ext_load)])[:-1][sorted_g]
    slot_sorted = jnp.where(
        (sorted_g < num_groups) & (starts[jnp.minimum(sorted_g, num_groups)]
                                   + ranks < rows),
        starts[jnp.minimum(sorted_g, num_groups)] + ranks, -1)
    slots = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted)
    n_valid = (flat < num_groups).sum()
    drops = (n_valid - (slot_sorted >= 0).sum()).astype(jnp.int32)
    # block -> expert: block b belongs to group g iff starts[g] <= b*bm
    block_starts = jnp.arange(rows // block_m, dtype=jnp.int32) * block_m
    b2e = jnp.clip(
        jnp.searchsorted(starts[1:], block_starts, side="right"),
        0, num_groups - 1).astype(jnp.int32)
    return RaggedPlan(slots.reshape(T, K), b2e, starts[-1], load, drops)


def scatter_rows_flat(x: jax.Array, slots: jax.Array, rows: int) -> jax.Array:
    """x: (T, d), slots: (T, K) -> flat buffer (rows, d)."""
    T, d = x.shape
    K = slots.shape[1]
    flat_slots = slots.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = jnp.zeros((rows, d), x.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, rows)
    return buf.at[idx].add(x[tok], mode="drop")


def gather_rows_flat(buf: jax.Array, slots: jax.Array,
                     weights: jax.Array | None = None) -> jax.Array:
    """Inverse of scatter_rows_flat: (rows, d) -> (T, d) summing K slots."""
    valid = (slots >= 0).astype(buf.dtype)[..., None]
    out = jnp.take(buf, jnp.maximum(slots, 0), axis=0)     # (T, K, d)
    if weights is not None:
        out = out * weights[..., None].astype(buf.dtype)
    return (out * valid).sum(axis=1)


def dropless_capacity(tokens: int) -> int:
    """Worst-case per-group capacity for dropless dispatch: the K experts a
    token picks are distinct, so one expert can receive at most T tokens."""
    return tokens


def balanced_capacity(tokens: int, top_k: int, num_groups: int,
                      factor: float) -> int:
    """GShard-style capped capacity (the accuracy-degrading baseline the paper
    argues against): factor * T*K/G, rounded up."""
    return max(1, int(-(-tokens * top_k * factor // num_groups)))
