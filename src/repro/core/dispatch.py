"""Sort-based static-shape token dispatch / combine (TPU-idiomatic).

GPU MemFine permutes tokens with dynamic ``index_select``; on TPU all shapes
are static, so we rank token-slots within their target group via a stable
argsort + exclusive-cumsum and scatter into fixed ``(groups, capacity)``
buffers (scatter mode='drop' discards capacity overflow, which is impossible
under dropless capacity but counted for the GShard-style capacity baseline).

The same machinery serves two layers of the stack:
  * grouping by *expert* for local expert compute, and
  * grouping by *target device* for the all-to-all EP path (core/ep.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DispatchPlan(NamedTuple):
    slots: jax.Array      # (T, K) int32 — flat position in (G*capacity), -1 = dropped
    load: jax.Array       # (G,) int32 — demand per group (before capacity clip)
    drops: jax.Array      # scalar int32 — token-slots that exceeded capacity


def make_plan(group_idx: jax.Array, num_groups: int, capacity: int) -> DispatchPlan:
    """group_idx: (T, K) int32 in [0, num_groups) -> scatter plan."""
    T, K = group_idx.shape
    flat = group_idx.reshape(-1)
    order = jnp.argsort(flat, stable=True)                # token-slots grouped
    sorted_g = flat[order]
    load = jnp.zeros((num_groups,), jnp.int32).at[flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(load)[:-1]])
    ranks = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_g]
    ok = ranks < capacity
    slot_sorted = jnp.where(ok, sorted_g * capacity + ranks, -1)
    slots = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted)
    drops = (T * K - ok.sum()).astype(jnp.int32)
    return DispatchPlan(slots.reshape(T, K), load, drops)


def scatter_rows(x: jax.Array, plan: DispatchPlan, num_groups: int,
                 capacity: int) -> jax.Array:
    """x: (T, d) -> buffer (G, capacity, d); each token copied to its K slots."""
    T, d = x.shape
    K = plan.slots.shape[1]
    flat_slots = plan.slots.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = jnp.zeros((num_groups * capacity, d), x.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, num_groups * capacity)
    buf = buf.at[idx].add(x[tok], mode="drop")
    return buf.reshape(num_groups, capacity, d)


def scatter_values(vals: jax.Array, plan: DispatchPlan, num_groups: int,
                   capacity: int, fill=0) -> jax.Array:
    """vals: (T, K) per-slot payload (e.g. expert ids) -> (G, capacity)."""
    flat_slots = plan.slots.reshape(-1)
    flat_vals = vals.reshape(-1)
    out = jnp.full((num_groups * capacity,), fill, vals.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, num_groups * capacity)
    out = out.at[idx].set(flat_vals, mode="drop")
    return out.reshape(num_groups, capacity)


def gather_rows(buf: jax.Array, plan: DispatchPlan,
                weights: jax.Array | None = None) -> jax.Array:
    """Inverse of scatter_rows: buffer (G, C, d) -> (T, d), summing the K slots
    (optionally weighted by the router combine weights)."""
    G, C, d = buf.shape
    flat = buf.reshape(G * C, d)
    slots = plan.slots                                     # (T, K)
    valid = (slots >= 0).astype(flat.dtype)[..., None]     # (T, K, 1)
    rows = jnp.take(flat, jnp.maximum(slots, 0), axis=0)   # (T, K, d)
    if weights is not None:
        rows = rows * weights[..., None].astype(flat.dtype)
    return (rows * valid).sum(axis=1)


class RaggedPlan(NamedTuple):
    slots: jax.Array            # (T, K) int32 — flat row index, -1 dropped
    block_to_expert: jax.Array  # (R//bm,) int32
    total_rows: jax.Array       # scalar int32 (bm-aligned occupied rows)
    load: jax.Array             # (G,) int32
    drops: jax.Array            # scalar int32


def make_ragged_plan(group_idx: jax.Array, num_groups: int, rows: int,
                     block_m: int, valid: jax.Array | None = None) -> RaggedPlan:
    """MegaBlocks-style flat layout: rows grouped by expert, every group
    padded to a block_m multiple so each row-block maps to ONE expert.

    group_idx: (T, K); ``rows`` is the static buffer size (worst case +
    num_groups*block_m padding).  ``valid`` masks slots to exclude."""
    T, K = group_idx.shape
    flat = group_idx.reshape(-1)
    if valid is not None:
        flat = jnp.where(valid.reshape(-1), flat, num_groups)
    order = jnp.argsort(flat, stable=True)
    sorted_g = flat[order]
    ext_load = jnp.zeros((num_groups + 1,), jnp.int32).at[
        jnp.minimum(flat, num_groups)].add(1)
    load = ext_load[:num_groups]
    aligned = -(-load // block_m) * block_m                # per-group padded
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(aligned)])        # (G+1,)
    ranks = jnp.arange(T * K, dtype=jnp.int32) - jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(ext_load)])[:-1][sorted_g]
    slot_sorted = jnp.where(
        (sorted_g < num_groups) & (starts[jnp.minimum(sorted_g, num_groups)]
                                   + ranks < rows),
        starts[jnp.minimum(sorted_g, num_groups)] + ranks, -1)
    slots = jnp.zeros((T * K,), jnp.int32).at[order].set(slot_sorted)
    n_valid = (flat < num_groups).sum()
    drops = (n_valid - (slot_sorted >= 0).sum()).astype(jnp.int32)
    # block -> expert: block b belongs to group g iff starts[g] <= b*bm
    block_starts = jnp.arange(rows // block_m, dtype=jnp.int32) * block_m
    b2e = jnp.clip(
        jnp.searchsorted(starts[1:], block_starts, side="right"),
        0, num_groups - 1).astype(jnp.int32)
    return RaggedPlan(slots.reshape(T, K), b2e, starts[-1], load, drops)


def scatter_rows_flat(x: jax.Array, slots: jax.Array, rows: int) -> jax.Array:
    """x: (T, d), slots: (T, K) -> flat buffer (rows, d)."""
    T, d = x.shape
    K = slots.shape[1]
    flat_slots = slots.reshape(-1)
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    buf = jnp.zeros((rows, d), x.dtype)
    idx = jnp.where(flat_slots >= 0, flat_slots, rows)
    return buf.at[idx].add(x[tok], mode="drop")


def gather_rows_flat(buf: jax.Array, slots: jax.Array,
                     weights: jax.Array | None = None) -> jax.Array:
    """Inverse of scatter_rows_flat: (rows, d) -> (T, d) summing K slots."""
    valid = (slots >= 0).astype(buf.dtype)[..., None]
    out = jnp.take(buf, jnp.maximum(slots, 0), axis=0)     # (T, K, d)
    if weights is not None:
        out = out * weights[..., None].astype(buf.dtype)
    return (out * valid).sum(axis=1)


# ---------------------------------------------------------------------------
# single-sort unified planning (docs/DESIGN.md §Dispatch)
# ---------------------------------------------------------------------------

class UnifiedPlan(NamedTuple):
    """Every dispatch layout derived from ONE stable argsort of expert ids.

    Because experts are contiguous per EP peer (peer p owns experts
    ``[p*E/P, (p+1)*E/P)``), sorting token-slots by *global expert id* also
    groups them by target device — the coarse (device) plan and the fine
    (expert) plan are two read-outs of the same permutation, where the old
    path paid one argsort for each (``make_plan`` on ``expert_idx // e_local``
    then ``make_ragged_plan`` on the received rows).

    The receiver side needs no sort at all: within each peer's send block
    rows are expert-sorted, so shipping the tiny ``counts`` matrix through
    the same all-to-all lets the receiver place every row with cumsums
    (see ``recv_expert_plan`` / ``recv_ragged_plan``).
    """
    send_slots: jax.Array | None    # (T, K) int32 into flat (P*cap_send), -1 dropped
    expert_slots: jax.Array | None  # (T, K) int32 into flat (E*cap_expert), -1 dropped
    counts: jax.Array               # (P, E//P) int32 — slots PACKED per (dst peer, peer-local expert)
    expert_load: jax.Array          # (E,) int32 demand per expert (pre-clip)
    peer_load: jax.Array            # (P,) int32 demand per peer (pre-clip)
    drops: jax.Array                # scalar int32 — send-side (peer-capacity) drops
    drops_expert: jax.Array         # scalar int32 — expert-capacity drops


def make_unified_plan(expert_idx: jax.Array, num_experts: int,
                      num_peers: int = 1, *, cap_send: int | None = None,
                      cap_expert: int | None = None) -> UnifiedPlan:
    """expert_idx: (T, K) int32 global expert ids -> UnifiedPlan.

    Exactly one stable argsort, regardless of how many layouts are read out
    (asserted by tests/test_dispatch_planner.py on the jaxpr).
    """
    if num_experts % num_peers:
        raise ValueError(f"E={num_experts} not divisible by P={num_peers}")
    e_local = num_experts // num_peers
    T, K = expert_idx.shape
    N = T * K
    flat = expert_idx.reshape(-1)
    order = jnp.argsort(flat, stable=True)                 # THE one sort
    sorted_e = flat[order]
    pos = jnp.arange(N, dtype=jnp.int32)

    expert_load = jnp.zeros((num_experts,), jnp.int32).at[flat].add(1)
    e_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(expert_load)[:-1]])
    rank_e = pos - e_starts[sorted_e]                      # rank within expert

    peer_load = expert_load.reshape(num_peers, e_local).sum(1)
    p_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(peer_load)[:-1]])
    sorted_p = sorted_e // e_local
    rank_p = pos - p_starts[sorted_p]                      # rank within peer

    send_slots = None
    drops_send = jnp.int32(0)
    counts = expert_load.reshape(num_peers, e_local)
    if cap_send is not None:
        ok = rank_p < cap_send
        slot_sorted = jnp.where(ok, sorted_p * cap_send + rank_p, -1)
        send_slots = jnp.zeros((N,), jnp.int32).at[order].set(
            slot_sorted).reshape(T, K)
        drops_send = (N - ok.sum()).astype(jnp.int32)
        # slots packed per (peer, expert) after the cap clip: within a peer
        # rows are expert-sorted, so the clip truncates the tail experts
        within = e_starts - p_starts[jnp.arange(num_experts) // e_local]
        sent = jnp.clip(cap_send - within, 0, expert_load)
        counts = sent.reshape(num_peers, e_local)

    expert_slots = None
    drops_expert = jnp.int32(0)
    if cap_expert is not None:
        ok = rank_e < cap_expert
        slot_sorted = jnp.where(ok, sorted_e * cap_expert + rank_e, -1)
        expert_slots = jnp.zeros((N,), jnp.int32).at[order].set(
            slot_sorted).reshape(T, K)
        drops_expert = (N - ok.sum()).astype(jnp.int32)

    return UnifiedPlan(send_slots, expert_slots, counts, expert_load,
                       peer_load, drops_send, drops_expert)


def _recv_positions(recv_counts: jax.Array, recv_eid: jax.Array):
    """Shared receiver-side arithmetic: for each received row, its expert and
    its rank within that expert across all source peers — cumsums only.

    recv_counts: (P, e_local) — rows from source p for local expert e.
    recv_eid: (P*cap_send,) local expert id per received row, -1 invalid.
    Relies on the sender invariant that each source block is expert-sorted
    and packed from position 0 (make_unified_plan guarantees both).
    """
    P, e_local = recv_counts.shape
    Rr = recv_eid.shape[0]
    cap_src = Rr // P
    # rows from sources before p for each expert (exclusive cumsum over P)
    src_off = jnp.concatenate(
        [jnp.zeros((1, e_local), jnp.int32),
         jnp.cumsum(recv_counts, axis=0)[:-1]], axis=0)
    # start of expert e inside source block p (exclusive cumsum over e)
    blk_start = jnp.concatenate(
        [jnp.zeros((P, 1), jnp.int32),
         jnp.cumsum(recv_counts, axis=1)[:, :-1]], axis=1)
    p = jnp.arange(Rr, dtype=jnp.int32) // cap_src
    i = jnp.arange(Rr, dtype=jnp.int32) - p * cap_src
    valid = recv_eid >= 0
    e = jnp.where(valid, recv_eid, 0)
    idx = p * e_local + e
    rank = (src_off.reshape(-1)[idx] + i - blk_start.reshape(-1)[idx])
    load = recv_counts.sum(0)
    return e, rank, valid, load


def eids_from_counts(recv_counts: jax.Array, cap_src: int) -> jax.Array:
    """Reconstruct per-row local expert ids from the counts matrix alone:
    (P, e_local) -> (P*cap_src,) int32, -1 for unoccupied slots.

    Each source block is expert-sorted and packed from position 0 (the
    sender invariant), so row i of block p belongs to the first expert whose
    inclusive cumulative count exceeds i.  This replaces shipping an expert-id
    buffer through its own scatter + all_to_all — one fewer collective and
    one fewer serialized scatter per chunk."""
    P, e_local = recv_counts.shape
    cum = jnp.cumsum(recv_counts, axis=1)                  # (P, e_local)
    i = jnp.arange(cap_src, dtype=jnp.int32)
    eid = (i[None, :, None] >= cum[:, None, :]).sum(-1)    # (P, cap_src)
    valid = i[None, :] < cum[:, -1:]
    return jnp.where(valid, eid, -1).reshape(-1).astype(jnp.int32)


def recv_expert_plan(recv_counts: jax.Array, recv_eid: jax.Array,
                     capacity: int) -> DispatchPlan:
    """Receiver-side (E_local, capacity) plan from the exchanged counts
    matrix — zero sorts (the sender's single sort already ordered rows)."""
    e, rank, valid, load = _recv_positions(recv_counts, recv_eid)
    ok = valid & (rank < capacity)
    slots = jnp.where(ok, e * capacity + rank, -1)
    drops = (valid.sum() - ok.sum()).astype(jnp.int32)
    return DispatchPlan(slots[:, None], load, drops)


def recv_ragged_plan(recv_counts: jax.Array, recv_eid: jax.Array,
                     rows: int, block_m: int) -> RaggedPlan:
    """Receiver-side MegaBlocks-style flat plan from the counts matrix —
    zero sorts; drop-in replacement for ``make_ragged_plan`` on the EP path."""
    e, rank, valid, load = _recv_positions(recv_counts, recv_eid)
    e_local = recv_counts.shape[1]
    aligned = -(-load // block_m) * block_m
    g_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                jnp.cumsum(aligned)])      # (e_local+1,)
    slot = g_starts[e] + rank
    ok = valid & (slot < rows)
    slots = jnp.where(ok, slot, -1)
    drops = (valid.sum() - ok.sum()).astype(jnp.int32)
    block_starts = jnp.arange(rows // block_m, dtype=jnp.int32) * block_m
    b2e = jnp.clip(
        jnp.searchsorted(g_starts[1:], block_starts, side="right"),
        0, e_local - 1).astype(jnp.int32)
    return RaggedPlan(slots[:, None], b2e, g_starts[-1], load, drops)


def invert_slots(slots: jax.Array, rows: int) -> jax.Array:
    """slots: (T, K) -> (rows,) int32 source flat-position map, -1 = empty.

    The scatter direction expressed as a gather: output row r comes from
    token-slot ``inv[r]`` (slots are unique, so this is a true inverse).
    Feeds the scalar-prefetched index maps of kernels/dispatch_pallas.py.
    """
    flat = slots.reshape(-1)
    N = flat.shape[0]
    pos = jnp.arange(N, dtype=jnp.int32)
    idx = jnp.where(flat >= 0, flat, rows)
    return jnp.full((rows,), -1, jnp.int32).at[idx].set(pos, mode="drop")


def dropless_capacity(tokens: int) -> int:
    """Worst-case per-group capacity for dropless dispatch: the K experts a
    token picks are distinct, so one expert can receive at most T tokens."""
    return tokens


def balanced_capacity(tokens: int, top_k: int, num_groups: int,
                      factor: float) -> int:
    """GShard-style capped capacity (the accuracy-degrading baseline the paper
    argues against): factor * T*K/G, rounded up."""
    return max(1, int(-(-tokens * top_k * factor // num_groups)))
