"""Expert parallelism via shard_map + lax.all_to_all (the paper's dispatch path).

Megatron MemFine moves tokens between EP ranks with NCCL all-to-alls around
each expert's GEMM; the JAX/TPU analogue is a ``jax.shard_map`` region over
the ``model`` mesh axis with explicit ``lax.all_to_all`` collectives, one
dispatch + one combine per FCDA chunk (docs/DESIGN.md §2).

Buffer sizing is the heart of the memory story: under dropless routing the
send block per peer must hold the worst case (every local token-slot targets
one peer -> cap_send = T_chunk*K) and the local expert buffer the group worst
case (every group token lands on one local expert -> cap_recv = P*T_chunk).
Unchunked, that is the paper's `s' -> e*s` blow-up *by construction*; FCDA
divides both by the chunk count c.

The chunk body is expressed as ``ChunkStages`` (route+dispatch / expert
compute / combine) so the pipelined schedule (docs/DESIGN.md §Pipeline) can
overlap chunk i+1's dispatch all-to-all with chunk i's FFN and chunk i-1's
draining combine; ``pipeline=1`` composes the same stages back into the
sequential FCDA loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core.chunking import ChunkStages, chunked_pipeline
from repro.core.placement import PlacementSpec, place_expert_idx
from repro.core.router import route
from repro.kernels.ops import (combine_rows, dispatch_rows, expert_ffn,
                               moe_ffn as fused_moe_leg, ragged_expert_ffn)

#: default ragged-layout row-block size; per-run override via
#: DistContext.ragged_block (core/moe.py)
RAGGED_BLOCK = 128


def _ep_local(x_l, router_w, router_b, w1, w3, w2, *, moe_cfg: MoEConfig,
              chunks: int, remat: bool, ep_axis: str, all_axes: tuple,
              use_pallas: bool, ragged: bool = False,
              interpret: bool = False, pipeline: int = 1,
              ragged_block: int = RAGGED_BLOCK, fused: bool = False,
              placement: PlacementSpec | None = None):
    """Per-device body. x_l: (B_l, S_l, d) local tokens."""
    peers = compat.axis_size(ep_axis)
    E = moe_cfg.num_experts
    # With a placement the dispatch groups are weight SLOTS, not expert ids:
    # the single-sort planner is group-id agnostic, so sorting by slot id
    # still groups by target peer (slots are peer-contiguous by construction)
    # and the counts-matrix reconstruction on the receiver is unchanged
    # (docs/DESIGN.md §Placement).  e_local below is slots-per-peer.
    if placement is not None:
        if placement.num_experts != E or placement.num_peers != peers:
            raise ValueError(
                f"placement for (E={placement.num_experts}, "
                f"P={placement.num_peers}), layer has (E={E}, P={peers})")
        n_groups = placement.total_slots
    else:
        n_groups = E
    e_local = n_groups // peers
    b_l, s_l, d = x_l.shape
    tokens = b_l * s_l
    x2 = x_l.reshape(tokens, d)
    k = moe_cfg.top_k
    t_c = tokens // chunks                 # uniform chunk split (static)

    def stage_dispatch(xc):
        """Route + single-sort plan + dispatch all-to-all (in-flight state)."""
        r = route({"w": router_w, "bias": router_b}, xc, moe_cfg)
        # placement: expert id -> weight-slot id, replicas split by token
        # index parity (deterministic; identity spec short-circuits)
        sel = place_expert_idx(r.expert_idx, placement)
        if moe_cfg.capacity_mode == "dropless":
            # a token's k experts are distinct, so at most min(k, E_local) of
            # its slots can target one peer, and at most one can land on a
            # given expert/slot — exact worst cases, not heuristics (a peer
            # hosts each expert in at most one slot, so this survives
            # replication unchanged)
            cap_send = t_c * min(k, e_local)
        else:
            cap_send = dsp.balanced_capacity(t_c, k, peers, moe_cfg.capacity_factor)
        # ---- dispatch: ONE stable argsort per chunk plans everything ------
        # sorting by global group id (expert, or slot under placement)
        # groups by target device too (groups are contiguous per peer), and
        # within each peer block rows arrive group-sorted, so the receiver
        # places rows with cumsums over the exchanged counts matrix — no
        # second sort (docs/DESIGN.md §Dispatch)
        uplan = dsp.make_unified_plan(sel, n_groups, peers,
                                      cap_send=cap_send)
        send = dispatch_rows(xc, uplan.send_slots, peers * cap_send,
                             use_pallas=use_pallas, interpret=interpret)
        send = send.reshape(peers, cap_send, d)                    # (P, cap_s, d)
        recv = lax.all_to_all(send, ep_axis, 0, 0, tiled=True)
        recv_cnt = lax.all_to_all(uplan.counts, ep_axis, 0, 0, tiled=True)
        return {"recv": recv, "recv_cnt": recv_cnt,
                "send_slots": uplan.send_slots, "weights": r.weights,
                "aux_loss": r.aux_loss, "load": r.load,
                "drops_send": uplan.drops}

    def stage_compute(st):
        """Local expert FFN over the received rows."""
        recv, recv_cnt = st["recv"], st["recv_cnt"]
        _, cap_send, _ = recv.shape
        if moe_cfg.capacity_mode == "dropless":
            cap_recv = peers * t_c
        else:
            cap_recv = dsp.balanced_capacity(peers * t_c, k, E,
                                             moe_cfg.capacity_factor)
        # no expert-id buffer travels with the rows: each source block is
        # expert-sorted and packed from 0, so the counts matrix alone
        # reconstructs every row's expert (dsp.eids_from_counts)
        rows = recv.reshape(peers * cap_send, d)
        local_e = dsp.eids_from_counts(recv_cnt, cap_send)
        if ragged or fused:
            # MegaBlocks-style flat layout: R worst-case rows + block padding
            # instead of (E_local, cap_recv) per-expert buffers — E_local/k
            # fewer buffer rows, and the Pallas kernels predicate off blocks
            # past the actual load (docs/DESIGN.md §Perf).
            R = peers * cap_send + e_local * ragged_block
            R = -(-R // ragged_block) * ragged_block
            plan_r = dsp.recv_ragged_plan(recv_cnt, local_e, R, ragged_block)
            if fused:
                # single-launch leg (kernels/fused_moe.py): dispatch +
                # SwiGLU + down-proj + combine in one persistent kernel —
                # the (R, d) buffer never materializes in HBM on forward.
                # The router weight is applied after the return all-to-all
                # (stage_combine), so this combine is unweighted.
                back = fused_moe_leg(rows, w1, w3, w2, plan_r.slots,
                                     plan_r.block_to_expert,
                                     plan_r.total_rows, None,
                                     block_m=ragged_block,
                                     use_pallas=use_pallas,
                                     interpret=interpret)
            else:
                buf = dispatch_rows(rows, plan_r.slots, R,
                                    total_rows=plan_r.total_rows,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
                h = ragged_expert_ffn(buf, w1, w3, w2,
                                      plan_r.block_to_expert,
                                      plan_r.total_rows,
                                      block_m=ragged_block,
                                      use_pallas=use_pallas,
                                      interpret=interpret)
                back = combine_rows(h, plan_r.slots, None, plan_r.total_rows,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
            back = back.reshape(peers, cap_send, d)
            drops_e = plan_r.drops
        else:
            # (E_local, cap_recv) layout is flat (E_local*cap_recv, d) to
            # the dispatch kernels (occupancy is not a prefix here, so no
            # total_rows predication — only the -1-slot masking applies)
            plan_e = dsp.recv_expert_plan(recv_cnt, local_e, cap_recv)
            buf = dispatch_rows(rows, plan_e.slots, e_local * cap_recv,
                                use_pallas=use_pallas, interpret=interpret)
            h = expert_ffn(buf.reshape(e_local, cap_recv, d), w1, w3, w2,
                           use_pallas=use_pallas, interpret=interpret)
            back = combine_rows(h.reshape(e_local * cap_recv, d),
                                plan_e.slots, use_pallas=use_pallas,
                                interpret=interpret)
            back = back.reshape(peers, cap_send, d)
            drops_e = plan_e.drops
        return {"back": back, "send_slots": st["send_slots"],
                "weights": st["weights"], "aux_loss": st["aux_loss"],
                "load": st["load"],
                "drops": st["drops_send"] + drops_e}

    def stage_combine(st):
        """Combine all-to-all: return rows to their senders, weight, reduce."""
        back = st["back"]
        _, cap_send, _ = back.shape
        recv_back = lax.all_to_all(back, ep_axis, 0, 0, tiled=True)
        y = combine_rows(recv_back.reshape(peers * cap_send, d),
                         st["send_slots"], st["weights"],
                         use_pallas=use_pallas, interpret=interpret)
        stats = {
            "aux_loss": lax.pmean(st["aux_loss"], all_axes),
            "load": lax.psum(st["load"].astype(jnp.float32), all_axes),
            "drops": lax.psum(st["drops"].astype(jnp.float32), all_axes),
        }
        return y, stats

    stages = ChunkStages(stage_dispatch, stage_compute, stage_combine)
    # chunked_pipeline composes the stages back into the sequential loop
    # when depth or the chunk count rules the pipeline out
    y, stats = chunked_pipeline(stages, x2, chunks, depth=pipeline,
                                remat=remat)
    return y.reshape(b_l, s_l, d), stats


def moe_ffn_ep(params: dict, x: jax.Array, moe_cfg: MoEConfig, mesh, *,
               batch_axes: tuple = ("data",), ep_axis: str = "model",
               chunks: int = 1, remat: bool = True,
               use_pallas: bool = False, ragged: bool = False,
               interpret: bool = False, pipeline: int = 1,
               ragged_block: int = RAGGED_BLOCK, fused: bool = False,
               placement: PlacementSpec | None = None):
    """x: (B, S, d) global -> (y, stats).  B sharded over batch_axes, S over
    ep_axis (the EP group = one row of the model axis).  ``pipeline`` is the
    FCDA schedule depth: 1 = sequential loop, >= 2 = overlapped chunks.
    ``fused`` runs the local expert leg as ONE kernel launch over the ragged
    layout (kernels/fused_moe.py) instead of dispatch/FFN/combine.
    ``placement`` re-homes expert weights across EP peers (and replicates
    hot experts) per docs/DESIGN.md §Placement; identity/None is the
    hardcoded contiguous mapping."""
    all_axes = tuple(batch_axes) + (ep_axis,)
    if placement is not None and placement.is_identity:
        placement = None            # bitwise-identical fast path
    w1, w3, w2 = params["w1"], params["w3"], params["w2"]
    if placement is not None:
        placement.validate()
        # Re-home the expert weights into slot order.  This global gather of
        # the EP-sharded canonical weights IS the migration all-to-all on a
        # real mesh (each peer pulls the slices its slots need); under
        # autodiff its transpose scatter-adds every replica's gradient back
        # into the canonical (E, d, f) rows.
        idx = jnp.asarray(placement.slot_to_expert, dtype=jnp.int32)
        w1, w3, w2 = w1[idx], w3[idx], w2[idx]
    fn = functools.partial(
        _ep_local, moe_cfg=moe_cfg, chunks=chunks, remat=remat,
        ep_axis=ep_axis, all_axes=all_axes, use_pallas=use_pallas,
        ragged=ragged, interpret=interpret, pipeline=pipeline,
        ragged_block=ragged_block, fused=fused, placement=placement)
    x_spec = P(tuple(batch_axes), ep_axis, None)
    stats_spec = {"aux_loss": P(), "load": P(None), "drops": P()}
    return shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=(x_spec, stats_spec),
        # pallas_call (interpret) emits ShapeDtypeStructs without vma info;
        # manual-axis correctness is covered by tests/test_distributed.py
        check_vma=False,
    )(x, params["router"]["w"], params["router"]["bias"], w1, w3, w2)
