"""Expert parallelism via shard_map + lax.all_to_all (the paper's dispatch path).

Megatron MemFine moves tokens between EP ranks with NCCL all-to-alls around
each expert's GEMM; the JAX/TPU analogue is a ``jax.shard_map`` region over
the ``model`` mesh axis with explicit ``lax.all_to_all`` collectives, one
dispatch + one combine per FCDA chunk (DESIGN.md §2).

Buffer sizing is the heart of the memory story: under dropless routing the
send block per peer must hold the worst case (every local token-slot targets
one peer -> cap_send = T_chunk*K) and the local expert buffer the group worst
case (every group token lands on one local expert -> cap_recv = P*T_chunk).
Unchunked, that is the paper's `s' -> e*s` blow-up *by construction*; FCDA
divides both by the chunk count c.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core.chunking import chunked_map
from repro.core.router import route
from repro.kernels.ops import expert_ffn, ragged_expert_ffn

RAGGED_BLOCK = 128


def _ep_local(x_l, router_w, router_b, w1, w3, w2, *, moe_cfg: MoEConfig,
              chunks: int, remat: bool, ep_axis: str, all_axes: tuple,
              use_pallas: bool, ragged: bool = False,
              interpret: bool = False):
    """Per-device body. x_l: (B_l, S_l, d) local tokens."""
    peers = lax.axis_size(ep_axis)
    rank = lax.axis_index(ep_axis)
    E = moe_cfg.num_experts
    e_local = E // peers
    b_l, s_l, d = x_l.shape
    tokens = b_l * s_l
    x2 = x_l.reshape(tokens, d)
    k = moe_cfg.top_k

    def chunk_fn(xc):
        t_c = xc.shape[0]
        r = route({"w": router_w, "bias": router_b}, xc, moe_cfg)
        if moe_cfg.capacity_mode == "dropless":
            # a token's k experts are distinct, so at most min(k, E_local) of
            # its slots can target one peer, and at most one can land on a
            # given expert — exact worst cases, not heuristics
            cap_send = t_c * min(k, e_local)
            cap_recv = peers * t_c
        else:
            cap_send = dsp.balanced_capacity(t_c, k, peers, moe_cfg.capacity_factor)
            cap_recv = dsp.balanced_capacity(peers * t_c, k, E,
                                             moe_cfg.capacity_factor)
        # ---- dispatch: group token-slots by target device, exchange --------
        target_dev = r.expert_idx // e_local                       # (t_c, k)
        plan_s = dsp.make_plan(target_dev, peers, cap_send)
        send = dsp.scatter_rows(xc, plan_s, peers, cap_send)       # (P, cap_s, d)
        send_eid = dsp.scatter_values(r.expert_idx, plan_s, peers, cap_send,
                                      fill=jnp.int32(-1))          # (P, cap_s)
        recv = lax.all_to_all(send, ep_axis, 0, 0, tiled=True)
        recv_eid = lax.all_to_all(send_eid, ep_axis, 0, 0, tiled=True)
        # ---- local expert compute ----------------------------------------
        rows = recv.reshape(peers * cap_send, d)
        flat_eid = recv_eid.reshape(-1)
        valid = flat_eid >= 0
        local_e = jnp.where(valid, flat_eid - rank * e_local, e_local)
        if ragged:
            # MegaBlocks-style flat layout: R worst-case rows + block padding
            # instead of (E_local, cap_recv) per-expert buffers — E_local/k
            # fewer buffer rows, and the Pallas kernel predicates off blocks
            # past the actual load (EXPERIMENTS.md §Perf).
            R = peers * cap_send + e_local * RAGGED_BLOCK
            R = -(-R // RAGGED_BLOCK) * RAGGED_BLOCK
            plan_r = dsp.make_ragged_plan(local_e[:, None], e_local, R,
                                          RAGGED_BLOCK,
                                          valid=valid[:, None])
            buf = dsp.scatter_rows_flat(rows, plan_r.slots, R)
            h = ragged_expert_ffn(buf, w1, w3, w2, plan_r.block_to_expert,
                                  plan_r.total_rows, block_m=RAGGED_BLOCK,
                                  use_pallas=use_pallas, interpret=interpret)
            back = dsp.gather_rows_flat(h, plan_r.slots)
            back = back.reshape(peers, cap_send, d)
            drops_e = plan_r.drops
        else:
            plan_e = dsp.make_plan(local_e[:, None], e_local + 1, cap_recv)
            buf = dsp.scatter_rows(rows, plan_e, e_local + 1, cap_recv)
            h = expert_ffn(buf[:e_local], w1, w3, w2, use_pallas=use_pallas,
                           interpret=interpret)
            h = jnp.concatenate([h, jnp.zeros((1,) + h.shape[1:], h.dtype)],
                                axis=0)
            back = dsp.gather_rows(h, plan_e).reshape(peers, cap_send, d)
            # overflow in the padding (invalid-row) group is not a real drop
            drops_e = jnp.sum((plan_e.slots.reshape(-1) == -1) & valid)
        # ---- combine: return rows to their senders, weight, reduce --------
        recv_back = lax.all_to_all(back, ep_axis, 0, 0, tiled=True)
        y = dsp.gather_rows(recv_back, plan_s, r.weights)          # (t_c, d)
        stats = {
            "aux_loss": lax.pmean(r.aux_loss, all_axes),
            "load": lax.psum(r.load.astype(jnp.float32), all_axes),
            "drops": lax.psum((plan_s.drops + drops_e).astype(jnp.float32),
                              all_axes),
        }
        return y, stats

    y, stats = chunked_map(chunk_fn, x2, chunks, remat=remat)
    return y.reshape(b_l, s_l, d), stats


def moe_ffn_ep(params: dict, x: jax.Array, moe_cfg: MoEConfig, mesh, *,
               batch_axes: tuple = ("data",), ep_axis: str = "model",
               chunks: int = 1, remat: bool = True,
               use_pallas: bool = False, ragged: bool = False,
               interpret: bool = False):
    """x: (B, S, d) global -> (y, stats).  B sharded over batch_axes, S over
    ep_axis (the EP group = one row of the model axis)."""
    all_axes = tuple(batch_axes) + (ep_axis,)
    fn = functools.partial(
        _ep_local, moe_cfg=moe_cfg, chunks=chunks, remat=remat,
        ep_axis=ep_axis, all_axes=all_axes, use_pallas=use_pallas,
        ragged=ragged, interpret=interpret)
    x_spec = P(tuple(batch_axes), ep_axis, None)
    stats_spec = {"aux_loss": P(), "load": P(None), "drops": P()}
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=(x_spec, stats_spec),
        # pallas_call (interpret) emits ShapeDtypeStructs without vma info;
        # manual-axis correctness is covered by tests/test_distributed.py
        check_vma=False,
    )(x, params["router"]["w"], params["router"]["bias"],
      params["w1"], params["w3"], params["w2"])
