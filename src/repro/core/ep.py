"""Expert parallelism via shard_map + lax.all_to_all (the paper's dispatch path).

Megatron MemFine moves tokens between EP ranks with NCCL all-to-alls around
each expert's GEMM; the JAX/TPU analogue is a ``jax.shard_map`` region over
the ``model`` mesh axis with explicit ``lax.all_to_all`` collectives, one
dispatch + one combine per FCDA chunk (docs/DESIGN.md §2).

Buffer sizing is the heart of the memory story: under dropless routing the
send block per peer must hold the worst case (every local token-slot targets
one peer -> cap_send = T_chunk*K) and the local expert buffer the group worst
case (every group token lands on one local expert -> cap_recv = P*T_chunk).
Unchunked, that is the paper's `s' -> e*s` blow-up *by construction*; FCDA
divides both by the chunk count c.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core.chunking import chunked_map
from repro.core.router import route
from repro.kernels.ops import (combine_rows, dispatch_rows, expert_ffn,
                               ragged_expert_ffn)

RAGGED_BLOCK = 128


def _ep_local(x_l, router_w, router_b, w1, w3, w2, *, moe_cfg: MoEConfig,
              chunks: int, remat: bool, ep_axis: str, all_axes: tuple,
              use_pallas: bool, ragged: bool = False,
              interpret: bool = False):
    """Per-device body. x_l: (B_l, S_l, d) local tokens."""
    peers = compat.axis_size(ep_axis)
    E = moe_cfg.num_experts
    e_local = E // peers
    b_l, s_l, d = x_l.shape
    tokens = b_l * s_l
    x2 = x_l.reshape(tokens, d)
    k = moe_cfg.top_k

    def chunk_fn(xc):
        t_c = xc.shape[0]
        r = route({"w": router_w, "bias": router_b}, xc, moe_cfg)
        if moe_cfg.capacity_mode == "dropless":
            # a token's k experts are distinct, so at most min(k, E_local) of
            # its slots can target one peer, and at most one can land on a
            # given expert — exact worst cases, not heuristics
            cap_send = t_c * min(k, e_local)
            cap_recv = peers * t_c
        else:
            cap_send = dsp.balanced_capacity(t_c, k, peers, moe_cfg.capacity_factor)
            cap_recv = dsp.balanced_capacity(peers * t_c, k, E,
                                             moe_cfg.capacity_factor)
        # ---- dispatch: ONE stable argsort per chunk plans everything ------
        # sorting by global expert id groups by target device too (experts
        # are contiguous per peer), and within each peer block rows arrive
        # expert-sorted, so the receiver places rows with cumsums over the
        # exchanged counts matrix — no second sort (docs/DESIGN.md §Dispatch)
        uplan = dsp.make_unified_plan(r.expert_idx, E, peers,
                                      cap_send=cap_send)
        send = dispatch_rows(xc, uplan.send_slots, peers * cap_send,
                             use_pallas=use_pallas, interpret=interpret)
        send = send.reshape(peers, cap_send, d)                    # (P, cap_s, d)
        recv = lax.all_to_all(send, ep_axis, 0, 0, tiled=True)
        recv_cnt = lax.all_to_all(uplan.counts, ep_axis, 0, 0, tiled=True)
        # ---- local expert compute ----------------------------------------
        # no expert-id buffer travels with the rows: each source block is
        # expert-sorted and packed from 0, so the counts matrix alone
        # reconstructs every row's expert (dsp.eids_from_counts)
        rows = recv.reshape(peers * cap_send, d)
        local_e = dsp.eids_from_counts(recv_cnt, cap_send)
        if ragged:
            # MegaBlocks-style flat layout: R worst-case rows + block padding
            # instead of (E_local, cap_recv) per-expert buffers — E_local/k
            # fewer buffer rows, and the Pallas kernels predicate off blocks
            # past the actual load (docs/DESIGN.md §Perf).
            R = peers * cap_send + e_local * RAGGED_BLOCK
            R = -(-R // RAGGED_BLOCK) * RAGGED_BLOCK
            plan_r = dsp.recv_ragged_plan(recv_cnt, local_e, R, RAGGED_BLOCK)
            buf = dispatch_rows(rows, plan_r.slots, R,
                                total_rows=plan_r.total_rows,
                                use_pallas=use_pallas, interpret=interpret)
            h = ragged_expert_ffn(buf, w1, w3, w2, plan_r.block_to_expert,
                                  plan_r.total_rows, block_m=RAGGED_BLOCK,
                                  use_pallas=use_pallas, interpret=interpret)
            back = combine_rows(h, plan_r.slots, None, plan_r.total_rows,
                                use_pallas=use_pallas, interpret=interpret)
            back = back.reshape(peers, cap_send, d)
            drops_e = plan_r.drops
        else:
            # (E_local, cap_recv) layout is flat (E_local*cap_recv, d) to
            # the dispatch kernels (occupancy is not a prefix here, so no
            # total_rows predication — only the -1-slot masking applies)
            plan_e = dsp.recv_expert_plan(recv_cnt, local_e, cap_recv)
            buf = dispatch_rows(rows, plan_e.slots, e_local * cap_recv,
                                use_pallas=use_pallas, interpret=interpret)
            h = expert_ffn(buf.reshape(e_local, cap_recv, d), w1, w3, w2,
                           use_pallas=use_pallas, interpret=interpret)
            back = combine_rows(h.reshape(e_local * cap_recv, d),
                                plan_e.slots, use_pallas=use_pallas,
                                interpret=interpret)
            back = back.reshape(peers, cap_send, d)
            drops_e = plan_e.drops
        # ---- combine: return rows to their senders, weight, reduce --------
        recv_back = lax.all_to_all(back, ep_axis, 0, 0, tiled=True)
        y = combine_rows(recv_back.reshape(peers * cap_send, d),
                         uplan.send_slots, r.weights,
                         use_pallas=use_pallas, interpret=interpret)
        stats = {
            "aux_loss": lax.pmean(r.aux_loss, all_axes),
            "load": lax.psum(r.load.astype(jnp.float32), all_axes),
            "drops": lax.psum((uplan.drops + drops_e).astype(jnp.float32),
                              all_axes),
        }
        return y, stats

    y, stats = chunked_map(chunk_fn, x2, chunks, remat=remat)
    return y.reshape(b_l, s_l, d), stats


def moe_ffn_ep(params: dict, x: jax.Array, moe_cfg: MoEConfig, mesh, *,
               batch_axes: tuple = ("data",), ep_axis: str = "model",
               chunks: int = 1, remat: bool = True,
               use_pallas: bool = False, ragged: bool = False,
               interpret: bool = False):
    """x: (B, S, d) global -> (y, stats).  B sharded over batch_axes, S over
    ep_axis (the EP group = one row of the model axis)."""
    all_axes = tuple(batch_axes) + (ep_axis,)
    fn = functools.partial(
        _ep_local, moe_cfg=moe_cfg, chunks=chunks, remat=remat,
        ep_axis=ep_axis, all_axes=all_axes, use_pallas=use_pallas,
        ragged=ragged, interpret=interpret)
    x_spec = P(tuple(batch_axes), ep_axis, None)
    stats_spec = {"aux_loss": P(), "load": P(None), "drops": P()}
    return shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=(x_spec, stats_spec),
        # pallas_call (interpret) emits ShapeDtypeStructs without vma info;
        # manual-axis correctness is covered by tests/test_distributed.py
        check_vma=False,
    )(x, params["router"]["w"], params["router"]["bias"],
      params["w1"], params["w3"], params["w2"])
