"""Telemetry-driven expert placement + hot-expert replication.

MemFine schedules *around* routing skew (FCDA chunking + recompute depth);
this module *moves* the work instead (docs/DESIGN.md §Placement).  The
per-layer per-expert EMA that ``core/telemetry.py`` already tracks feeds a
greedy LPT assignment (MicroMoE, arXiv 2511.16947) that maps experts to EP
peers, plus replication of persistently hot experts across peers with a
deterministic load-split at routing time (MoETuner, arXiv 2502.06643).

The representation is *slot-based*: each EP peer owns ``slots_per_peer =
e_local + replicas`` expert-weight slots, and ``slot_to_expert`` (peer-major)
says which expert's weights live in each slot.  A replicated expert occupies
one slot on several peers (never two on the same peer).  The dispatch path
then runs the existing single-sort ``UnifiedPlan`` machinery over *slot ids*
instead of expert ids — the planner is group-id agnostic, so the plan stays
single-sort and the combine stays transpose-symmetric.  The identity spec is
detected and skipped entirely, so an identity ``PlacementSpec`` is bitwise
identical to the unplaced path.

Everything here is tiny host-side numpy; the only traced op is
``place_expert_idx`` (a constant-table gather).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np

#: cap on the load-split modulus (lcm of replica counts); beyond this the
#: round-robin split is approximate-even instead of exact-even
MAX_SPLIT_MOD = 2520


class PlacementSpec(NamedTuple):
    """Expert -> (peer, slot) assignment for one MoE layer.

    ``slot_to_expert`` is peer-major: slot ``s`` lives on peer
    ``s // slots_per_peer`` and holds the weights of expert
    ``slot_to_expert[s]``.  Hashable (NamedTuple of ints/tuples) so it can
    sit in ``DistContext`` and key the trainer's compiled-step LRU cache.
    """
    num_experts: int
    num_peers: int
    slot_to_expert: Tuple[int, ...]

    # -- shape -----------------------------------------------------------------
    @property
    def total_slots(self) -> int:
        return len(self.slot_to_expert)

    @property
    def slots_per_peer(self) -> int:
        return self.total_slots // self.num_peers

    @property
    def replica_slots(self) -> int:
        """Extra weight slots per peer beyond the identity e_local."""
        return self.slots_per_peer - self.num_experts // self.num_peers

    @property
    def is_identity(self) -> bool:
        return (self.total_slots == self.num_experts
                and self.slot_to_expert == tuple(range(self.num_experts)))

    @classmethod
    def identity(cls, num_experts: int, num_peers: int) -> "PlacementSpec":
        """The hardcoded contiguous mapping (expert e on peer e // e_local)."""
        if num_experts % num_peers:
            raise ValueError(f"E={num_experts} not divisible by P={num_peers}")
        return cls(num_experts, num_peers, tuple(range(num_experts)))

    def validate(self) -> None:
        E, P, s2e = self.num_experts, self.num_peers, self.slot_to_expert
        if len(s2e) % P:
            raise ValueError(f"{len(s2e)} slots not divisible by {P} peers")
        spp = len(s2e) // P
        if spp < E // P:
            raise ValueError("fewer slots per peer than e_local")
        seen = set()
        for p in range(P):
            block = s2e[p * spp:(p + 1) * spp]
            if len(set(block)) != spp:
                raise ValueError(f"peer {p} hosts a duplicate expert: {block}")
            seen.update(block)
        if seen != set(range(E)):
            raise ValueError(f"experts {set(range(E)) - seen} unplaced")

    # -- derived tables (host-side numpy, constant-folded under jit) -----------
    def replica_counts(self) -> np.ndarray:
        """(E,) number of slots hosting each expert (>= 1)."""
        return np.bincount(np.asarray(self.slot_to_expert),
                           minlength=self.num_experts).astype(np.int64)

    def expert_slot_table(self) -> np.ndarray:
        """(E, R) int32: row e lists expert e's slots round-robin.

        R is the lcm of the replica counts (capped at MAX_SPLIT_MOD), so each
        replica appears equally often per row and the token-index-parity split
        ``table[e, pos % R]`` is exactly even (approximate beyond the cap).
        """
        counts = self.replica_counts()
        R = 1
        for c in sorted(set(int(c) for c in counts)):
            R = R * c // math.gcd(R, c)
            if R >= MAX_SPLIT_MOD:
                R = MAX_SPLIT_MOD
                break
        slots_of = [[] for _ in range(self.num_experts)]
        for s, e in enumerate(self.slot_to_expert):
            slots_of[e].append(s)
        table = np.empty((self.num_experts, R), dtype=np.int32)
        for e, slots in enumerate(slots_of):
            table[e] = [slots[i % len(slots)] for i in range(R)]
        return table

    def peer_loads(self, load) -> np.ndarray:
        """(P,) predicted per-peer routed load for a (E,) load vector.

        Each expert's load splits evenly across its replicas — the model the
        solver and ``MACTController.observed_s_pp`` price; the runtime parity
        split matches it up to the MAX_SPLIT_MOD cap.
        """
        load = np.asarray(load, dtype=np.float64).reshape(-1)
        if load.size != self.num_experts:
            raise ValueError(
                f"load of size {load.size}, expected {self.num_experts}")
        share = load / self.replica_counts()
        s2e = np.asarray(self.slot_to_expert)
        return share[s2e].reshape(self.num_peers, self.slots_per_peer).sum(1)


def bottleneck(spec: PlacementSpec, load) -> float:
    """Hottest-peer predicted load — the quantity LPT minimises."""
    return float(spec.peer_loads(load).max())


def plan_placement(load, num_peers: int, *, replicas: int = 0
                   ) -> PlacementSpec:
    """Greedy LPT assignment + hot-expert replication for one layer.

    Pass 1 (LPT): experts in descending load order, each to the least-loaded
    peer with a free CANONICAL slot (the ``replicas`` extra slots per peer
    are reserved — letting LPT pack cold experts into them starves the
    replication pass of exactly the peers a hot expert should split onto).
    Pass 2 (replication): repeatedly replicate the hottest-share expert onto
    its least-loaded non-hosting peer, committing only moves that improve
    the sorted per-peer load vector lexicographically (a hot column split
    across two equally-hot peers improves the SECOND-highest load before it
    moves the max, so plain bottleneck-only greedy would stall).  When no
    replication helps, remaining reserved slots are padded with each peer's
    coldest absent expert — a cold replica adds (almost) no load but keeps
    every peer at the uniform ``slots_per_peer`` the dispatch shape needs.
    """
    load = np.asarray(load, dtype=np.float64).reshape(-1)
    E = load.size
    if num_peers <= 0 or E % num_peers:
        raise ValueError(f"E={E} not divisible by P={num_peers}")
    e_local = E // num_peers
    spp = e_local + replicas
    if replicas < 0 or spp > E:
        raise ValueError(f"replicas={replicas} out of range for E={E}, "
                         f"P={num_peers}")
    peer_slots: list[list[int]] = [[] for _ in range(num_peers)]
    peer_load = np.zeros(num_peers)
    for e in np.argsort(-load, kind="stable"):
        p = min((p for p in range(num_peers) if len(peer_slots[p]) < e_local),
                key=lambda p: (peer_load[p], p))
        peer_slots[p].append(int(e))
        peer_load[p] += load[e]
    counts = np.ones(E)

    def peer_loads_now() -> np.ndarray:
        share = load / counts
        return np.array([share[s].sum() for s in peer_slots])

    while any(len(s) < spp for s in peer_slots):
        share = load / counts
        pl = peer_loads_now()
        before = tuple(sorted(pl, reverse=True))
        committed = False
        for e in np.argsort(-share, kind="stable"):
            e = int(e)
            cands = [p for p in range(num_peers)
                     if len(peer_slots[p]) < spp and e not in peer_slots[p]]
            if not cands:
                continue
            p = min(cands, key=lambda p: (pl[p], p))
            peer_slots[p].append(e)
            counts[e] += 1
            if tuple(sorted(peer_loads_now(), reverse=True)) < before:
                committed = True
                break
            peer_slots[p].pop()
            counts[e] -= 1
        if not committed:
            for p in range(num_peers):
                while len(peer_slots[p]) < spp:
                    share = load / counts
                    cold = min((e for e in range(E)
                                if e not in peer_slots[p]),
                               key=lambda e: (share[e], e))
                    peer_slots[p].append(cold)
                    counts[cold] += 1
            break
    # canonical within-peer order (sorted by expert id) so equal assignments
    # compare equal across replans — the hysteresis band depends on it
    s2e = tuple(e for p in range(num_peers) for e in sorted(peer_slots[p]))
    spec = PlacementSpec(E, num_peers, s2e)
    spec.validate()
    return spec


def choose_placements(loads, num_layers: int, num_peers: int, *,
                      num_experts: Optional[int] = None, replicas: int = 0,
                      current: Optional[Sequence[PlacementSpec]] = None,
                      hysteresis: float = 0.1
                      ) -> Tuple[PlacementSpec, ...]:
    """Per-MoE-layer placement vector with a MACT-style hysteresis band.

    ``loads`` is the telemetry ``(L_moe, E)`` EMA (None -> identity for every
    layer; ``num_experts`` then sizes the identity specs).  A layer switches
    away from its incumbent only when the candidate's predicted bottleneck
    beats the incumbent's by more than the hysteresis fraction — same
    anti-flapping rule as ``MACTController.choose_layer_schedules``.
    """
    if loads is None:
        if num_experts is None:
            raise ValueError("num_experts required when loads is None")
        ident = PlacementSpec.identity(num_experts, num_peers)
        return tuple(current) if current is not None else (ident,) * num_layers
    loads = np.asarray(loads, dtype=np.float64)
    if loads.ndim != 2 or loads.shape[0] != num_layers:
        raise ValueError(f"loads of shape {loads.shape}, expected "
                         f"({num_layers}, E)")
    E = loads.shape[1]
    ident = PlacementSpec.identity(E, num_peers)
    out = []
    for i in range(num_layers):
        row = loads[i]
        inc = current[i] if current is not None else ident
        cand = plan_placement(row, num_peers, replicas=replicas)
        if bottleneck(cand, row) * (1.0 + hysteresis) < bottleneck(inc, row):
            out.append(cand)
        else:
            out.append(inc)
    return tuple(out)


def migrated_slots(old: Optional[PlacementSpec], new: PlacementSpec) -> int:
    """Weight slots whose resident expert changes old -> new.

    This is what the replan-boundary all-to-all moves: each changed slot
    receives one expert's parameter slice from whichever peer holds it.
    ``old=None`` means the identity layout (the cold-start weight placement),
    so adopting identity at cold start moves nothing.  Slots are compared by
    (peer, offset); a slot with no predecessor (replica slots just carved
    out) always counts as moved.
    """
    if old is None:
        old = PlacementSpec.identity(new.num_experts, new.num_peers)
    if old.num_peers != new.num_peers:
        return new.total_slots
    spp_o, spp_n = old.slots_per_peer, new.slots_per_peer
    moved = 0
    for p in range(new.num_peers):
        for o in range(spp_n):
            prev = old.slot_to_expert[p * spp_o + o] if o < spp_o else None
            moved += new.slot_to_expert[p * spp_n + o] != prev
    return moved


def place_expert_idx(expert_idx, spec: PlacementSpec):
    """Map routed expert ids (T, K) -> weight-slot ids, load-splitting
    replicas by token-index parity.

    Deterministic at trace time: slot = table[e, flat_pos % R].  With R the
    lcm of the replica counts, consecutive token-slots round-robin across an
    expert's replicas, so the split is even regardless of routing order.
    Identity specs short-circuit (bitwise-identical to the unplaced path).
    """
    if spec is None or spec.is_identity:
        return expert_idx
    import jax.numpy as jnp  # traced path only; keep module import-light
    table = jnp.asarray(spec.expert_slot_table())
    t, k = expert_idx.shape
    pos = jnp.arange(t * k, dtype=jnp.int32).reshape(t, k)
    return table[expert_idx, pos % table.shape[1]]
