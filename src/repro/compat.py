"""Version shims for the JAX APIs this repo uses across jax releases.

The repo targets the modern spellings (``jax.shard_map`` with ``check_vma``,
``jax.set_mesh``); older releases (< 0.5) expose the same machinery under
``jax.experimental.shard_map.shard_map(check_rep=...)`` and make ``Mesh``
itself the ambient-mesh context manager.  Everything that enters a shard_map
region or sets an ambient mesh goes through here so the rest of the code can
stay version-agnostic.
"""

from __future__ import annotations

import contextlib

import jax

__all__ = ["shard_map", "set_mesh", "make_mesh", "axis_size"]


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis inside a shard_map/pmap region.

    ``jax.lax.axis_size`` on new jax; on old releases the axis env records
    the same static size under ``jax.core.axis_frame``.
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    import jax.core as core
    return core.axis_frame(axis_name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` otherwise.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); ``None``
    means library default.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def set_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh.

    New jax: ``jax.set_mesh``.  Old jax: ``Mesh`` is itself a context
    manager with the same effect for jit/NamedSharding resolution.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with a fallback for releases that predate it."""
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    import numpy as np
    devs = np.asarray(jax.devices()).reshape(tuple(axis_shapes))
    return jax.sharding.Mesh(devs, tuple(axis_names))
