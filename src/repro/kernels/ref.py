"""Pure-jnp oracles for the Pallas kernels (and the CPU/dry-run compute path).

The grouped expert FFN is the compute hot spot MemFine schedules around:
dispatched buffers (E, C, d) hit per-expert SwiGLU FFNs (E, d, f)/(E, f, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., E, M, K), w: (E, K, N) -> (..., E, M, N)."""
    return jnp.einsum("...emk,ekn->...emn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def grouped_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array) -> jax.Array:
    """silu(x @ w1) * (x @ w3), per expert group."""
    a = jnp.einsum("...emk,ekn->...emn", x, w1, preferred_element_type=jnp.float32)
    b = jnp.einsum("...emk,ekn->...emn", x, w3, preferred_element_type=jnp.float32)
    return (jax.nn.silu(a) * b).astype(x.dtype)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                   w2: jax.Array) -> jax.Array:
    """Full per-expert SwiGLU FFN: (..., E, C, d) -> (..., E, C, d)."""
    h = grouped_swiglu_ref(x, w1, w3)
    return grouped_matmul_ref(h, w2)


# ---------------------------------------------------------------------------
# ragged (flat expert-grouped rows) layout — oracle for kernels/ragged_mlp.py
# ---------------------------------------------------------------------------

def _blocked(x: jax.Array, block_to_expert: jax.Array):
    R = x.shape[0]
    nb = block_to_expert.shape[0]
    return x.reshape(nb, R // nb, x.shape[1])


def ragged_matmul_ref(x: jax.Array, w: jax.Array, block_to_expert: jax.Array,
                      total_rows) -> jax.Array:
    """x: (R, K) expert-grouped rows -> (R, N); rows past total_rows are 0.
    Blocked formulation: weights gathered per bm-row block (one expert per
    block by construction), so the gather is (nb, K, N), never (R, K, N)."""
    R, K = x.shape
    xb = _blocked(x, block_to_expert)                            # (nb, bm, K)
    wb = jnp.take(w, block_to_expert, axis=0)                    # (nb, K, N)
    out = jnp.einsum("bmk,bkn->bmn", xb, wb,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                      block_to_expert: jax.Array, total_rows) -> jax.Array:
    R, K = x.shape
    xb = _blocked(x, block_to_expert)
    a = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w1, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    b = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w3, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    out = (jax.nn.silu(a) * b).astype(x.dtype).reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_expert_ffn_ref(x: jax.Array, w1, w3, w2, block_to_expert,
                          total_rows) -> jax.Array:
    h = ragged_swiglu_ref(x, w1, w3, block_to_expert, total_rows)
    return ragged_matmul_ref(h, w2, block_to_expert, total_rows)


# ---------------------------------------------------------------------------
# dispatch/combine — oracles for kernels/dispatch_pallas.py (same float32
# accumulate-then-cast discipline, so interpret-mode parity is bit-for-bit)
# ---------------------------------------------------------------------------

def scatter_rows_ref(x: jax.Array, src: jax.Array, total_rows,
                     weights: jax.Array | None = None) -> jax.Array:
    """x: (T, d), src: (R,) source-row map (-1 = empty) -> (R, d)."""
    R = src.shape[0]
    rows = jnp.take(x, jnp.maximum(src, 0), axis=0).astype(jnp.float32)
    if weights is not None:
        rows = rows * weights[:, None].astype(jnp.float32)
    live = (src >= 0) & (jnp.arange(R) < jnp.asarray(total_rows))
    return jnp.where(live[:, None], rows, 0.0).astype(x.dtype)


def fused_moe_ref(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
                  src: jax.Array, slots: jax.Array, block_to_expert: jax.Array,
                  total_rows, weights: jax.Array | None = None) -> jax.Array:
    """Oracle for kernels/fused_moe.py: dispatch -> SwiGLU -> down-proj ->
    weighted combine, mirroring the fused kernel's arithmetic exactly.

    The fused kernel scatters expert outputs into each token's row in
    *ascending buffer-row* order (it walks the ragged layout front to back),
    so this ref sorts each token's slots ascending before the fp32
    slot-by-slot accumulation; h is cast to the working dtype before the
    down-proj (the kernel's epilogue cast) while y stays fp32 through the
    combine.  Under exact arithmetic (integer-valued inputs, power-of-two
    weights) parity with the kernel is bit-for-bit."""
    T, _ = x.shape
    R = src.shape[0]
    buf = scatter_rows_ref(x, src, total_rows)                    # (R, d)
    h = ragged_swiglu_ref(buf, w1, w3, block_to_expert, total_rows)
    hb = _blocked(h, block_to_expert)
    wb = jnp.take(w2, block_to_expert, axis=0)
    y = jnp.einsum("bmk,bkn->bmn", hb, wb,
                   preferred_element_type=jnp.float32).reshape(R, -1)

    order = jnp.argsort(jnp.where(slots < 0, R, slots), axis=1)
    ss = jnp.take_along_axis(slots, order, axis=1)
    ww = None if weights is None else jnp.take_along_axis(weights, order, axis=1)
    acc = jnp.zeros((T, y.shape[1]), jnp.float32)
    for k in range(ss.shape[1]):
        s = ss[:, k]
        row = jnp.take(y, jnp.maximum(s, 0), axis=0)
        if ww is not None:
            row = row * ww[:, k, None].astype(jnp.float32)
        acc = acc + jnp.where((s >= 0)[:, None], row, 0.0)
    return acc.astype(x.dtype)


def gather_combine_ref(buf: jax.Array, slots: jax.Array,
                       weights: jax.Array | None = None) -> jax.Array:
    """buf: (R, d), slots: (T, K) (-1 = dropped) -> (T, d) weighted K-sum.

    Accumulates slot-by-slot in float32 with a masked add per k — the same
    expression the kernel evaluates per row.  Parity with the kernel is
    bit-for-bit whenever the arithmetic is exact (the backend is free to
    FMA-contract either side, which only matters in the last ulp)."""
    T, K = slots.shape
    acc = jnp.zeros((T, buf.shape[1]), jnp.float32)
    for k in range(K):
        s = slots[:, k]
        row = jnp.take(buf, jnp.maximum(s, 0), axis=0).astype(jnp.float32)
        if weights is not None:
            row = row * weights[:, k, None].astype(jnp.float32)
        acc = acc + jnp.where((s >= 0)[:, None], row, 0.0)
    return acc.astype(buf.dtype)
