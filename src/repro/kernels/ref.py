"""Pure-jnp oracles for the Pallas kernels (and the CPU/dry-run compute path).

The grouped expert FFN is the compute hot spot MemFine schedules around:
dispatched buffers (E, C, d) hit per-expert SwiGLU FFNs (E, d, f)/(E, f, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., E, M, K), w: (E, K, N) -> (..., E, M, N)."""
    return jnp.einsum("...emk,ekn->...emn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def grouped_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array) -> jax.Array:
    """silu(x @ w1) * (x @ w3), per expert group."""
    a = jnp.einsum("...emk,ekn->...emn", x, w1, preferred_element_type=jnp.float32)
    b = jnp.einsum("...emk,ekn->...emn", x, w3, preferred_element_type=jnp.float32)
    return (jax.nn.silu(a) * b).astype(x.dtype)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                   w2: jax.Array) -> jax.Array:
    """Full per-expert SwiGLU FFN: (..., E, C, d) -> (..., E, C, d)."""
    h = grouped_swiglu_ref(x, w1, w3)
    return grouped_matmul_ref(h, w2)


# ---------------------------------------------------------------------------
# ragged (flat expert-grouped rows) layout — oracle for kernels/ragged_mlp.py
# ---------------------------------------------------------------------------

def _blocked(x: jax.Array, block_to_expert: jax.Array):
    R = x.shape[0]
    nb = block_to_expert.shape[0]
    return x.reshape(nb, R // nb, x.shape[1])


def ragged_matmul_ref(x: jax.Array, w: jax.Array, block_to_expert: jax.Array,
                      total_rows) -> jax.Array:
    """x: (R, K) expert-grouped rows -> (R, N); rows past total_rows are 0.
    Blocked formulation: weights gathered per bm-row block (one expert per
    block by construction), so the gather is (nb, K, N), never (R, K, N)."""
    R, K = x.shape
    xb = _blocked(x, block_to_expert)                            # (nb, bm, K)
    wb = jnp.take(w, block_to_expert, axis=0)                    # (nb, K, N)
    out = jnp.einsum("bmk,bkn->bmn", xb, wb,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                      block_to_expert: jax.Array, total_rows) -> jax.Array:
    R, K = x.shape
    xb = _blocked(x, block_to_expert)
    a = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w1, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    b = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w3, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    out = (jax.nn.silu(a) * b).astype(x.dtype).reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_expert_ffn_ref(x: jax.Array, w1, w3, w2, block_to_expert,
                          total_rows) -> jax.Array:
    h = ragged_swiglu_ref(x, w1, w3, block_to_expert, total_rows)
    return ragged_matmul_ref(h, w2, block_to_expert, total_rows)
