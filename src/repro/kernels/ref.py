"""Pure-jnp oracles for the Pallas kernels (and the CPU/dry-run compute path).

The grouped expert FFN is the compute hot spot MemFine schedules around:
dispatched buffers (E, C, d) hit per-expert SwiGLU FFNs (E, d, f)/(E, f, d).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grouped_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., E, M, K), w: (E, K, N) -> (..., E, M, N)."""
    return jnp.einsum("...emk,ekn->...emn", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def grouped_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array) -> jax.Array:
    """silu(x @ w1) * (x @ w3), per expert group."""
    a = jnp.einsum("...emk,ekn->...emn", x, w1, preferred_element_type=jnp.float32)
    b = jnp.einsum("...emk,ekn->...emn", x, w3, preferred_element_type=jnp.float32)
    return (jax.nn.silu(a) * b).astype(x.dtype)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                   w2: jax.Array) -> jax.Array:
    """Full per-expert SwiGLU FFN: (..., E, C, d) -> (..., E, C, d)."""
    h = grouped_swiglu_ref(x, w1, w3)
    return grouped_matmul_ref(h, w2)


# ---------------------------------------------------------------------------
# ragged (flat expert-grouped rows) layout — oracle for kernels/ragged_mlp.py
# ---------------------------------------------------------------------------

def _blocked(x: jax.Array, block_to_expert: jax.Array):
    R = x.shape[0]
    nb = block_to_expert.shape[0]
    return x.reshape(nb, R // nb, x.shape[1])


def ragged_matmul_ref(x: jax.Array, w: jax.Array, block_to_expert: jax.Array,
                      total_rows) -> jax.Array:
    """x: (R, K) expert-grouped rows -> (R, N); rows past total_rows are 0.
    Blocked formulation: weights gathered per bm-row block (one expert per
    block by construction), so the gather is (nb, K, N), never (R, K, N)."""
    R, K = x.shape
    xb = _blocked(x, block_to_expert)                            # (nb, bm, K)
    wb = jnp.take(w, block_to_expert, axis=0)                    # (nb, K, N)
    out = jnp.einsum("bmk,bkn->bmn", xb, wb,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_swiglu_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                      block_to_expert: jax.Array, total_rows) -> jax.Array:
    R, K = x.shape
    xb = _blocked(x, block_to_expert)
    a = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w1, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    b = jnp.einsum("bmk,bkn->bmn", xb, jnp.take(w3, block_to_expert, axis=0),
                   preferred_element_type=jnp.float32)
    out = (jax.nn.silu(a) * b).astype(x.dtype).reshape(R, -1)
    live = jnp.arange(R) < jnp.asarray(total_rows)
    return jnp.where(live[:, None], out, 0)


def ragged_expert_ffn_ref(x: jax.Array, w1, w3, w2, block_to_expert,
                          total_rows) -> jax.Array:
    h = ragged_swiglu_ref(x, w1, w3, block_to_expert, total_rows)
    return ragged_matmul_ref(h, w2, block_to_expert, total_rows)


# ---------------------------------------------------------------------------
# dispatch/combine — oracles for kernels/dispatch_pallas.py (same float32
# accumulate-then-cast discipline, so interpret-mode parity is bit-for-bit)
# ---------------------------------------------------------------------------

def scatter_rows_ref(x: jax.Array, src: jax.Array, total_rows,
                     weights: jax.Array | None = None) -> jax.Array:
    """x: (T, d), src: (R,) source-row map (-1 = empty) -> (R, d)."""
    R = src.shape[0]
    rows = jnp.take(x, jnp.maximum(src, 0), axis=0).astype(jnp.float32)
    if weights is not None:
        rows = rows * weights[:, None].astype(jnp.float32)
    live = (src >= 0) & (jnp.arange(R) < jnp.asarray(total_rows))
    return jnp.where(live[:, None], rows, 0.0).astype(x.dtype)


def gather_combine_ref(buf: jax.Array, slots: jax.Array,
                       weights: jax.Array | None = None) -> jax.Array:
    """buf: (R, d), slots: (T, K) (-1 = dropped) -> (T, d) weighted K-sum.

    Accumulates slot-by-slot in float32 with a masked add per k — the same
    expression the kernel evaluates per row.  Parity with the kernel is
    bit-for-bit whenever the arithmetic is exact (the backend is free to
    FMA-contract either side, which only matters in the last ulp)."""
    T, K = slots.shape
    acc = jnp.zeros((T, buf.shape[1]), jnp.float32)
    for k in range(K):
        s = slots[:, k]
        row = jnp.take(buf, jnp.maximum(s, 0), axis=0).astype(jnp.float32)
        if weights is not None:
            row = row * weights[:, k, None].astype(jnp.float32)
        acc = acc + jnp.where((s >= 0)[:, None], row, 0.0)
    return acc.astype(buf.dtype)
