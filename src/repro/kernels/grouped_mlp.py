"""Pallas TPU kernels: grouped (per-expert) blocked matmul and fused SwiGLU.

TPU adaptation of the expert-FFN hot spot (docs/DESIGN.md §6): the dispatched
buffer (E, C, d) is contracted against stacked expert weights with a
(E, C/bm, N/bn, K/bk) grid.  The K loop is innermost so the (bm, bn) output
tile stays resident in VMEM (revisited across k steps) and accumulates in
fp32 scratch; tiles are MXU-aligned multiples of 128 where shapes allow.

On this CPU container the kernels are validated with ``interpret=True``
against ``ref.py`` (Pallas does not lower to the CPU backend otherwise);
``ops.py`` selects the jnp reference path for CPU / dry-run executions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import pick_block as _pick_block


def _matmul_kernel(x_ref, w_ref, o_ref, acc, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def _swiglu_kernel(x_ref, w1_ref, w3_ref, o_ref, acc1, acc3, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        acc3[...] = jnp.zeros_like(acc3)

    acc1[...] += jnp.dot(x_ref[0], w1_ref[0], preferred_element_type=jnp.float32)
    acc3[...] += jnp.dot(x_ref[0], w3_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0] = (jax.nn.silu(acc1[...]) * acc3[...]).astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x: (E, M, K) @ w: (E, K, N) -> (E, M, N), one expert per grid row."""
    E, M, K = x.shape
    _, _, N = w.shape
    bm, bn, bk = _pick_block(M, block_m), _pick_block(N, block_n), _pick_block(K, block_k)
    n_k = K // bk
    grid = (E, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def grouped_swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, *,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   interpret: bool = False) -> jax.Array:
    """Fused silu(x@w1) * (x@w3) per expert: (E, M, K) -> (E, M, N)."""
    E, M, K = x.shape
    _, _, N = w1.shape
    bm, bn, bk = _pick_block(M, block_m), _pick_block(N, block_n), _pick_block(K, block_k)
    n_k = K // bk
    grid = (E, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, w1, w3)
