"""Pallas TPU kernels: grouped (per-expert) blocked matmul and fused SwiGLU.

TPU adaptation of the expert-FFN hot spot (docs/DESIGN.md §6): the dispatched
buffer (E, C, d) is contracted against stacked expert weights with a
(E, C/bm, N/bn, K/bk) grid.  The K loop is innermost so the (bm, bn) output
tile stays resident in VMEM (revisited across k steps) and accumulates in
fp32 scratch; tiles are MXU-aligned multiples of 128 where shapes allow.

Tile sizes resolve through the measured autotuner cache (docs/DESIGN.md
§Autotune) with heuristic defaults as the cold-cache fallback; operands are
zero-padded to the chosen block multiples (exact under contraction, padded
output rows/cols sliced off), so ANY block size is legal — no sub-lane tiles
on prime dims, and the autotuner searches a free grid.

On this CPU container the kernels are validated with ``interpret=True``
against ``ref.py`` (Pallas does not lower to the CPU backend otherwise);
``ops.py`` selects the jnp reference path for CPU / dry-run executions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import choose_block, resolve_tiles

_DEFAULTS = {"bm": 128, "bn": 128, "bk": 512}


def _padded_operands(op, x, w_list, block_m, block_n, block_k):
    """Resolve tiles and zero-pad (E, M, K) x and (E, K, N) weights."""
    E, M, K = x.shape
    N = w_list[0].shape[2]
    tiles = resolve_tiles(op, (E, M, K, N), x.dtype, _DEFAULTS,
                          {"bm": block_m, "bn": block_n, "bk": block_k})
    cm = choose_block(M, tiles["bm"])
    cn = choose_block(N, tiles["bn"])
    ck = choose_block(K, tiles["bk"])
    if (cm.padded, ck.padded) != (M, K):
        x = jnp.pad(x, ((0, 0), (0, cm.padded - M), (0, ck.padded - K)))
    if (ck.padded, cn.padded) != (K, N):
        w_list = [jnp.pad(w, ((0, 0), (0, ck.padded - K), (0, cn.padded - N)))
                  for w in w_list]
    return x, w_list, cm, cn, ck


def _matmul_kernel(x_ref, w_ref, o_ref, acc, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0] = acc[...].astype(o_ref.dtype)


def _swiglu_kernel(x_ref, w1_ref, w3_ref, o_ref, acc1, acc3, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        acc3[...] = jnp.zeros_like(acc3)

    acc1[...] += jnp.dot(x_ref[0], w1_ref[0], preferred_element_type=jnp.float32)
    acc3[...] += jnp.dot(x_ref[0], w3_ref[0], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[0] = (jax.nn.silu(acc1[...]) * acc3[...]).astype(o_ref.dtype)


def grouped_matmul(x: jax.Array, w: jax.Array, *, block_m: int | None = None,
                   block_n: int | None = None, block_k: int | None = None,
                   interpret: bool = False) -> jax.Array:
    """x: (E, M, K) @ w: (E, K, N) -> (E, M, N), one expert per grid row."""
    E, M, K = x.shape
    _, _, N = w.shape
    xp, (wp,), cm, cn, ck = _padded_operands(
        "grouped_matmul", x, [w], block_m, block_n, block_k)
    bm, bn, bk = cm.block, cn.block, ck.block
    grid = (E, cm.grid, cn.grid, ck.grid)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=ck.grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, cm.padded, cn.padded), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    return out[:, :M, :N]


def grouped_swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, *,
                   block_m: int | None = None, block_n: int | None = None,
                   block_k: int | None = None,
                   interpret: bool = False) -> jax.Array:
    """Fused silu(x@w1) * (x@w3) per expert: (E, M, K) -> (E, M, N)."""
    E, M, K = x.shape
    _, _, N = w1.shape
    xp, (w1p, w3p), cm, cn, ck = _padded_operands(
        "grouped_swiglu", x, [w1, w3], block_m, block_n, block_k)
    bm, bn, bk = cm.block, cn.block, ck.block
    grid = (E, cm.grid, cn.grid, ck.grid)
    out = pl.pallas_call(
        functools.partial(_swiglu_kernel, n_k=ck.grid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, cm.padded, cn.padded), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(xp, w1p, w3p)
    return out[:, :M, :N]
