"""Jit-friendly wrappers that select the Pallas kernel or the jnp reference.

``use_pallas`` defaults to False because this container (and the dry-run) runs
on the CPU backend, where Pallas only executes in interpret mode.  On a real
TPU deployment the launchers pass ``use_pallas=True``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch_pallas as dp
from repro.kernels import ref
from repro.kernels.grouped_mlp import grouped_matmul, grouped_swiglu
from repro.kernels.ragged_mlp import ragged_matmul, ragged_swiglu


def _f0(v):
    return np.zeros(v.shape, jax.dtypes.float0)


def expert_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array, *,
               use_pallas: bool = False, interpret: bool = False) -> jax.Array:
    """Per-expert SwiGLU FFN over dispatched buffers.

    x: (..., E, C, d); w1, w3: (E, d, f); w2: (E, f, d) -> (..., E, C, d).
    Leading batch dims are vmapped over for the kernel path.
    """
    if not use_pallas:
        return ref.expert_ffn_ref(x, w1, w3, w2)

    def one(xb):
        h = grouped_swiglu(xb, w1, w3, interpret=interpret)
        return grouped_matmul(h, w2, interpret=interpret)

    fn = one
    for _ in range(x.ndim - 3):
        fn = jax.vmap(fn)
    return fn(x)


# ---------------------------------------------------------------------------
# dispatch / combine with a transpose-symmetric custom VJP
#
# Combine is the exact transpose of dispatch, so instead of letting autodiff
# transpose a scatter (serialized scatter HLO + a (G, cap, d) residual graph),
# dispatch-backward *calls the combine kernel* and combine-backward *calls the
# dispatch kernel*; the router-weight grad is a segment dot.  The only arrays
# saved for backward are the int32 index maps (and, for combine, its own
# primal inputs) — no dispatch buffer survives autodiff.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _dispatch_k(x, slots, src, block_m, interpret):
    # slots is residual-only (consumed by the backward gather)
    return dp.scatter_rows(x, src, src.shape[0], block_m=block_m,
                           interpret=interpret)


def _dispatch_fwd(x, slots, src, block_m, interpret):
    return _dispatch_k(x, slots, src, block_m, interpret), (slots, src)


def _dispatch_bwd(block_m, interpret, res, g):
    slots, src = res
    # transpose of scatter = gather: dx[t] = sum_k g[slot[t, k]]
    dx = dp.gather_combine(g, slots, None, block_t=block_m,
                           interpret=interpret)
    return dx, _f0(slots), _f0(src)


_dispatch_k.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _combine_k(buf, slots, weights, total_rows, block_t, interpret):
    return dp.gather_combine(buf, slots, weights, block_t=block_t,
                             interpret=interpret)


def _combine_fwd(buf, slots, weights, total_rows, block_t, interpret):
    y = _combine_k(buf, slots, weights, total_rows, block_t, interpret)
    return y, (buf, slots, weights, total_rows)


def _combine_bwd(block_t, interpret, res, g):
    buf, slots, weights, total_rows = res
    T, K = slots.shape
    R = buf.shape[0]
    from repro.core.dispatch import invert_slots
    # transpose of gather = scatter, with the combine weight riding along:
    # dbuf[r] = w_flat[pos(r)] * g[token(r)].  With a prefix layout
    # (ragged), total_rows predicates off the dead row-blocks.
    pos = invert_slots(slots, R)                           # (R,) flat (t*K+k)
    src_tok = jnp.where(pos >= 0, pos // K, -1)
    wslot = jnp.where(
        pos >= 0, jnp.take(weights.reshape(-1), jnp.maximum(pos, 0)), 0)
    dbuf = dp.scatter_rows(g, src_tok, total_rows, wslot, block_m=block_t,
                           interpret=interpret)
    # weight grad via a segment dot: dw[t,k] = <g[t], buf[slot[t,k]]>
    rows = jnp.take(buf, jnp.maximum(slots, 0), axis=0)    # (T, K, d)
    dw = jnp.einsum("td,tkd->tk", g.astype(jnp.float32),
                    rows.astype(jnp.float32))
    dw = jnp.where(slots >= 0, dw, 0.0).astype(weights.dtype)
    return dbuf, _f0(slots), dw, _f0(total_rows)


_combine_k.defvjp(_combine_fwd, _combine_bwd)


def dispatch_rows(x: jax.Array, slots: jax.Array, rows: int,
                  total_rows=None, *, use_pallas: bool = False,
                  interpret: bool = False, block_m: int = 8) -> jax.Array:
    """Build the (rows, d) dispatch buffer from x (T, d) and the planner's
    slot map (T, K).  Pallas path: scalar-prefetched gather-formulated
    scatter with row-block predication past ``total_rows`` and a custom VJP
    whose backward is the combine kernel."""
    if not use_pallas:
        from repro.core.dispatch import scatter_rows_flat
        return scatter_rows_flat(x, slots, rows)
    from repro.core.dispatch import invert_slots
    K = slots.shape[1]
    pos = invert_slots(slots, rows)
    src_tok = jnp.where(pos >= 0, pos // K, -1)
    if total_rows is not None:
        # predication hint: with a prefix layout, blocks past the routed load
        # are skipped entirely (issued copies track the ACTUAL load)
        src_tok = jnp.where(jnp.arange(rows) < jnp.asarray(total_rows),
                            src_tok, -1)
    return _dispatch_k(x, slots, src_tok, block_m, interpret)


def combine_rows(buf: jax.Array, slots: jax.Array,
                 weights: jax.Array | None = None, total_rows=None, *,
                 use_pallas: bool = False, interpret: bool = False,
                 block_t: int = 8) -> jax.Array:
    """Inverse of dispatch_rows: (rows, d) -> (T, d), each token the weighted
    sum of its K slot rows.  Pallas path: gather kernel with a custom VJP
    whose backward is the dispatch kernel (+ segment dot for the weights);
    pass ``total_rows`` for prefix (ragged) layouts so the backward scatter
    predicates off dead row-blocks."""
    if not use_pallas:
        from repro.core.dispatch import gather_rows_flat
        return gather_rows_flat(buf, slots, weights)
    T, K = slots.shape
    if weights is None:
        weights = jnp.ones((T, K), buf.dtype)
    total = jnp.asarray(buf.shape[0] if total_rows is None else total_rows,
                        jnp.int32)
    return _combine_k(buf, slots, weights, total, block_t, interpret)


def _segment_outer(a: jax.Array, b: jax.Array, b2e: jax.Array,
                   num_experts: int) -> jax.Array:
    """Per-expert sum of block outer products: dw[e] = sum_{blocks of e}
    a_block^T @ b_block.  A scan over blocks — never materialises a
    (n_blocks, d, f) tensor (the weight-gather trap of the jnp fallback)."""
    nb = b2e.shape[0]
    R = a.shape[0]
    ab = a.reshape(nb, R // nb, a.shape[1])
    bb = b.reshape(nb, R // nb, b.shape[1])
    acc0 = jnp.zeros((num_experts, a.shape[1], b.shape[1]), jnp.float32)

    def body(acc, inp):
        ai, bi, e = inp
        contrib = jnp.dot(ai.T, bi, preferred_element_type=jnp.float32)
        return acc.at[e].add(contrib), None

    acc, _ = jax.lax.scan(body, acc0, (ab, bb, b2e))
    return acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _ragged_ffn_kernel(x, w1, w3, w2, b2e, rows, block_m, interpret):
    h = ragged_swiglu(x, w1, w3, b2e, rows, block_m=block_m,
                      interpret=interpret)
    return ragged_matmul(h, w2, b2e, rows, block_m=block_m,
                         interpret=interpret)


def _ragged_ffn_fwd(x, w1, w3, w2, b2e, rows, block_m, interpret):
    y = _ragged_ffn_kernel(x, w1, w3, w2, b2e, rows, block_m, interpret)
    return y, (x, w1, w3, w2, b2e, rows)


def _ragged_ffn_bwd(block_m, interpret, res, gy):
    x, w1, w3, w2, b2e, rows = res
    E = w1.shape[0]
    mm = functools.partial(ragged_matmul, block_to_expert=b2e,
                           total_rows=rows, block_m=block_m,
                           interpret=interpret)
    # recompute the two up-projections (chunk-recompute discipline: no (R, f)
    # residuals are ever stored)
    h1 = mm(x, w1).astype(jnp.float32)
    h3 = mm(x, w3).astype(jnp.float32)
    s = jax.nn.sigmoid(h1)
    silu_h1 = h1 * s
    a = (silu_h1 * h3).astype(x.dtype)
    da = mm(gy, jnp.swapaxes(w2, 1, 2)).astype(jnp.float32)
    dh3 = (da * silu_h1).astype(x.dtype)
    dh1 = (da * h3 * (s + silu_h1 * (1 - s))).astype(x.dtype)
    dx = (mm(dh1, jnp.swapaxes(w1, 1, 2))
          + mm(dh3, jnp.swapaxes(w3, 1, 2))).astype(x.dtype)
    dw1 = _segment_outer(x, dh1, b2e, E).astype(w1.dtype)
    dw3 = _segment_outer(x, dh3, b2e, E).astype(w3.dtype)
    dw2 = _segment_outer(a, gy, b2e, E).astype(w2.dtype)
    return dx, dw1, dw3, dw2, _f0(b2e), _f0(rows)


_ragged_ffn_kernel.defvjp(_ragged_ffn_fwd, _ragged_ffn_bwd)


def ragged_expert_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array,
                      w2: jax.Array, block_to_expert: jax.Array,
                      total_rows, *, block_m: int = 128,
                      use_pallas: bool = False,
                      interpret: bool = False) -> jax.Array:
    """SwiGLU FFN over the MegaBlocks-style flat layout (kernels/ragged_mlp).

    x: (R, d) expert-grouped bm-aligned rows -> (R, d).  On TPU the kernel
    predicates off blocks past ``total_rows``, so issued MXU work scales with
    the ACTUAL routed load instead of the dropless worst case.  The Pallas
    path carries a custom VJP (pallas_call has no autodiff rule): backward
    recomputes the up-projections with the same kernels and accumulates
    weight grads with a per-block scan.
    """
    if not use_pallas:
        return ref.ragged_expert_ffn_ref(x, w1, w3, w2, block_to_expert,
                                         total_rows)
    rows = jnp.asarray(total_rows, jnp.int32)
    return _ragged_ffn_kernel(x, w1, w3, w2,
                              block_to_expert.astype(jnp.int32), rows,
                              block_m, interpret)


# ---------------------------------------------------------------------------
# fully fused MoE leg: dispatch -> SwiGLU -> down-proj -> combine in ONE
# kernel launch (kernels/fused_moe.py) — the (R, d) dispatch buffer never
# exists in HBM on the forward pass.  The custom VJP composes the transpose
# symmetry with chunk-recompute: combine-backward IS the dispatch kernel
# (scatter token grads, combine weight riding along), dispatch-backward IS
# the combine kernel (gather per-token sums), and the FFN interior is
# recomputed with the ragged kernels — so the buffer exists only transiently
# inside the backward, exactly as the three-launch path's VJP already does,
# and no (R, ·) residual is saved.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(9, 10, 11, 12))
def _fused_moe_k(x, w1, w3, w2, src, wslot, slots, b2e, rows,
                 has_weights, block_m, block_k, interpret):
    from repro.kernels.fused_moe import fused_moe
    return fused_moe(x, w1, w3, w2, src, wslot, rows, b2e,
                     block_k=block_k, interpret=interpret)


def _fused_moe_fwd(x, w1, w3, w2, src, wslot, slots, b2e, rows,
                   has_weights, block_m, block_k, interpret):
    y = _fused_moe_k(x, w1, w3, w2, src, wslot, slots, b2e, rows,
                     has_weights, block_m, block_k, interpret)
    # residuals: primal inputs + int32 maps only — no (R, ·) intermediate
    return y, (x, w1, w3, w2, src, wslot, slots, b2e, rows)


def _fused_moe_bwd(has_weights, block_m, block_k, interpret, res, gy):
    x, w1, w3, w2, src, wslot, slots, b2e, rows = res
    E = w1.shape[0]
    # combine-bwd = dispatch kernel: dL/dy[r] = wslot[r] * gy[token(r)]
    g_buf = dp.scatter_rows(gy, src, rows, wslot, block_m=block_m,
                            interpret=interpret)
    # dispatch recompute — the buffer exists only inside this backward
    buf = dp.scatter_rows(x, src, rows, block_m=block_m, interpret=interpret)
    mm = functools.partial(ragged_matmul, block_to_expert=b2e,
                           total_rows=rows, block_m=block_m,
                           interpret=interpret)
    h1 = mm(buf, w1).astype(jnp.float32)
    h3 = mm(buf, w3).astype(jnp.float32)
    s = jax.nn.sigmoid(h1)
    silu_h1 = h1 * s
    a = (silu_h1 * h3).astype(x.dtype)
    da = mm(g_buf, jnp.swapaxes(w2, 1, 2)).astype(jnp.float32)
    dh3 = (da * silu_h1).astype(x.dtype)
    dh1 = (da * h3 * (s + silu_h1 * (1 - s))).astype(x.dtype)
    dbuf = (mm(dh1, jnp.swapaxes(w1, 1, 2))
            + mm(dh3, jnp.swapaxes(w3, 1, 2))).astype(x.dtype)
    # dispatch-bwd = combine kernel: dx[t] = sum_k dbuf[slot[t, k]]
    dx = dp.gather_combine(dbuf, slots, None, interpret=interpret)
    dw1 = _segment_outer(buf, dh1, b2e, E).astype(w1.dtype)
    dw3 = _segment_outer(buf, dh3, b2e, E).astype(w3.dtype)
    dw2 = _segment_outer(a, g_buf, b2e, E).astype(w2.dtype)
    if has_weights:
        # d wslot[r] = <gy[token(r)], y[r]> — needs the FFN output, one
        # extra ragged matmul; skipped entirely when the combine is unweighted
        # (the EP local leg, where the router weight is applied later).
        # Evaluated in the SAME (T, K)-shaped einsum as _combine_bwd and then
        # permuted to rows, so the (T, K) router grad the outer transpose
        # reassembles is bit-identical to the three-launch path's.
        from repro.core.dispatch import invert_slots
        y_buf = mm(a, w2)                                  # == combine's buf
        rows_y = jnp.take(y_buf, jnp.maximum(slots, 0), axis=0)   # (T, K, d)
        dwtk = jnp.einsum("td,tkd->tk", gy.astype(jnp.float32),
                          rows_y.astype(jnp.float32))
        dwtk = jnp.where(slots >= 0, dwtk, 0.0).astype(wslot.dtype)
        pos = invert_slots(slots, wslot.shape[0])
        d_wslot = jnp.where(
            pos >= 0, jnp.take(dwtk.reshape(-1), jnp.maximum(pos, 0)),
            jnp.zeros((), wslot.dtype))
    else:
        d_wslot = jnp.zeros_like(wslot)
    return (dx, dw1, dw3, dw2, _f0(src), d_wslot, _f0(slots), _f0(b2e),
            _f0(rows))


_fused_moe_k.defvjp(_fused_moe_fwd, _fused_moe_bwd)


def moe_ffn(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
            slots: jax.Array, block_to_expert: jax.Array, total_rows,
            weights: jax.Array | None = None, *, block_m: int = 128,
            block_k: int | None = None, use_pallas: bool = False,
            interpret: bool = False) -> jax.Array:
    """The whole per-chunk expert leg in one launch: x (T, d) + slot map
    (T, K) -> (T, d) weighted expert-FFN combine, over the MegaBlocks-style
    flat layout described by ``block_to_expert``/``total_rows`` (buffer size
    R = len(block_to_expert) * block_m).

    Pallas path: kernels/fused_moe.py (persistent single launch; the (R, d)
    dispatch buffer never touches HBM on forward) with the transpose-
    symmetric chunk-recompute VJP above.  jnp path: the composed reference
    (scatter -> ragged FFN ref -> gather), autodiff'd as-is."""
    R = block_to_expert.shape[0] * block_m
    if not use_pallas:
        from repro.core.dispatch import scatter_rows_flat, gather_rows_flat
        buf = scatter_rows_flat(x, slots, R)
        y = ref.ragged_expert_ffn_ref(buf, w1, w3, w2, block_to_expert,
                                      total_rows)
        return gather_rows_flat(y, slots, weights)
    from repro.core.dispatch import invert_slots
    T, K = slots.shape
    # derive the row-side maps OUTSIDE the custom_vjp: wslot is a
    # differentiable gather of the router weights, so its cotangent
    # transposes back to (T, K) automatically
    pos = invert_slots(slots, R)
    src = jnp.where(pos >= 0, pos // K, -1)
    if weights is None:
        w_flat = jnp.ones((T * K,), x.dtype)
    else:
        w_flat = weights.reshape(-1)
    wslot = jnp.where(pos >= 0, jnp.take(w_flat, jnp.maximum(pos, 0)),
                      jnp.zeros((), x.dtype))
    return _fused_moe_k(x, w1, w3, w2, src, wslot,
                        slots.astype(jnp.int32),
                        block_to_expert.astype(jnp.int32),
                        jnp.asarray(total_rows, jnp.int32),
                        weights is not None, block_m, block_k, interpret)
