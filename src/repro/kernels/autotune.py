"""Measured tile autotuner for the Pallas kernels (docs/DESIGN.md §Autotune).

The tuning pass is separate from the kernels themselves (the
transformation-pass shape of DaCe's optimization layer): kernels declare
*which* tile names they consume and a heuristic default, and this module
owns *how* winners are found and remembered.

* **Search** — ``autotune`` times a caller-built kernel closure over a
  candidate tile grid with the paired-block methodology of
  ``benchmarks/pipeline_microbench.py``: candidates are timed interleaved
  in blocks (min over repeats within a block, median across blocks per
  candidate), so common-mode machine drift hits every candidate alike.
  Candidates that fail to compile/execute (e.g. VMEM overflow on a real
  TPU) are skipped, not fatal.  Because the kernels pad to any block size
  (kernels/tiling.py::choose_block), the space is a free grid — not just
  divisors.
* **Persistence** — winners are stored per ``(op, shape, dtype,
  device_kind)`` in an on-disk JSON cache (``REPRO_AUTOTUNE_CACHE`` or
  ``~/.cache/repro/autotune.json``).  Every kernel in the package consults
  it through ``tiling.resolve_tiles`` at trace time; a missing or corrupt
  cache silently falls back to the heuristic defaults — tuning is an
  optimization, never a correctness dependency.
"""

from __future__ import annotations

import json
import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "autotune.json")
_ENV = "REPRO_AUTOTUNE_CACHE"

#: lazily-loaded in-process view of the on-disk cache; reset by set_cache_path
_cache: Optional[dict] = None
_cache_from: Optional[str] = None


def cache_path() -> str:
    return os.environ.get(_ENV, DEFAULT_CACHE)


def set_cache_path(path: Optional[str]) -> None:
    """Point the process at a different cache file (tests, benchmarks).
    ``None`` restores the environment/default resolution."""
    global _cache, _cache_from
    if path is None:
        os.environ.pop(_ENV, None)
    else:
        os.environ[_ENV] = path
    _cache, _cache_from = None, None


def load_cache(path: Optional[str] = None) -> dict:
    """Read the JSON cache; a missing, unreadable or corrupt file is an
    empty cache (heuristic fallback), never an error."""
    path = path or cache_path()
    try:
        with open(path) as f:
            data = json.load(f)
        return data if isinstance(data, dict) else {}
    except (OSError, ValueError):
        return {}


def save_cache(cache: dict, path: Optional[str] = None) -> None:
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
    os.replace(tmp, path)


def device_kind() -> str:
    try:
        import jax
        return jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:
        return "unknown"


def cache_key(op: str, shape: Sequence[int], dtype, kind: str | None = None) -> str:
    dname = getattr(dtype, "__name__", None) or getattr(dtype, "name", str(dtype))
    return "|".join([op, "x".join(str(int(s)) for s in shape), str(dname),
                     kind or device_kind()])


def _loaded() -> dict:
    global _cache, _cache_from
    path = cache_path()
    if _cache is None or _cache_from != path:
        _cache = load_cache(path)
        _cache_from = path
    return _cache


def lookup(op: str, shape: Sequence[int], dtype) -> Optional[dict]:
    """Cached winner tiles for this exact (op, shape, dtype, device), or
    None — the trace-time hook ``tiling.resolve_tiles`` calls."""
    entry = _loaded().get(cache_key(op, shape, dtype))
    return dict(entry["tiles"]) if isinstance(entry, dict) and "tiles" in entry \
        else None


def record(op: str, shape: Sequence[int], dtype, tiles: dict, *,
           time_ms: Optional[float] = None,
           baseline_ms: Optional[float] = None) -> None:
    """Persist a winner (and refresh the in-process view)."""
    cache = _loaded()
    cache[cache_key(op, shape, dtype)] = {
        "tiles": {k: int(v) for k, v in tiles.items()},
        "time_ms": time_ms, "baseline_ms": baseline_ms,
    }
    save_cache(cache)


# ---------------------------------------------------------------------------
# measured search
# ---------------------------------------------------------------------------

@dataclass
class AutotuneResult:
    op: str
    winner: dict                      # winning tile dict
    winner_ms: float
    baseline: Optional[dict]          # the heuristic candidate, if supplied
    baseline_ms: Optional[float]
    table: list = field(default_factory=list)   # [(tiles, median_ms)]
    skipped: list = field(default_factory=list)

    @property
    def speedup_vs_baseline(self) -> Optional[float]:
        if self.baseline_ms is None:
            return None
        return self.baseline_ms / self.winner_ms


def _min_time(fn: Callable, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def autotune(op: str, shape: Sequence[int], dtype,
             make_fn: Callable[..., Callable[[], object]],
             candidates: Sequence[dict], *, baseline: Optional[dict] = None,
             blocks: int = 3, repeats: int = 3,
             persist: bool = True) -> AutotuneResult:
    """Measure ``candidates`` and persist the winner for ``(op, shape,
    dtype, device)``.

    ``make_fn(**tiles)`` must return a zero-arg callable that runs the
    kernel to completion (compile + block_until_ready inside the callable's
    first invocation is fine — every candidate is warmed once before
    timing).  ``baseline`` (the heuristic tiling) is prepended to the
    candidate list when given, so the winner is *never slower than the
    heuristic on the measurements that chose it* — the autotuned >=
    heuristic guarantee the microbench asserts.
    """
    cands = list(candidates)
    if baseline is not None and baseline not in cands:
        cands.insert(0, dict(baseline))

    runnable: list[tuple[dict, Callable]] = []
    skipped: list[dict] = []
    for c in cands:
        try:
            fn = make_fn(**c)
            fn()                                   # compile + warm
            runnable.append((c, fn))
        except Exception:
            skipped.append(dict(c))
    if not runnable:
        raise RuntimeError(f"autotune({op}): no candidate ran")

    times: dict[int, list[float]] = {i: [] for i in range(len(runnable))}
    for _ in range(blocks):                        # interleaved: paired blocks
        for i, (_, fn) in enumerate(runnable):
            times[i].append(_min_time(fn, repeats))
    medians = [statistics.median(times[i]) for i in range(len(runnable))]
    win = min(range(len(runnable)), key=medians.__getitem__)

    base_ms = None
    if baseline is not None:
        for i, (c, _) in enumerate(runnable):
            if c == baseline:
                base_ms = medians[i] * 1e3
                break
    result = AutotuneResult(
        op=op, winner=dict(runnable[win][0]), winner_ms=medians[win] * 1e3,
        baseline=baseline, baseline_ms=base_ms,
        table=[(dict(c), m * 1e3) for (c, _), m in zip(runnable, medians)],
        skipped=skipped)
    if persist:
        record(op, shape, dtype, result.winner, time_ms=result.winner_ms,
               baseline_ms=base_ms)
    return result


def matmul_candidates(M: int, N: int, K: int, *,
                      sizes: Sequence[int] = (32, 64, 128, 256, 512),
                      cap: int = 24) -> list[dict]:
    """A bounded (bm, bn, bk) grid for matmul-shaped ops: every size <= the
    padded dim's next multiple, deduped, largest-first truncated to ``cap``
    (the search must stay cheap enough to run inside a microbench)."""
    def opts(dim):
        out = [s for s in sizes if s <= 2 * dim]
        return out or [min(sizes)]
    cands, seen = [], set()
    for bm in opts(M):
        for bn in opts(N):
            for bk in opts(K):
                key = (min(bm, 2 * M), min(bn, 2 * N), min(bk, 2 * K))
                if key in seen:
                    continue
                seen.add(key)
                cands.append({"bm": bm, "bn": bn, "bk": bk})
    cands.sort(key=lambda c: -(c["bm"] * c["bn"] * c["bk"]))
    return cands[:cap]
