"""Token dispatch/combine as Pallas TPU kernels (docs/DESIGN.md §Dispatch).

The jnp path materialises dispatch buffers with ``jnp.zeros().at[idx].add``,
which XLA lowers to serialized scatters on TPU — per-chunk overhead that
grows linearly with the FCDA chunk count.  These kernels drive the same data
movement with scalar-prefetched index maps instead:

* ``scatter_rows``  — build the (R, d) dispatch buffer.  The planner's slot
  map is inverted once (``core/dispatch.py::invert_slots``) so the scatter
  becomes a per-output-row *gather*: row r copies source row ``src[r]``
  (src is SMEM-prefetched, the copy is a dynamic-sublane VMEM slice).
  Row-blocks past ``total_rows`` are predicated off entirely, mirroring
  ``ragged_mlp.py``'s live-block trick: with the MegaBlocks-style flat
  layout the occupied rows form a prefix, so issued copies scale with the
  actual routed load, not the dropless worst case.
* ``gather_combine`` — the exact transpose: token t sums its K slot rows,
  weighted by the router combine weights.

Combine is the transpose of dispatch, so the backward of each is the other
kernel (kernels/ops.py wires the custom VJP); no autodiff'd scatter and no
``(G, cap, d)`` residual appears in the backward graph.

Both source arrays are kept whole in VMEM (BlockSpec over the full array):
FCDA chunking bounds T per chunk, so the source fits comfortably; the grid
only tiles the output rows.  Validated bit-for-bit against kernels/ref.py in
interpret mode; the CPU/dry-run path keeps the jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import choose_block, resolve_tiles


def _scatter_kernel(src_ref, rows_ref, x_ref, w_ref, o_ref, *, bm: int):
    """One (bm, d) output block: row r <- w[r] * x[src[base+r]] (0 if empty)."""
    base = pl.program_id(0) * bm
    live = base < rows_ref[0]

    @pl.when(jnp.logical_not(live))
    def _dead():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(live)
    def _copy():
        def body(r, _):
            s = src_ref[base + r]
            row = x_ref[pl.ds(jnp.maximum(s, 0), 1), :].astype(jnp.float32)
            w = w_ref[pl.ds(r, 1), :].astype(jnp.float32)       # (1, 1)
            row = jnp.where(s >= 0, row * w, 0.0)
            o_ref[pl.ds(r, 1), :] = row.astype(o_ref.dtype)
            return 0

        jax.lax.fori_loop(0, bm, body, 0)


def scatter_rows(x: jax.Array, src: jax.Array, total_rows,
                 weights: jax.Array | None = None, *,
                 block_m: int | None = None,
                 interpret: bool = False) -> jax.Array:
    """x: (T, d) tokens; src: (R,) int32 source-row map (-1 = empty slot)
    -> (R, d) dispatch buffer.  ``weights``: optional per-slot scale (R,)
    (used by the combine-backward, where the router weight rides along).
    Row-blocks past ``total_rows`` are skipped (predicated off); when the
    chosen block does not divide R, src is padded with -1 (dead slots) and
    the padded rows sliced off — any block size is legal."""
    T, d = x.shape
    R = src.shape[0]
    tiles = resolve_tiles("scatter_rows", (T, R, d), x.dtype, {"bm": 8},
                          {"bm": block_m})
    cm = choose_block(R, tiles["bm"])
    bm = cm.block
    if weights is None:
        weights = jnp.ones((R,), x.dtype)
    if cm.padded != R:
        src = jnp.pad(src, (0, cm.padded - R), constant_values=-1)
        weights = jnp.pad(weights, (0, cm.padded - R))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(cm.grid,),
        in_specs=[
            pl.BlockSpec((T, d), lambda i, src, rows: (0, 0)),   # full source
            pl.BlockSpec((bm, 1), lambda i, src, rows: (i, 0)),  # slot weights
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, src, rows: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_scatter_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((cm.padded, d), x.dtype),
        interpret=interpret,
    )(src.astype(jnp.int32), jnp.asarray(total_rows, jnp.int32).reshape(1),
      x, weights.reshape(cm.padded, 1))
    return out[:R]


def _gather_kernel(slots_ref, buf_ref, w_ref, o_ref, *, bt: int, K: int):
    """One (bt, d) output block: token t sums its K weighted slot rows.

    Accumulates in float32.  The backend may FMA-contract the per-slot
    multiply into the accumulate; results agree with ref.py bit-for-bit
    whenever the arithmetic is exact and to ~1 ulp otherwise (the parity
    tests exercise both regimes).
    """
    base = pl.program_id(0) * bt
    d = o_ref.shape[1]

    def body(r, _):
        acc = jnp.zeros((1, d), jnp.float32)
        for k in range(K):                                  # K is small, static
            s = slots_ref[(base + r) * K + k]
            row = buf_ref[pl.ds(jnp.maximum(s, 0), 1), :].astype(jnp.float32)
            wk = w_ref[pl.ds(r, 1), pl.ds(k, 1)].astype(jnp.float32)  # (1, 1)
            acc = acc + jnp.where(s >= 0, row * wk, 0.0)
        o_ref[pl.ds(r, 1), :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bt, body, 0)


def gather_combine(buf: jax.Array, slots: jax.Array,
                   weights: jax.Array | None = None, *,
                   block_t: int | None = None,
                   interpret: bool = False) -> jax.Array:
    """buf: (R, d); slots: (T, K) int32 (-1 = dropped) -> (T, d), each token
    the weighted sum of its K slot rows (the transpose of scatter_rows).
    When the chosen block does not divide T, slots are padded with -1 (dead
    tokens) and the padded rows sliced off — any block size is legal."""
    R, d = buf.shape
    T, K = slots.shape
    tiles = resolve_tiles("gather_combine", (T, R, d), buf.dtype, {"bt": 8},
                          {"bt": block_t})
    ct = choose_block(T, tiles["bt"])
    bt = ct.block
    if weights is None:
        weights = jnp.ones((T, K), buf.dtype)
    if ct.padded != T:
        slots = jnp.pad(slots, ((0, ct.padded - T), (0, 0)),
                        constant_values=-1)
        weights = jnp.pad(weights, ((0, ct.padded - T), (0, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(ct.grid,),
        in_specs=[
            pl.BlockSpec((R, d), lambda i, slots: (0, 0)),       # full buffer
            pl.BlockSpec((bt, K), lambda i, slots: (i, 0)),      # combine wts
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i, slots: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_gather_kernel, bt=bt, K=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ct.padded, d), buf.dtype),
        interpret=interpret,
    )(slots.reshape(-1).astype(jnp.int32), buf, weights.astype(buf.dtype))
    return out[:T]
