"""Fused persistent MoE expert kernel (docs/DESIGN.md §6, §Fused).

The three-launch hot path (``scatter_rows`` dispatch -> grouped SwiGLU +
down-proj -> ``gather_combine``) round-trips the ``(R, d)`` dispatch buffer
through HBM twice per FCDA chunk and pays three kernel launches whose count
scales with the chunk count MACT picks.  This kernel performs the whole leg
in ONE launch over the MegaBlocks-style ragged layout:

  grid step (i, k) — row-block i (bm rows, one expert ``b2e[i]``),
  k-th slice of the d (hidden) contraction:

    1. *dispatch*   gather the block's rows straight from token storage via
                    the SMEM-prefetched inverted slot map ``src`` (exactly
                    the dispatch kernel's gather formulation) into a VMEM
                    scratch tile — the ``(R, d)`` buffer never exists in HBM;
    2. *SwiGLU*     accumulate both up-projections in fp32 VMEM scratch,
                    K-innermost as in ``grouped_mlp.py`` (the (bm, f) tiles
                    stay resident across k steps);
    3. *down-proj + combine* (epilogue, k == n_k-1)  y = silu(h1)*h3 @ w2,
                    then scatter-accumulate ``wslot[r] * y[r]`` into the
                    token-major output block — whose index map is CONSTANT,
                    so the fp32 ``(T, d)`` accumulator stays resident in
                    VMEM for the whole grid: a persistent kernel, written
                    back to HBM once at the end.

Row-blocks past ``total_rows`` are predicated off entirely (prefix layout,
as in ``ragged_mlp.py``); empty slots inside live blocks carry ``src = -1``
and are masked per row.  Accumulation into a token's output row happens in
ascending buffer-row order — ``ref.fused_moe_ref`` mirrors that exact order
so interpret-mode parity is bit-for-bit under exact arithmetic.

Tile sizes resolve through the measured autotuner cache
(kernels/autotune.py) with the padded ``choose_block`` fallback, so any
``block_k`` is legal (the d contraction is zero-padded — exact).  The
backward pass is NOT this kernel: ``kernels/ops.py::moe_ffn`` wires the
transpose-symmetric custom VJP (combine-bwd = dispatch kernel, dispatch-bwd
= combine kernel, FFN recomputed with the ragged kernels), so no ``(R, ·)``
residual survives autodiff either.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import choose_block, resolve_tiles


def _fused_kernel(src_ref, b2e_ref, rows_ref, x_ref, w1_ref, w3_ref, w2_ref,
                  wslot_ref, o_ref, xs, acc1, acc3, *, bm: int, n_k: int):
    i = pl.program_id(0)
    k = pl.program_id(1)
    base = i * bm
    live = base < rows_ref[0]

    @pl.when((i == 0) & (k == 0))
    def _zero_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc1[...] = jnp.zeros_like(acc1)
        acc3[...] = jnp.zeros_like(acc3)

    @pl.when(live)
    def _dispatch_and_up():
        # dispatch leg: gather this block's rows from token storage via the
        # inverted slot map (the scatter expressed as a per-output-row gather)
        def gather(r, _):
            s = src_ref[base + r]
            row = x_ref[pl.ds(jnp.maximum(s, 0), 1), :]
            xs[pl.ds(r, 1), :] = jnp.where(s >= 0, row, 0).astype(xs.dtype)
            return 0

        jax.lax.fori_loop(0, bm, gather, 0)
        # up-projections: fp32 accumulate, K-innermost (grouped_mlp.py)
        acc1[...] += jnp.dot(xs[...], w1_ref[0],
                             preferred_element_type=jnp.float32)
        acc3[...] += jnp.dot(xs[...], w3_ref[0],
                             preferred_element_type=jnp.float32)

    @pl.when(live & (k == n_k - 1))
    def _down_and_combine():
        h = (jax.nn.silu(acc1[...]) * acc3[...]).astype(xs.dtype)
        y = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)

        # combine leg: weighted scatter-accumulate into the persistent
        # token-major fp32 block (ascending row order — the parity contract)
        def scatter(r, _):
            s = src_ref[base + r]
            w = wslot_ref[pl.ds(r, 1), :].astype(jnp.float32)      # (1, 1)
            yr = jax.lax.dynamic_slice_in_dim(y, r, 1, axis=0)
            contrib = jnp.where(s >= 0, yr * w, 0.0)
            t = jnp.maximum(s, 0)
            o_ref[pl.ds(t, 1), :] += contrib
            return 0

        jax.lax.fori_loop(0, bm, scatter, 0)


def fused_moe(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array,
              src: jax.Array, wslot: jax.Array | None, total_rows,
              block_to_expert: jax.Array, *, block_k: int | None = None,
              interpret: bool = False) -> jax.Array:
    """x: (T, d) tokens; src: (R,) inverted slot map (-1 = empty slot);
    wslot: (R,) per-slot combine weight (None = 1); block_to_expert:
    (R // bm,) — the ragged layout's block -> expert map (R must be
    bm-aligned, as produced by ``recv_ragged_plan``/``make_ragged_plan``).

    Returns (T, d): each token the weighted sum of its expert-FFN outputs,
    with the dispatch buffer, SwiGLU intermediates and FFN output all kept
    in VMEM — nothing but the (T, d) result touches HBM on this pass.
    """
    T, d = x.shape
    E, _, f = w1.shape
    R = src.shape[0]
    nb = block_to_expert.shape[0]
    if R % nb:
        raise ValueError(f"rows R={R} not a multiple of {nb} blocks")
    bm = R // nb

    tiles = resolve_tiles("fused_moe", (T, d, f, E, bm), x.dtype,
                          {"bk": 512}, {"bk": block_k})
    ck = choose_block(d, tiles["bk"])
    if ck.padded != d:                      # pad the contraction dim: exact
        x = jnp.pad(x, ((0, 0), (0, ck.padded - d)))
        w1 = jnp.pad(w1, ((0, 0), (0, ck.padded - d), (0, 0)))
        w3 = jnp.pad(w3, ((0, 0), (0, ck.padded - d), (0, 0)))
    bk, n_k = ck.block, ck.grid
    if wslot is None:
        wslot = jnp.ones((R,), x.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb, n_k),
        in_specs=[
            pl.BlockSpec((T, bk), lambda i, k, src, b2e, rows: (0, k)),
            pl.BlockSpec((1, bk, f), lambda i, k, src, b2e, rows: (b2e[i], k, 0)),
            pl.BlockSpec((1, bk, f), lambda i, k, src, b2e, rows: (b2e[i], k, 0)),
            pl.BlockSpec((1, f, d), lambda i, k, src, b2e, rows: (b2e[i], 0, 0)),
            pl.BlockSpec((bm, 1), lambda i, k, src, b2e, rows: (i, 0)),
        ],
        # constant index map: the (T, d) fp32 accumulator is resident across
        # the entire grid — the "persistent" in persistent kernel
        out_specs=pl.BlockSpec((T, d), lambda i, k, src, b2e, rows: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bm, bk), x.dtype),          # gathered row tile
            pltpu.VMEM((bm, f), jnp.float32),       # up-proj accumulators
            pltpu.VMEM((bm, f), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_fused_kernel, bm=bm, n_k=n_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=interpret,
    )(src.astype(jnp.int32), block_to_expert.astype(jnp.int32),
      jnp.asarray(total_rows, jnp.int32).reshape(1),
      x, w1, w3, w2, wslot.reshape(R, 1))
    return out.astype(x.dtype)
