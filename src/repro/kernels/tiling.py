"""Shared tile-size selection for the Pallas kernels (docs/DESIGN.md §6).

Two layers:

* ``pick_block`` — the original divisor-only heuristic (largest divisor of
  the dimension no bigger than the preferred MXU-aligned block).  Kept as
  the cold-cache fallback, but no longer used raw by the kernels: for a
  prime dimension just past the preferred block it degrades to block 1 —
  sub-lane tiles that serialize the MXU.
* ``choose_block`` — the production rule: when the best divisor is
  degenerate (less than half the achievable block), keep the preferred
  block and *pad* the dimension up to the next multiple instead.  Every
  kernel wrapper in this package zero-pads its operands to the padded dims
  and slices/masks the result back, so ANY block size is legal — which is
  also what lets the measured autotuner (kernels/autotune.py) search the
  full tile space instead of only divisors.

Tile preferences themselves are resolved through the autotuner's on-disk
cache (docs/DESIGN.md §Autotune): ``resolve_tiles`` returns the measured
winner for ``(op, shape, dtype, device_kind)`` when one is cached, and the
caller's heuristic defaults otherwise.  Explicit block arguments at a kernel
call site always win over both.
"""

from __future__ import annotations

from typing import NamedTuple


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (MXU likes 128s).

    Heuristic fallback only: degrades to 1 on primes.  Kernels go through
    ``choose_block`` which pads instead of shrinking below half the target.
    """
    b = min(preferred, dim)
    while dim % b:
        b -= 1
    return max(b, 1)


class BlockChoice(NamedTuple):
    """A legal (block, padded_dim) pair: ``block`` divides ``padded``, and
    ``padded - dim`` is the zero/masked tail the kernel wrapper adds."""
    block: int
    padded: int

    @property
    def grid(self) -> int:
        return self.padded // self.block


def choose_block(dim: int, preferred: int) -> BlockChoice:
    """Pick a block for ``dim`` targeting ``preferred``, padding if needed.

    If the largest divisor <= preferred is at least half the achievable
    block (min(preferred, dim)), use it unpadded — the common aligned case,
    zero overhead.  Otherwise (prime or near-prime dims) keep the full
    preferred-size block and pad the dimension up to a multiple: padded
    rows/cols are zeros (exact under contraction) and are sliced or
    predicated off by the wrappers, so no sub-lane tile is ever issued.
    """
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    target = min(max(preferred, 1), dim)
    b = pick_block(dim, preferred)
    if 2 * b >= target:
        return BlockChoice(b, dim)
    return BlockChoice(target, -(-dim // target) * target)


def resolve_tiles(op: str, shape: tuple, dtype, defaults: dict,
                  explicit: dict | None = None) -> dict:
    """Resolve named tile preferences for one kernel call.

    Precedence per tile name: explicit call-site value (not None) >
    autotune-cache winner for ``(op, shape, dtype, device_kind)`` >
    ``defaults``.  Returns a plain dict of ints; callers still pass each
    through ``choose_block`` against the actual dims, so a cached winner
    tuned for one shape family stays legal on any shape.
    """
    out = dict(defaults)
    try:  # cache lookups must never break a trace — fall back silently
        from repro.kernels.autotune import lookup
        cached = lookup(op, shape, dtype)
    except Exception:
        cached = None
    if cached:
        for k in out:
            if k in cached:
                out[k] = int(cached[k])
    if explicit:
        for k, v in explicit.items():
            if v is not None:
                out[k] = int(v)
    return out
