"""Shared tile-size selection for the Pallas kernels (docs/DESIGN.md §6).

Every kernel in this package block-decomposes its operands with the same
rule: the largest divisor of the dimension no bigger than the preferred
(MXU-aligned) block.  One definition here instead of a copy per kernel
module.
"""

from __future__ import annotations


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (MXU likes 128s)."""
    b = min(preferred, dim)
    while dim % b:
        b -= 1
    return max(b, 1)
