"""Flash attention — Pallas TPU kernel (beyond-paper prefill hot spot).

Online-softmax blocked attention: grid (batch*heads, Sq/bq, Skv/bk) with the
KV loop innermost; the (bq, hd) output tile plus running max/denominator live
in VMEM scratch across KV steps.  Causal runs skip fully-masked KV blocks via
``pl.when`` (the jnp path gets the same effect from its triangular python
loop); ``window`` masks a sliding band (mixtral SWA / gemma3 local layers).

Validated in interpret mode against models/attention.py's blocked-jnp path
(itself validated against a naive oracle in tests/test_models.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  n_kv: int, bq: int, bk: int, causal: bool, window: int,
                  scale: float):
    i = pl.program_id(1)          # query block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start = i * bq
    kv_start = j * bk
    # a kv block is live unless entirely in the causal future or entirely
    # past the sliding window
    live = True
    if causal:
        live = kv_start <= q_start + bq - 1
    if window:
        live = jnp.logical_and(live, kv_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = kpos <= qpos
        if window:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(j == n_kv - 1)
    def _epilogue():
        o_ref[0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, S, hd) — heads folded into the leading dim; KV already
    repeated to the query head count.  Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_kv, Skv)
    while S % bq:
        bq //= 2
    while Skv % bk:
        bk //= 2
    n_kv = Skv // bk
    grid = (BH, S // bq, n_kv)
    kernel = functools.partial(_flash_kernel, n_kv=n_kv, bq=bq, bk=bk,
                               causal=causal, window=window,
                               scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
