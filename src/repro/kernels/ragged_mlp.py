"""Ragged (MegaBlocks-style) grouped expert matmul — Pallas TPU kernel.

The dropless per-expert buffer layout computes every CAPACITY slot: with the
theoretical-worst capacity MemFine requires, that is E_local/k more FLOPs
than the tokens actually routed (2x on DeepSeek-V3 shapes, 4x on Mixtral).
This kernel computes a *flat* row buffer sorted by expert, with each
expert's rows padded to the block size so every (bm)-row block belongs to
exactly one expert:

  x:       (R, K)  rows grouped by expert, bm-aligned groups
  w:       (E, K, N) stacked expert weights
  b2e:     (R//bm,) int32 — scalar-prefetched block -> expert map
  rows:    (1,) int32 — total occupied rows; blocks past it are skipped
           (predicated off), so issued MXU work scales with the ACTUAL load,
           not the worst case.

``block_m`` is the layout's row-block size (set by the dispatch plan, R is
always a multiple); the N/K tiles resolve through the autotuner cache
(docs/DESIGN.md §Autotune) and the operands are zero-padded to the chosen
block multiples — exact under contraction, padded output columns sliced off
— so any tile size is legal.

Validated in interpret mode against ref.py; on CPU/dry-run executions the
MoE layer keeps the einsum path (Pallas does not lower to the CPU backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import choose_block, resolve_tiles

_DEFAULTS = {"bn": 128, "bk": 512}


def _padded_nk(op, x, w_list, block_n, block_k):
    """Resolve (bn, bk) and zero-pad x's K dim and the weights' K/N dims."""
    R, K = x.shape
    E, _, N = w_list[0].shape
    tiles = resolve_tiles(op, (R, K, N, E), x.dtype, _DEFAULTS,
                          {"bn": block_n, "bk": block_k})
    cn = choose_block(N, tiles["bn"])
    ck = choose_block(K, tiles["bk"])
    if ck.padded != K:
        x = jnp.pad(x, ((0, 0), (0, ck.padded - K)))
    if (ck.padded, cn.padded) != (K, N):
        w_list = [jnp.pad(w, ((0, 0), (0, ck.padded - K), (0, cn.padded - N)))
                  for w in w_list]
    return x, w_list, cn, ck


def _ragged_kernel(b2e_ref, rows_ref, x_ref, w_ref, o_ref, acc, *, n_k: int):
    k = pl.program_id(2)
    bm = x_ref.shape[0]
    live = pl.program_id(0) * bm < rows_ref[0]

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    @pl.when(live)
    def _compute():
        acc[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def _ragged_swiglu_kernel(b2e_ref, rows_ref, x_ref, w1_ref, w3_ref, o_ref,
                          acc1, acc3, *, n_k: int):
    k = pl.program_id(2)
    bm = x_ref.shape[0]
    live = pl.program_id(0) * bm < rows_ref[0]

    @pl.when(k == 0)
    def _init():
        acc1[...] = jnp.zeros_like(acc1)
        acc3[...] = jnp.zeros_like(acc3)

    @pl.when(live)
    def _compute():
        acc1[...] += jnp.dot(x_ref[...], w1_ref[0],
                             preferred_element_type=jnp.float32)
        acc3[...] += jnp.dot(x_ref[...], w3_ref[0],
                             preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = (jax.nn.silu(acc1[...]) * acc3[...]).astype(o_ref.dtype)


def ragged_matmul(x: jax.Array, w: jax.Array, block_to_expert: jax.Array,
                  total_rows: jax.Array, *, block_m: int = 128,
                  block_n: int | None = None, block_k: int | None = None,
                  interpret: bool = False) -> jax.Array:
    """x: (R, K) bm-aligned expert-grouped rows; w: (E, K, N) -> (R, N)."""
    R, K = x.shape
    E, _, N = w.shape
    bm = block_m
    assert R % bm == 0 and block_to_expert.shape == (R // bm,)
    xp, (wp,), cn, ck = _padded_nk("ragged_matmul", x, [w], block_n, block_k)
    bn, bk = cn.block, ck.block
    grid = (R // bm, cn.grid, ck.grid)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, b2e, rows: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, b2e, rows: (b2e[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, b2e, rows: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_kernel, n_k=ck.grid),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, cn.padded), x.dtype),
        interpret=interpret,
    )(block_to_expert.astype(jnp.int32),
      jnp.asarray(total_rows, jnp.int32).reshape(1), xp, wp)
    return out[:, :N]


def ragged_swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array,
                  block_to_expert: jax.Array, total_rows: jax.Array, *,
                  block_m: int = 128, block_n: int | None = None,
                  block_k: int | None = None,
                  interpret: bool = False) -> jax.Array:
    """Fused silu(x@w1)*(x@w3) over the ragged layout: (R, K) -> (R, N)."""
    R, K = x.shape
    E, _, N = w1.shape
    bm = block_m
    assert R % bm == 0 and block_to_expert.shape == (R // bm,)
    xp, (w1p, w3p), cn, ck = _padded_nk("ragged_swiglu", x, [w1, w3],
                                        block_n, block_k)
    bn, bk = cn.block, ck.block
    grid = (R // bm, cn.grid, ck.grid)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, b2e, rows: (i, k)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, b2e, rows: (b2e[i], k, j)),
            pl.BlockSpec((1, bk, bn), lambda i, j, k, b2e, rows: (b2e[i], k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, b2e, rows: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_ragged_swiglu_kernel, n_k=ck.grid),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, cn.padded), x.dtype),
        interpret=interpret,
    )(block_to_expert.astype(jnp.int32),
      jnp.asarray(total_rows, jnp.int32).reshape(1), xp, w1p, w3p)
    return out[:, :N]
