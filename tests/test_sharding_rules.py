"""Divisibility-guarded sharding rules (subprocess: needs a real mesh)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_param_shardings_guarded():
    src = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=16'
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.models import transformer
        mesh = jax.make_mesh((2, 8), ("data", "model"))
        for arch in ("mixtral-8x7b", "whisper-small", "jamba-1.5-large-398b"):
            cfg = get_config(arch).reduced()
            params = jax.eval_shape(
                lambda k: transformer.init_params(k, cfg),
                jax.ShapeDtypeStruct((2,), jnp.uint32))
            sh = shd.param_shardings(params, mesh, cfg)
            # every sharded dim divides its axis product
            for leaf, s in zip(jax.tree.leaves(params), jax.tree.leaves(sh)):
                spec = list(s.spec) + [None] * (len(leaf.shape) - len(s.spec))
                for dim, ax in zip(leaf.shape, spec):
                    if ax is not None:
                        n = shd.axis_size(mesh, ax)
                        assert dim % n == 0, (arch, leaf.shape, s.spec)
            print("OK", arch)
        # cache pspec: batch-shardable, stacked, and long-context cases
        # (PartitionSpec normalises 1-tuples to bare names)
        assert shd.cache_pspec(mesh, (8, 128, 4, 16), 8)[0] == "data"
        assert shd.cache_pspec(mesh, (3, 8, 128, 4, 16), 8)[1] == "data"
        assert shd.cache_pspec(mesh, (1, 1024, 4, 16), 1)[1] == "data"
        print("CACHE OK")
    """)
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr
    assert "CACHE OK" in out.stdout
