"""The moe_ffn stats contract (see its docstring): load/drops are per-step
TOTALS with identical values across all three strategies, drops == 0 under
dropless capacity for every strategy, and > 0 for an undersized
balanced_capacity baseline."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core import moe as M

CFG = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)


@pytest.fixture(scope="module")
def setup():
    params = M.init_moe(jax.random.PRNGKey(0), 16, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    return params, x


def _mesh11():
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _ctx(strategy, **kw):
    if strategy == "ep_shardmap":
        return M.DistContext(mesh=_mesh11(), moe_strategy=strategy,
                             moe_chunks=2, **kw)
    return M.DistContext(moe_strategy=strategy, moe_chunks=2, **kw)


STRATEGIES = ["ep_shardmap", "tp_gspmd", "dense"]


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_dropless_invariant(setup, strategy):
    params, x = setup
    _, stats = M.moe_ffn(params, x, CFG, _ctx(strategy))
    assert float(stats["drops"]) == 0.0


@pytest.mark.parametrize("strategy", ["ep_shardmap", "tp_gspmd"])
def test_undersized_capacity_drops(setup, strategy):
    params, x = setup
    cap_cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                        capacity_mode="capacity", capacity_factor=0.5)
    _, stats = M.moe_ffn(params, x, cap_cfg, _ctx(strategy))
    assert float(stats["drops"]) > 0.0


def test_load_and_drops_are_per_step_totals(setup):
    """load sums to B*S*K token-slots (totals, not means) and is IDENTICAL
    across strategies; drops likewise."""
    params, x = setup
    B, S, _ = x.shape
    loads, drops = {}, {}
    for s in STRATEGIES:
        _, stats = M.moe_ffn(params, x, CFG, _ctx(s))
        loads[s] = np.asarray(stats["load"])
        drops[s] = float(stats["drops"])
        assert stats["load"].dtype == jnp.float32
    for s in STRATEGIES:
        assert loads[s].sum() == B * S * CFG.top_k, s
        np.testing.assert_array_equal(loads[s], loads["dense"], err_msg=s)
        assert drops[s] == 0.0


def test_ragged_ep_same_stats(setup):
    params, x = setup
    _, s_ep = M.moe_ffn(params, x, CFG, _ctx("ep_shardmap"))
    _, s_rg = M.moe_ffn(params, x, CFG, _ctx("ep_shardmap", moe_ragged=True))
    np.testing.assert_array_equal(np.asarray(s_ep["load"]),
                                  np.asarray(s_rg["load"]))
    assert float(s_rg["drops"]) == 0.0
