"""Substrate tests: optimizer, schedule, data pipeline, checkpointing,
router balancing, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import latest_step, restore, save
from repro.configs import get_config
from repro.configs.base import MoEConfig
from repro.core.moe import DistContext
from repro.core.router import init_router, route, update_bias
from repro.data.pipeline import SyntheticLMData
from repro.models import transformer
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.serving.engine import generate, prefill

CTX = DistContext()


# -- optimizer ---------------------------------------------------------------

def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw_update(grads, state, params, lr=0.1,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clips_gradients():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, m = adamw_update({"w": jnp.full(3, 1e6)}, state, params, lr=0.0)
    assert float(m["grad_norm"]) > 1e6 - 1


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lr_peak = float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                    total_steps=100))
    lr_end = float(cosine_schedule(100, peak_lr=1.0, warmup_steps=10,
                                   total_steps=100))
    assert lr0 == 0.0 and abs(lr_peak - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-6


# -- data --------------------------------------------------------------------

def test_data_deterministic_and_learnable():
    cfg = get_config("llama3.2-3b").reduced()
    d = SyntheticLMData(cfg, 32, 4, seed=7)
    b1, b2 = d.batch_at(3), d.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (b1["tokens"] != d.batch_at(4)["tokens"]).any()
    # labels are next-token
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # structure: majority of transitions follow the affine rule
    t, l = b1["tokens"], b1["labels"]
    frac = ((t * 31 + 7) % cfg.vocab_size == l).mean()
    assert frac > 0.7


def test_data_modality_stubs():
    vlm = get_config("internvl2-76b").reduced()
    b = SyntheticLMData(vlm, 32, 2).batch_at(0)
    assert b["patches"].shape == (2, vlm.num_patch_tokens, vlm.d_model)
    assert b["labels"].shape == (2, 32)
    assert (b["labels"][:, :vlm.num_patch_tokens] == -1).all()
    au = get_config("whisper-small").reduced()
    b = SyntheticLMData(au, 32, 2).batch_at(0)
    assert b["frames"].shape == (2, au.encoder_seq, au.d_model)


# -- checkpointing -----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(5.0))
    assert back["b"]["c"].shape == (2, 3)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"a": jnp.zeros(4)})


# -- router balancing --------------------------------------------------------

def test_loss_free_bias_balances_load():
    """Repeatedly applying the bias update drives routing toward balance."""
    cfg = MoEConfig(num_experts=4, top_k=1, loss_free_bias=True,
                    bias_update_rate=0.05)
    params = init_router(jax.random.PRNGKey(3), 16, 4)
    # skew inputs so one expert dominates initially
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 16)) * 0.1 + 1.0
    loads = []
    for _ in range(50):
        r = route(params, x, cfg)
        loads.append(np.asarray(r.load))
        params = {**params, "bias": update_bias(params["bias"], r.load, cfg)}
    assert loads[-1].max() - loads[-1].min() < loads[0].max() - loads[0].min()


def test_aux_loss_minimal_when_uniform():
    cfg = MoEConfig(num_experts=4, top_k=1)
    E, T = 4, 1024
    params = init_router(jax.random.PRNGKey(0), 8, E)
    # near-uniform logits -> aux ~ 1 (its minimum is 1 for uniform routing)
    params["w"] = jnp.zeros_like(params["w"])
    x = jax.random.normal(jax.random.PRNGKey(1), (T, 8))
    r = route(params, x, cfg)
    assert abs(float(r.aux_loss) - 1.0) < 0.05


# -- serving -----------------------------------------------------------------

def test_prefill_then_generate():
    cfg = get_config("gemma3-27b").reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          cfg.vocab_size)}
    out = generate(params, cfg, CTX, batch, steps=4, cache_len=16)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all()


def test_prefill_logits_match_forward():
    cfg = get_config("llama3.2-3b").reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    logits, _ = prefill(params, cfg, CTX, {"tokens": toks}, cache_len=16)
    full, _ = transformer.forward(params, cfg, CTX, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_cross_entropy_masking():
    import jax.numpy as jnp
    from repro.training.step import cross_entropy
    logits = jnp.log(jnp.array([[[0.7, 0.2, 0.1], [0.1, 0.8, 0.1]]]))
    labels = jnp.array([[0, -1]])          # second position masked
    ce = float(cross_entropy(logits, labels))
    assert abs(ce - (-np.log(0.7))) < 1e-5
    # all-masked is safe (no NaN)
    ce2 = float(cross_entropy(logits, jnp.array([[-1, -1]])))
    assert ce2 == 0.0
