"""Paged-serving parity matrix + paged memory model (docs/DESIGN.md §Paging).

The contract under test: the paged cache path is *bit-identical* to the
monolithic slot map — same greedy tokens for every request across every
cache layout (linear K/V, window ring wrapping at a page boundary, SSM
state + conv tail, hybrid, enc-dec cross attention), with prefix hits,
preemption spill/restore and decode-time CoW in the loop.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import GPU_64G, HardwareProfile
from repro.core import memory_model as mm
from repro.core.moe import DistContext
from repro.models import blocks, transformer
from repro.serving import engine
from repro.serving.paged_cache import PagedCachePool
from repro.serving.paged_scheduler import PagedScheduler
from repro.serving.paging import SCRATCH_PAGE, ZERO_PAGE
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ServeConfig)

CTX = DistContext()


def _model(arch, seed=0):
    cfg = registry()[arch].reduced()
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _trace(cfg, shapes, seed=0, prefix=None):
    """shapes: list of (prompt_len, gen[, priority]); ``prefix`` prepends a
    shared stem to every prompt (prefix-cache scenarios)."""
    rng = np.random.default_rng(seed)
    out = []
    for i, sh in enumerate(shapes):
        S, g, prio = (sh + (0,))[:3]
        toks = rng.integers(0, cfg.vocab_size, S).astype(np.int32)
        if prefix is not None:
            toks = np.concatenate([prefix, toks])
        out.append(Request(rid=i, tokens=toks, max_new_tokens=g,
                           arrival=0.0, priority=prio))
    return out


def _run_pair(arch, cache_len, shapes, *, page=8, chunk=8, slots=3,
              prefix_stem=0, prefix_cache=False, seed=0):
    """Run the same trace through the monolithic and the paged scheduler;
    return both schedulers (outputs compared by the caller)."""
    cfg, params = _model(arch)
    rng = np.random.default_rng(seed + 100)
    stem = (rng.integers(0, cfg.vocab_size, prefix_stem).astype(np.int32)
            if prefix_stem else None)
    mono = ContinuousBatchingScheduler(
        params, cfg, CTX,
        ServeConfig(max_slots=slots, cache_len=cache_len,
                    prefill_chunk=chunk), key=jax.random.PRNGKey(1))
    mono.run(_trace(cfg, shapes, seed, stem))
    paged = PagedScheduler(
        params, cfg, CTX,
        ServeConfig(max_slots=slots, cache_len=cache_len,
                    prefill_chunk=chunk, page_size=page,
                    prefix_cache=prefix_cache), key=jax.random.PRNGKey(1))
    paged.run(_trace(cfg, shapes, seed, stem))
    return mono, paged


def _assert_parity(mono, paged):
    a = {r.rid: list(r.out) for r in mono.finished}
    b = {r.rid: list(r.out) for r in paged.finished}
    assert a == b, f"paged outputs diverge: {a} vs {b}"
    paged.pool.alloc.audit()
    if paged.trie is not None:
        paged.trie.clear()
    for key in paged.pool.alloc.spaces:
        assert paged.pool.alloc.allocated(key) == 0, (
            f"space {key} leaked after drain")


# ---------------------------------------------------------------------------
# parity matrix: every cache layout, paged == monolithic bit for bit
# ---------------------------------------------------------------------------

MATRIX = [
    # linear full-attention caches
    ("llama3.2-3b", 48, [(16, 6), (24, 4), (8, 5)]),
    # window-64 ring wrapping exactly at a page boundary (64 = 8 pages):
    # prompt 72 wraps during prefill, decode keeps wrapping
    ("mixtral-8x7b", 96, [(72, 10), (16, 6)]),
    # window + full attention mix, both group kinds live at once
    ("gemma3-27b", 96, [(40, 6), (24, 4)]),
    # no token caches at all: pure SSM state + conv tail blocks
    ("mamba2-130m", 48, [(16, 6), (24, 4)]),
    # hybrid mamba/attention: state blocks and K/V pages together
    ("jamba-1.5-large-398b", 48, [(16, 5), (24, 4)]),
]


@pytest.mark.parametrize("arch,cache_len,shapes", MATRIX,
                         ids=[m[0] for m in MATRIX])
def test_paged_decode_matches_monolithic(arch, cache_len, shapes):
    mono, paged = _run_pair(arch, cache_len, shapes)
    _assert_parity(mono, paged)
    assert paged.pool.alloc.hwm_bytes() > 0 or not paged.pool.groups


def test_paged_page_size_not_dividing_cache_len():
    """A trailing partial page (cache_len % page != 0) pads, never leaks
    into the dense gather."""
    mono, paged = _run_pair("llama3.2-3b", 44, [(16, 5), (20, 4)], page=8)
    _assert_parity(mono, paged)


# ---------------------------------------------------------------------------
# prefix cache: hit-path prefill is bit-identical to the cold path
# ---------------------------------------------------------------------------

def test_prefix_hit_bit_identical_to_cold():
    """Requests sharing a 16-token system prompt: the trie skips the shared
    chunks on later admissions, yet every output token matches the
    monolithic scheduler (which always prefills cold)."""
    mono, paged = _run_pair("llama3.2-3b", 48, [(8, 5)] * 4,
                            prefix_stem=16, prefix_cache=True)
    _assert_parity(mono, paged)
    st = paged.trie.stats()
    assert st["tokens_reused"] > 0 and st["hits"] >= 3
    m = paged.metrics(1.0)
    assert m["prefix_hit_rate"] > 0.5


def test_prefix_hit_ring_wrap_cow():
    """Decode-time CoW: a prefix-adopted ring page is re-entered when the
    write cursor wraps (mixtral window 64) — the request forks a private
    copy mid-decode and still matches the monolithic tokens."""
    # rid 0 registers its 32-token prompt; rid 1 shares it and generates
    # past the ring (32 + 40 = 72 > 64), wrapping into adopted pages
    mono, paged = _run_pair("mixtral-8x7b", 96, [(0, 4), (0, 40)],
                            prefix_stem=32, prefix_cache=True, slots=2)
    _assert_parity(mono, paged)
    assert paged.trie.stats()["tokens_reused"] > 0


def test_prefix_adopted_cache_equals_cold_prefill_cache():
    """Unit-level: gather_dense over trie-adopted pages + the node's state
    snapshot reproduces the cold chunked-prefill cache bit for bit at the
    matched boundary."""
    cfg, params = _model("llama3.2-3b")
    toks = np.arange(24, dtype=np.int32) % cfg.vocab_size
    scfg = ServeConfig(max_slots=2, cache_len=32, prefill_chunk=8,
                       page_size=8, prefix_cache=True)
    sched = PagedScheduler(params, cfg, CTX, scfg, key=jax.random.PRNGKey(1))
    sched.run([Request(rid=0, tokens=toks, max_new_tokens=2, arrival=0.0)])
    matched, nodes = sched.trie.lookup(toks)
    assert matched == 24                  # raw lookup: every whole block
                                          # (the scheduler caps it < prompt)
    rp = sched.pool.ops.new_request()
    sched.trie.adopt(rp, nodes)
    got = sched.pool.gather_dense(rp.tables, nodes[-1].snapshot, matched)
    _, cold = engine.prefill_chunked(params, cfg, CTX, toks[None, :matched],
                                     32, 8)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(cold)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sched.pool.release(rp)


# ---------------------------------------------------------------------------
# preemption: spill / restore round-trips bit-exactly
# ---------------------------------------------------------------------------

def _tight_budget(cfg, sched, shapes, chunk):
    """A budget admitting ~2 worst-case requests of the largest shape."""
    per_req = max(sched.pool.ops.worst_case_bytes(S + g) for S, g in shapes)
    base = mm.serving_paged_peak_bytes(
        cfg, page_bytes=0, decode_tokens=4, prefill_tokens=chunk)
    return dataclasses.replace(GPU_64G, hbm_bytes=base + 2.2 * per_req,
                               alpha=1.0)


def test_preemption_spill_restore_bit_exact():
    """Under a 2-request budget a low-priority resident is spilled for
    high-priority arrivals and later restored — its final output matches a
    run that was never preempted, and nothing accepted is lost."""
    cfg, params = _model("mixtral-8x7b")
    shapes = [(16, 12, 0), (16, 4, 1), (16, 4, 1), (16, 4, 1)]
    scfg0 = ServeConfig(max_slots=4, cache_len=32, prefill_chunk=8,
                        page_size=8, preemption=True)
    probe = PagedScheduler(params, cfg, CTX, scfg0, key=jax.random.PRNGKey(1))
    hw = _tight_budget(cfg, probe, [(16, 12), (16, 4)], 8)
    scfg = dataclasses.replace(scfg0, hw=hw)
    paged = PagedScheduler(params, cfg, CTX, scfg, key=jax.random.PRNGKey(1))
    m = paged.run(_trace(cfg, shapes))
    assert m["preemptions"] >= 1 and m["shed"] == 0
    assert m["requests"] == len(shapes)
    assert m["modeled_peak_bytes"] <= m["budget_bytes"]
    mono = ContinuousBatchingScheduler(
        params, cfg, CTX, ServeConfig(max_slots=4, cache_len=32,
                                      prefill_chunk=8),
        key=jax.random.PRNGKey(1))
    mono.run(_trace(cfg, shapes))
    _assert_parity(mono, paged)
    low = next(r for r in paged.finished if r.rid == 0)
    assert low.preemptions >= 1


def test_pool_spill_restore_roundtrip():
    """Pool-level: spill -> restore returns fresh private pages whose dense
    gather is bit-identical to the pre-spill cache."""
    cfg, params = _model("jamba-1.5-large-398b")
    toks = jnp.arange(16, dtype=jnp.int32)[None] % cfg.vocab_size
    _, cache = engine.prefill_chunked(params, cfg, CTX, toks, 32, 8)
    pool = PagedCachePool(params, cfg, CTX, 2, 32, 8)
    rp = pool.ops.new_request()
    pool.install(rp, cache, 16)
    before = pool.gather_dense(rp.tables, pool.state_snapshot(cache), 16)
    saved = pool.spill(rp)
    for key in pool.alloc.spaces:         # spill dropped every reference
        assert pool.alloc.allocated(key) == 0
    rp2 = pool.restore(saved)
    after = pool.gather_dense(rp2.tables, pool.state_snapshot(cache), 16)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pool.release(rp2)
    pool.alloc.audit()


# ---------------------------------------------------------------------------
# enc-dec: cross-attention state blocks (scheduler rejects encoder archs,
# so parity is pinned at the pool level)
# ---------------------------------------------------------------------------

def test_paged_decode_enc_dec_cross_attention():
    cfg, params = _model("whisper-small")
    batch = {"tokens": jnp.arange(16, dtype=jnp.int32)[None] % cfg.vocab_size,
             "frames": jax.random.normal(jax.random.PRNGKey(2),
                                         (1, cfg.encoder_seq, cfg.d_model))}
    _, cache = engine.prefill(params, cfg, CTX, batch, 24)
    enc_out = jnp.zeros((1, cfg.encoder_seq, cfg.d_model))
    pool = PagedCachePool(params, cfg, CTX, 2, 24, 8, enc_out=enc_out)
    rp = pool.ops.new_request()
    pool.install(rp, cache, 16)
    # reference = the monolithic slot map: vmapped decode_step over a
    # 2-slot pool (slot 1 empty), exactly what the scheduler compiles
    empty = transformer.init_cache(params, cfg, 1, 24, jnp.float32,
                                   enc_out=enc_out)
    refc = jax.tree.map(lambda a, b: jnp.concatenate([a[None], b[None]]),
                        cache, empty)
    step_fn = jax.jit(jax.vmap(
        lambda p, c, t: transformer.decode_step(p, cfg, CTX, c, t),
        in_axes=(None, 0, 0)))
    tok = 7
    for step in range(3):
        toks = np.asarray([[[tok]], [[0]]], np.int32)
        ref_logits, refc = step_fn(params, refc, jnp.asarray(toks))
        pool.prepare_decode_write(rp, 16 + step)
        got = pool.decode_wave(params, [rp, None],
                               np.asarray([16 + step, 0], np.int32), toks)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(ref_logits)[0])
        tok = int(np.argmax(np.asarray(ref_logits)[0, 0, -1]))
    pool.release(rp)
    pool.alloc.audit()


# ---------------------------------------------------------------------------
# gather/scatter paged-token primitives (blocks.py)
# ---------------------------------------------------------------------------

def test_gather_scatter_paged_tokens_roundtrip():
    page, nb, KH, hd, Sc = 4, 3, 2, 5, 10          # trailing partial page
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(8, page, KH, hd)).astype(np.float32))
    pool = pool.at[ZERO_PAGE].set(0.0)
    table = jnp.asarray([5, 3, 7], jnp.int32)
    dense = blocks.gather_paged_tokens(pool, table, 0, Sc)
    assert dense.shape == (Sc, KH, hd)
    want = np.concatenate([np.asarray(pool)[5], np.asarray(pool)[3],
                           np.asarray(pool)[7]])[:Sc]
    np.testing.assert_array_equal(np.asarray(dense), want)
    # a never-filled block gathers the zero page: dense shows zeros
    holey = blocks.gather_paged_tokens(
        pool, jnp.asarray([5, ZERO_PAGE, 7], jnp.int32), 0, Sc)
    np.testing.assert_array_equal(np.asarray(holey)[page:2 * page], 0.0)
    # scatter writes each block's rows to its page; the padded tail of the
    # last page and scratch-targeted blocks never corrupt live pages
    newd = jnp.asarray(rng.normal(size=(Sc, KH, hd)).astype(np.float32))
    out = blocks.scatter_paged_tokens(
        pool, jnp.asarray([5, SCRATCH_PAGE, 7], jnp.int32), newd, 0, page)
    np.testing.assert_array_equal(np.asarray(out)[5], np.asarray(newd)[:page])
    np.testing.assert_array_equal(np.asarray(out)[7][:Sc - 2 * page],
                                  np.asarray(newd)[2 * page:])
    np.testing.assert_array_equal(np.asarray(out)[3], np.asarray(pool)[3])


def test_gather_paged_tokens_batched_tables():
    """The decode wave gathers (n_slots, nb) tables in one shot."""
    page, Sc = 4, 8
    pool = jnp.arange(6 * page, dtype=jnp.float32).reshape(6, page, 1, 1)
    pool = pool.at[ZERO_PAGE].set(0.0)    # invariant: zero page stays zero
    tables = jnp.asarray([[2, 3], [4, ZERO_PAGE]], jnp.int32)
    dense = blocks.gather_paged_tokens(pool, tables, 0, Sc)
    assert dense.shape == (2, Sc, 1, 1)
    np.testing.assert_array_equal(np.asarray(dense)[0, :, 0, 0],
                                  np.arange(8, 16, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(dense)[1, 4:, 0, 0], 0.0)


# ---------------------------------------------------------------------------
# memory model: decode-act regression + paged variant
# ---------------------------------------------------------------------------

def test_serving_peak_decode_act_clamped_to_residents():
    """Regression: the decode-wave activation term is sized by the tokens a
    wave can actually carry — min(decode_tokens, requests) — so one
    resident request costs one token's activations even on a wide slot
    map, not ``max_slots`` tokens' worth."""
    cfg = registry()["mixtral-8x7b"].reduced()
    kw = dict(cache_len=64, prefill_tokens=0)
    one_wide = mm.serving_peak_bytes(cfg, requests=1, decode_tokens=64, **kw)
    one_narrow = mm.serving_peak_bytes(cfg, requests=1, decode_tokens=1, **kw)
    assert one_wide == one_narrow
    # with enough residents the wave width matters again
    assert (mm.serving_peak_bytes(cfg, requests=64, decode_tokens=64, **kw)
            > mm.serving_peak_bytes(cfg, requests=64, decode_tokens=1, **kw))


def test_serving_paged_peak_and_fits():
    cfg = registry()["mixtral-8x7b"].reduced()
    kw = dict(decode_tokens=4, prefill_tokens=16)
    b0 = mm.serving_paged_peak_bytes(cfg, page_bytes=0, **kw)
    b1 = mm.serving_paged_peak_bytes(cfg, page_bytes=1e6, **kw)
    assert b1 == b0 + 1e6                 # pages are charged verbatim
    hw = HardwareProfile("t", hbm_bytes=b0 + 5e5, peak_flops=1, hbm_bw=1,
                         ici_bw=1, alpha=1.0)
    assert mm.serving_paged_fits(cfg, hw, page_bytes=4e5, **kw)
    assert not mm.serving_paged_fits(cfg, hw, page_bytes=6e5, **kw)


def test_paged_model_beats_monolithic_reservation():
    """The headline: short requests on a long cache_len cost pages for what
    they fill, far below the monolithic full-length reservation."""
    cfg, params = _model("llama3.2-3b")
    scfg = ServeConfig(max_slots=4, cache_len=256, prefill_chunk=8,
                       page_size=8)
    sched = PagedScheduler(params, cfg, CTX, scfg, key=jax.random.PRNGKey(1))
    sched.run(_trace(cfg, [(16, 4)] * 4))
    mono_cache = 4 * mm.decode_cache_bytes(cfg, 256, dtype_bytes=2)
    assert sched.pool.alloc.hwm_bytes() < 0.25 * mono_cache


def test_paged_scheduler_requires_page_size():
    cfg, params = _model("llama3.2-3b")
    with pytest.raises(ValueError, match="page_size"):
        PagedScheduler(params, cfg, CTX, ServeConfig())
