"""Fused persistent MoE kernel + measured autotuner validation.

Interpret-mode parity of kernels/fused_moe.py against (a) the three-launch
Pallas path (dispatch_rows -> ragged_expert_ffn -> combine_rows) and (b) the
jnp references, forward AND grads, across dropless/skewed loads and the
ring-of-experts edge cases (empty expert, all-to-one routing).  Exact cases
use integer-valued inputs and power-of-two router weights so parity is
bit-for-bit (np.testing.assert_array_equal); see kernels/ref.py::
fused_moe_ref for the accumulation-order contract that makes this hold.

Also covers the autotuner cache round-trip: record -> lookup -> kernels
honor the winner; a corrupt or missing cache file silently falls back to the
heuristic defaults.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core import moe as M
from repro.kernels import autotune, ref
from repro.kernels.fused_moe import fused_moe
from repro.kernels.ops import (combine_rows, dispatch_rows, moe_ffn,
                               ragged_expert_ffn)
from repro.kernels.tiling import resolve_tiles


# ---------------------------------------------------------------------------
# case builders
# ---------------------------------------------------------------------------

def _plan(topk, E, bm):
    T, K = np.asarray(topk).shape
    R = -(-(T * K + E * bm) // bm) * bm
    return dsp.make_ragged_plan(jnp.asarray(topk, jnp.int32), E, R, bm), R


def _exact_case(T=24, K=2, E=4, d=16, f=16, bm=8, seed=0, topk=None):
    """Integer-valued inputs + power-of-two router weights: every product
    and sum is exactly representable, so any correct evaluation order gives
    bitwise-identical results."""
    rng = np.random.default_rng(seed)
    if topk is None:
        topk = np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
    plan, R = _plan(topk, E, bm)
    x = jnp.asarray(rng.integers(-8, 8, (T, d)), jnp.float32)
    w1 = jnp.asarray(rng.integers(-2, 2, (E, d, f)), jnp.float32)
    w3 = jnp.asarray(rng.integers(-2, 2, (E, d, f)), jnp.float32)
    w2 = jnp.asarray(rng.integers(-2, 2, (E, f, d)), jnp.float32)
    wtk = jnp.asarray(2.0 ** rng.integers(-2, 2, (T, K)), jnp.float32)
    return plan, R, x, w1, w3, w2, wtk


def _row_maps(plan, weights, K, R):
    """Invert the (T, K) slot map into the fused kernel's row-side view."""
    pos = dsp.invert_slots(plan.slots, R)
    src = jnp.where(pos >= 0, pos // K, -1)
    wslot = None
    if weights is not None:
        wslot = jnp.where(pos >= 0,
                          jnp.take(weights.reshape(-1), jnp.maximum(pos, 0)),
                          0.0)
    return src, wslot


def _three_launch(x, w1, w3, w2, plan, wtk, R, bm):
    buf = dispatch_rows(x, plan.slots, R, plan.total_rows,
                        use_pallas=True, interpret=True, block_m=bm)
    y = ragged_expert_ffn(buf, w1, w3, w2, plan.block_to_expert,
                          plan.total_rows, block_m=bm,
                          use_pallas=True, interpret=True)
    return combine_rows(y, plan.slots, wtk, plan.total_rows,
                        use_pallas=True, interpret=True)


# ---------------------------------------------------------------------------
# forward parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [16, 17])   # 17: padded-contraction path
def test_fused_kernel_bitwise_vs_ref(d):
    plan, R, x, w1, w3, w2, wtk = _exact_case(d=d, seed=1)
    src, wslot = _row_maps(plan, wtk, wtk.shape[1], R)
    got = fused_moe(x, w1, w3, w2, src, wslot, plan.total_rows,
                    plan.block_to_expert, interpret=True)
    want = ref.fused_moe_ref(x, w1, w3, w2, src, plan.slots,
                             plan.block_to_expert, plan.total_rows, wtk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed,skew", [(2, False), (3, True)])
def test_moe_ffn_forward_vs_three_launch_and_jnp(seed, skew):
    """Fused single-launch forward == three-launch Pallas == jnp reference,
    bitwise, on both balanced (dropless) and skewed routing."""
    T, K, E, bm = 24, 2, 4, 8
    topk = None
    if skew:        # 3/4 of tokens hammer expert 0 (second slot varies)
        rng = np.random.default_rng(seed)
        topk = np.stack([(0 if t % 4 else rng.integers(1, E),
                          rng.integers(1, E)) for t in range(T)])
    plan, R, x, w1, w3, w2, wtk = _exact_case(T=T, K=K, E=E, bm=bm,
                                              seed=seed, topk=topk)
    fused = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                    plan.total_rows, wtk, block_m=bm,
                    use_pallas=True, interpret=True)
    three = _three_launch(x, w1, w3, w2, plan, wtk, R, bm)
    ref_np = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                     plan.total_rows, wtk, block_m=bm, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(three))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref_np))


def test_moe_ffn_float_allclose_vs_jnp():
    """Non-exact (gaussian) inputs: fused vs jnp agree to fp32 tolerance."""
    rng = np.random.default_rng(7)
    T, K, E, d, f, bm = 37, 2, 4, 16, 24, 8
    topk = np.stack([rng.choice(E, K, replace=False) for _ in range(T)])
    plan, R = _plan(topk, E, bm)
    x = jnp.asarray(rng.standard_normal((T, d)) * 0.5, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((E, f, d)) * 0.1, jnp.float32)
    wtk = jnp.asarray(rng.random((T, K)), jnp.float32)
    fused = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                    plan.total_rows, wtk, block_m=bm,
                    use_pallas=True, interpret=True)
    want = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                   plan.total_rows, wtk, block_m=bm, use_pallas=False)
    np.testing.assert_allclose(fused, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grad parity
# ---------------------------------------------------------------------------

def _grads(fn, x, w1, w3, w2, wtk, gy):
    def loss(x, w1, w3, w2, wtk):
        return jnp.sum(fn(x, w1, w3, w2, wtk) * gy)
    return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(x, w1, w3, w2, wtk)


def test_moe_ffn_grads_bitwise_vs_three_launch():
    """All five grads (x, w1, w3, w2, router weights) of the fused VJP match
    the three-launch Pallas path bit-for-bit under exact arithmetic."""
    T, K, E, bm = 24, 2, 4, 8
    plan, R, x, w1, w3, w2, wtk = _exact_case(T=T, K=K, E=E, bm=bm, seed=4)
    gy = jnp.asarray(np.random.default_rng(5).integers(-2, 2, x.shape),
                     jnp.float32)

    fused = lambda x, w1, w3, w2, wtk: moe_ffn(
        x, w1, w3, w2, plan.slots, plan.block_to_expert, plan.total_rows,
        wtk, block_m=bm, use_pallas=True, interpret=True)
    three = lambda x, w1, w3, w2, wtk: _three_launch(
        x, w1, w3, w2, plan, wtk, R, bm)

    gf = _grads(fused, x, w1, w3, w2, wtk, gy)
    gt = _grads(three, x, w1, w3, w2, wtk, gy)
    for name, a, b in zip("x w1 w3 w2 wtk".split(), gf, gt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"grad {name}")


def test_moe_ffn_grads_unweighted_vs_jnp():
    """EP-leg shape (weights applied outside): fused VJP grads match the
    autodiff of the jnp reference path.  Not bitwise — jnp's backward
    evaluates the silu-derivative chain with different HLO than the
    chunk-recompute VJP — so this pins a tight relative tolerance; the
    bitwise contract vs the three-launch VJP is the test above."""
    T, K, E, bm = 24, 2, 4, 8
    plan, R, x, w1, w3, w2, _ = _exact_case(T=T, K=K, E=E, bm=bm, seed=6)
    gy = jnp.asarray(np.random.default_rng(8).integers(-2, 2, x.shape),
                     jnp.float32)

    def run(use_pallas):
        def loss(x, w1, w3, w2):
            out = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                          plan.total_rows, None, block_m=bm,
                          use_pallas=use_pallas, interpret=use_pallas)
            return jnp.sum(out * gy)
        return jax.grad(loss, argnums=(0, 1, 2, 3))(x, w1, w3, w2)

    for name, a, b in zip("x w1 w3 w2".split(), run(True), run(False)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"grad {name}")


# ---------------------------------------------------------------------------
# routing edge cases
# ---------------------------------------------------------------------------

def test_all_to_one_routing():
    """Every token routed to expert 0 (K=1): experts 1..E-1 fully empty,
    expert 0 carries the whole load.  Forward bitwise; empty experts get
    exactly-zero weight grads."""
    T, E, bm = 16, 4, 8
    topk = np.zeros((T, 1), np.int32)
    plan, R, x, w1, w3, w2, wtk = _exact_case(T=T, K=1, E=E, bm=bm, seed=9,
                                              topk=topk)
    fused = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                    plan.total_rows, wtk, block_m=bm,
                    use_pallas=True, interpret=True)
    want = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                   plan.total_rows, wtk, block_m=bm, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))

    gy = jnp.ones_like(x)
    fn = lambda x, w1, w3, w2, wtk: moe_ffn(
        x, w1, w3, w2, plan.slots, plan.block_to_expert, plan.total_rows,
        wtk, block_m=bm, use_pallas=True, interpret=True)
    _, dw1, _, dw2, _ = _grads(fn, x, w1, w3, w2, wtk, gy)
    np.testing.assert_array_equal(np.asarray(dw1[1:]),
                                  np.zeros_like(np.asarray(dw1[1:])))
    np.testing.assert_array_equal(np.asarray(dw2[1:]),
                                  np.zeros_like(np.asarray(dw2[1:])))


def test_empty_expert():
    """Routing avoids expert 2 entirely: its row range is dead, the fused
    kernel predicates those blocks off, and parity still holds."""
    rng = np.random.default_rng(11)
    T, K, E, bm = 24, 2, 4, 8
    live = np.asarray([0, 1, 3])
    topk = np.stack([rng.choice(live, K, replace=False) for _ in range(T)])
    plan, R, x, w1, w3, w2, wtk = _exact_case(T=T, K=K, E=E, bm=bm, seed=12,
                                              topk=topk)
    fused = moe_ffn(x, w1, w3, w2, plan.slots, plan.block_to_expert,
                    plan.total_rows, wtk, block_m=bm,
                    use_pallas=True, interpret=True)
    three = _three_launch(x, w1, w3, w2, plan, wtk, R, bm)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(three))


# ---------------------------------------------------------------------------
# MoE layer integration: ctx.moe_fused over the EP strategy
# ---------------------------------------------------------------------------

def test_moe_layer_fused_matches_ragged():
    """DistContext(moe_fused=True) over ep_shardmap reproduces the ragged
    three-launch layer output (same routing, same stats)."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)
    params = M.init_moe(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    y_rg, s_rg = M.moe_ffn(params, x, cfg, M.DistContext(
        mesh=mesh, moe_strategy="ep_shardmap", moe_chunks=2,
        moe_ragged=True))
    y_fu, s_fu = M.moe_ffn(params, x, cfg, M.DistContext(
        mesh=mesh, moe_strategy="ep_shardmap", moe_chunks=2,
        moe_fused=True))
    np.testing.assert_allclose(y_fu, y_rg, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(s_fu["load"]),
                                  np.asarray(s_rg["load"]))


# ---------------------------------------------------------------------------
# autotuner cache
# ---------------------------------------------------------------------------

@pytest.fixture
def cache_file(tmp_path):
    path = str(tmp_path / "autotune.json")
    autotune.set_cache_path(path)
    yield path
    autotune.set_cache_path(None)


def test_cache_round_trip(cache_file):
    shape, dtype = (24, 16, 16, 4, 8), jnp.float32
    assert autotune.lookup("fused_moe", shape, dtype) is None
    autotune.record("fused_moe", shape, dtype, {"bk": 64}, time_ms=1.0)
    assert autotune.lookup("fused_moe", shape, dtype) == {"bk": 64}
    # a fresh load from disk (not the in-process view) sees the entry too
    autotune.set_cache_path(cache_file)
    assert autotune.lookup("fused_moe", shape, dtype) == {"bk": 64}
    # resolve_tiles prefers the cached winner over defaults,
    # and the explicit call-site value over both
    assert resolve_tiles("fused_moe", shape, dtype,
                         {"bk": 512}) == {"bk": 64}
    assert resolve_tiles("fused_moe", shape, dtype, {"bk": 512},
                         {"bk": 32}) == {"bk": 32}


def test_corrupt_cache_falls_back(cache_file):
    with open(cache_file, "w") as f:
        f.write("{not json !!")
    assert autotune.load_cache(cache_file) == {}
    assert autotune.lookup("fused_moe", (1, 2), jnp.float32) is None
    assert resolve_tiles("fused_moe", (1, 2), jnp.float32,
                         {"bk": 512}) == {"bk": 512}
    # recording over a corrupt file heals it
    autotune.record("op", (1, 2), jnp.float32, {"bk": 8})
    with open(cache_file) as f:
        assert "op|1x2" in json.dumps(json.load(f))


def test_missing_cache_is_empty(tmp_path):
    autotune.set_cache_path(str(tmp_path / "nope" / "autotune.json"))
    try:
        assert autotune.lookup("x", (1,), jnp.float32) is None
        assert resolve_tiles("x", (1,), jnp.float32, {"bm": 8}) == {"bm": 8}
    finally:
        autotune.set_cache_path(None)


def test_kernel_honors_cached_tiles(cache_file):
    """A recorded winner changes the tile the fused kernel traces with —
    and the result is still exact (padding keeps any block legal)."""
    plan, R, x, w1, w3, w2, wtk = _exact_case(d=16, seed=13)
    src, wslot = _row_maps(plan, wtk, wtk.shape[1], R)
    T, d = x.shape
    E, _, f = w1.shape
    bm = R // plan.block_to_expert.shape[0]
    autotune.record("fused_moe", (T, d, f, E, bm), x.dtype, {"bk": 8})
    got = fused_moe(x, w1, w3, w2, src, wslot, plan.total_rows,
                    plan.block_to_expert, interpret=True)
    want = ref.fused_moe_ref(x, w1, w3, w2, src, plan.slots,
                             plan.block_to_expert, plan.total_rows, wtk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_autotune_search_never_loses_to_baseline(cache_file):
    """The measured search prepends the heuristic baseline, so the winner's
    median is <= every candidate's (including the baseline's); failing
    candidates are skipped, not fatal."""
    a = jnp.ones((64, 64))

    def make_fn(bk):
        if bk == 13:                     # poisoned candidate: must be skipped
            raise ValueError("does not compile")
        def run():
            jnp.dot(a, a).block_until_ready()
        return run

    res = autotune.autotune("toy", (64,), jnp.float32, make_fn,
                            [{"bk": 13}, {"bk": 32}, {"bk": 64}],
                            baseline={"bk": 128}, blocks=2, repeats=2)
    assert res.baseline_ms is not None
    assert res.winner_ms <= res.baseline_ms
    assert {"bk": 13} in res.skipped
    # winner persisted for resolve_tiles
    assert autotune.lookup("toy", (64,), jnp.float32) == res.winner
