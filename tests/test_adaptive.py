"""Adaptive per-layer MACT: telemetry, hysteresis, recompile bounds, and
static-path parity (docs/DESIGN.md §Adaptive)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (AttentionSpec, HardwareProfile, LayerSpec,
                                ModelConfig, MoEConfig)
from repro.core.chunking import ScheduleSpec
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.core.telemetry import LoadTelemetry
from repro.models import transformer
from repro.training.trainer import Trainer


def _cfg4() -> ModelConfig:
    """4 MoE layers, one per period — exercises the scanned region."""
    return ModelConfig(
        name="adaptive-t4", family="moe", source="tests",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256,
        pattern=(LayerSpec(mixer="attn", ffn="moe", attn=AttentionSpec()),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96),
        dtype="float32")


def _mact(bins=(1, 2, 4, 8)) -> MACTController:
    # static_override=0 and a small HBM make s'_max a round, controllable
    # number so tests can park loads right at bin boundaries
    hw = HardwareProfile("test", hbm_bytes=1e8, peak_flops=1, hbm_bw=1,
                        ici_bw=1, alpha=0.9)
    return MACTController(get_config("deepseek-mini-8l").reduced(),
                          Parallelism(e=1, b=1), hw, seq_len=128, bins=bins,
                          static_override=0.0)


def _loads_for(mact: MACTController, s_pp: float, layers: int = 1):
    """(layers, E) load matrix whose observed s'' is exactly s_pp (e=1)."""
    E = mact.cfg.moe.num_experts
    return np.full((layers, E), s_pp / E)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_ema_math_and_shape_guard():
    t = LoadTelemetry(num_layers=2, num_experts=3, decay=0.5)
    assert t.loads is None
    a = np.arange(6, dtype=np.float64).reshape(2, 3)
    assert np.allclose(t.update(a), a)            # first obs initialises
    b = np.ones((2, 3))
    assert np.allclose(t.update(b), 0.5 * a + 0.5 * b)
    assert t.steps == 2
    with pytest.raises(ValueError):
        t.update(np.ones((3, 3)))
    t.reset()
    assert t.loads is None and t.steps == 0


def test_forward_emits_per_layer_loads_summing_to_global():
    cfg = _cfg4()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    _, stats = transformer.forward(params, cfg, DistContext(moe_chunks=2),
                                   batch)
    lpl = stats["load_per_layer"]
    assert lpl.shape == (4, cfg.moe.num_experts)
    assert np.allclose(np.asarray(lpl).sum(0), np.asarray(stats["load"]))
    # every layer actually routed every token-slot
    T = 2 * 32 * cfg.moe.top_k
    assert np.allclose(np.asarray(lpl).sum(1), T)


# ---------------------------------------------------------------------------
# static-path parity
# ---------------------------------------------------------------------------

def test_uniform_vector_reproduces_static_path_bitwise():
    cfg = _cfg4()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    y_static, s_static = transformer.forward(
        params, cfg, DistContext(moe_chunks=2), batch)
    uni = tuple(ScheduleSpec(2, 1) for _ in range(4))
    y_vec, s_vec = transformer.forward(
        params, cfg, DistContext(layer_schedules=uni), batch)
    assert (np.asarray(y_static) == np.asarray(y_vec)).all()
    assert (np.asarray(s_static["load_per_layer"])
            == np.asarray(s_vec["load_per_layer"])).all()


def test_heterogeneous_vector_unrolls_and_matches_loads():
    cfg = _cfg4()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    y_static, s_static = transformer.forward(
        params, cfg, DistContext(moe_chunks=1), batch)
    het = (ScheduleSpec(1, 1), ScheduleSpec(2, 1), ScheduleSpec(4, 1),
           ScheduleSpec(8, 1))
    y_het, s_het = transformer.forward(
        params, cfg, DistContext(layer_schedules=het), batch)
    # chunking is numerically (not bitwise) invariant; routing is identical
    assert np.abs(np.asarray(y_static) - np.asarray(y_het)).max() < 1e-4
    assert np.allclose(np.asarray(s_static["load_per_layer"]),
                       np.asarray(s_het["load_per_layer"]))


# ---------------------------------------------------------------------------
# controller: per-layer choice + hysteresis
# ---------------------------------------------------------------------------

def test_cold_start_plans_worst_case_uniformly():
    mact = _mact()
    vec = mact.choose_layer_schedules(None, 3, max_depth=2)
    assert len(vec) == 3 and len(set(vec)) == 1
    assert tuple(vec[0]) == mact.choose_schedule(max_depth=2)


def test_per_layer_choice_tracks_per_layer_load():
    mact = _mact()
    s_max = mact.s_prime_max()
    loads = np.concatenate([_loads_for(mact, 0.5 * s_max),
                            _loads_for(mact, 3.5 * s_max)])
    vec = mact.choose_layer_schedules(loads, 2, max_depth=1)
    assert vec[0].chunks == 1 and vec[1].chunks == 4
    assert len(set(vec)) == 2


def test_hysteresis_prevents_flapping_under_noisy_load():
    mact = _mact()
    s_max = mact.s_prime_max()
    # load oscillating +-4% around the c=2 -> c=3 boundary (2 * s'_max):
    # the candidate bin flips 2 <-> 4 every step without hysteresis
    noisy = [2.0 * s_max * (1 + eps)
             for eps in (0.04, -0.04, 0.04, -0.04, 0.04, -0.04)]

    def run(h):
        cur, changes = None, 0
        for s_pp in noisy:
            vec = mact.choose_layer_schedules(
                _loads_for(mact, s_pp), 1, max_depth=1, current=cur,
                hysteresis=h)
            if cur is not None and vec != cur:
                changes += 1
            cur = vec
        return changes, cur

    flaps, _ = run(0.0)
    assert flaps >= 3                      # no hysteresis: flips every step
    stable, cur = run(0.1)
    assert stable <= 1                     # one safety up-switch, then holds
    assert cur[0].chunks == 4              # held at the memory-safe bin


def test_safety_switch_overrides_hysteresis():
    mact = _mact()
    s_max = mact.s_prime_max()
    cur = (ScheduleSpec(2, 1),)
    vec = mact.choose_layer_schedules(
        _loads_for(mact, 6.0 * s_max), 1, max_depth=1, current=cur,
        hysteresis=10.0)                   # absurd band: safety still wins
    assert vec[0].chunks == 8


def test_schedule_emissions_within_bucketed_space():
    mact = _mact()
    space = set(mact.schedule_space(max_depth=2))
    s_max = mact.s_prime_max()
    rng = np.random.default_rng(0)
    for _ in range(20):
        s_pp = float(rng.uniform(0.1, 12.0)) * s_max
        vec = mact.choose_layer_schedules(_loads_for(mact, s_pp), 1,
                                          max_depth=2)
        assert set(vec) <= space
    # the space itself is small: len(bins) sequential + the depth-2 subset
    assert len(space) == 4 + 3


# ---------------------------------------------------------------------------
# trainer: bounded compiled-step cache + adaptive loop
# ---------------------------------------------------------------------------

def test_compiled_step_cache_is_lru_bounded():
    cfg = _cfg4()
    tr = Trainer(cfg, DistContext(), seq_len=32, global_batch=2, lr=1e-3,
                 max_compiled_steps=2)
    keys = [(1, 1), (2, 1), (4, 1)]
    for k in keys:
        tr._compiled(k)
    assert tr.compile_count == 3
    assert len(tr._steps) == 2             # LRU evicted the oldest
    assert (1, 1) not in tr._steps
    tr._compiled((2, 1))                   # hit: no recompile
    assert tr.compile_count == 3
    assert tr.evicted_recompile_count == 0
    with pytest.warns(UserWarning, match="previously-evicted"):
        tr._compiled((1, 1))               # evicted: recompile, warned
    assert tr.compile_count == 4
    assert tr.evicted_recompile_count == 1


def test_user_layer_schedules_honored_without_mact():
    cfg = _cfg4()
    vec = (ScheduleSpec(1, 1), ScheduleSpec(2, 1), ScheduleSpec(4, 1),
           ScheduleSpec(2, 1))
    tr = Trainer(cfg, DistContext(layer_schedules=vec), seq_len=32,
                 global_batch=2, lr=1e-3, use_mact=False)
    tr.fit(2)
    assert vec in tr._steps                # the hand-picked vector ran
    assert tr.chunk_trace == [4, 4]        # memory-binding layer reported


def test_adaptive_fit_records_schedules_and_bounds_compiles():
    cfg = _cfg4()
    tr = Trainer(cfg, DistContext(), seq_len=32, global_batch=2, lr=1e-3,
                 use_mact=True, adaptive_mact=True, replan_interval=2,
                 mact_ep_view=cfg.moe.num_experts)
    tr.fit(5)
    assert len(tr.schedule_trace) == 5
    assert all(len(v) == 4 for v in tr.schedule_trace)
    space = set(tr.mact.schedule_space(max_depth=1))
    assert all(set(v) <= space for v in tr.schedule_trace)
    # uniform vectors collapse to the global cache key -> static-path reuse
    assert all(not isinstance(k[0], tuple) or len(set(k)) > 1
               for k in tr._steps)
    assert tr.compile_count <= tr.max_compiled_steps
    # replan_interval=2 over 5 steps -> 3 plans (cold start + 2 re-plans)
    plans = [h for h in tr.mact.history if "layer_schedules" in h]
    assert len(plans) == 3
    assert tr.telemetry.steps == 5


def test_adaptive_uniform_telemetry_matches_static_trainer_losses():
    cfg = _cfg4()
    kw = dict(seq_len=32, global_batch=2, lr=1e-3,
              mact_ep_view=cfg.moe.num_experts)
    tr_s = Trainer(cfg, DistContext(), use_mact=True, **kw)
    tr_a = Trainer(cfg, DistContext(), use_mact=True, adaptive_mact=True,
                   **kw)
    tr_s.fit(3)
    tr_a.fit(3)
    # same data, same cold start; per-layer telemetry is (near-)uniform so
    # the adaptive trainer runs the very same compiled steps -> same losses
    assert [r["loss"] for r in tr_s.log] == [r["loss"] for r in tr_a.log]
    assert tr_s.chunk_trace == tr_a.chunk_trace
