"""Expert-balanced decode waves + expert-weight residency tier
(docs/DESIGN.md §Residency): memory-model split, per-request telemetry,
loads-reporting steps, masked subset waves, wave formation with the
starvation guard, host-offload/restore round-trips, and end-to-end
bitwise parity of every expert-aware mode against the default scheduler —
monolithic and paged."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import GPU_64G
from repro.core import memory_model as mm
from repro.core.moe import DistContext
from repro.core.telemetry import ExpertTelemetry
from repro.models import transformer
from repro.serving import engine, residency
from repro.serving.paged_scheduler import PagedScheduler
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ServeConfig)

CTX = DistContext()
ARCH = "mixtral-8x7b"


def _setup(seed=0):
    cfg = registry()[ARCH].reduced()
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    return cfg, params


def _trace(n=6, prompt=6, gen=5, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(1, 100, size=prompt).astype(np.int32),
                    max_new_tokens=gen) for i in range(n)]


def _drive(sched, reqs):
    for r in reqs:
        sched.submit(r)
    n = 0
    while sched.queue or sched.active or sched._prefilling is not None:
        sched.step(float(n))
        n += 1
        assert n < 1000, "scheduler failed to drain"
    return {r.rid: list(r.out) for r in sched.finished}


# ---------------------------------------------------------------------------
# memory model: resident-expert weight split
# ---------------------------------------------------------------------------

def test_serve_weight_bytes_resident_split():
    cfg, _ = _setup()
    E = cfg.moe.num_experts
    n_moe = transformer.num_moe_layers(cfg)
    full = mm.serve_weight_bytes(cfg)
    per = mm.expert_weight_bytes(cfg)
    assert per == 3 * cfg.d_model * cfg.moe.d_ff_expert * mm.WEIGHT_ONLY_BYTES
    # all-resident == default; each dropped expert saves exactly `per` per
    # MoE layer; zero residents strip the whole routed expert table
    assert mm.serve_weight_bytes(cfg, resident_experts=E) == full
    for r in range(E + 1):
        got = mm.serve_weight_bytes(cfg, resident_experts=r)
        np.testing.assert_allclose(got, full - (E - r) * per * n_moe)
    # clamped, and dense-stage weights always remain
    assert mm.serve_weight_bytes(cfg, resident_experts=E + 5) == full
    assert mm.serve_weight_bytes(cfg, resident_experts=0) > 0


def test_serving_peak_bytes_resident_defaults_unchanged():
    cfg, _ = _setup()
    kw = dict(requests=3, cache_len=64, decode_tokens=4, prefill_tokens=16)
    base = mm.serving_peak_bytes(cfg, **kw)
    assert mm.serving_peak_bytes(cfg, resident_experts=None, **kw) == base
    E = cfg.moe.num_experts
    assert mm.serving_peak_bytes(cfg, resident_experts=E,
                                 prefetch_experts=0, **kw) == base
    # resident < E shrinks the peak; the prefetch buffer adds back one
    # expert-layer row
    lo = mm.serving_peak_bytes(cfg, resident_experts=2, prefetch_experts=0,
                               **kw)
    assert lo < base
    got = mm.serving_peak_bytes(cfg, resident_experts=2, prefetch_experts=1,
                                **kw)
    np.testing.assert_allclose(got - lo, mm.expert_weight_bytes(cfg))


def test_dense_arch_resident_kwargs_noop():
    cfg = registry()["llama3.2-3b"].reduced()
    kw = dict(requests=2, cache_len=64, decode_tokens=4, prefill_tokens=16)
    assert (mm.serving_peak_bytes(cfg, resident_experts=2, **kw)
            == mm.serving_peak_bytes(cfg, **kw))


# ---------------------------------------------------------------------------
# per-request telemetry
# ---------------------------------------------------------------------------

def test_expert_telemetry_ema_and_support():
    t = ExpertTelemetry(num_layers=2, num_experts=4, decay=0.5)
    assert t.loads(0) is None and t.support(0) is None
    assert t.expert_set(0) == frozenset()
    first = np.array([[4.0, 0, 0, 0], [0, 4.0, 0, 0]])
    np.testing.assert_array_equal(t.update(0, first), first)  # no warmup bias
    t.update(0, np.array([[0, 0, 4.0, 0], [0, 4.0, 0, 0]]))
    np.testing.assert_allclose(t.loads(0),
                               [[2, 0, 2, 0], [0, 4, 0, 0]])
    assert t.expert_set(0) == frozenset({0, 1, 2})
    # decayed-out experts fall below relative support and leave the set
    for _ in range(12):
        t.update(0, np.array([[0, 0, 4.0, 0], [0, 4.0, 0, 0]]))
    assert t.expert_set(0) == frozenset({1, 2})
    t.forget(0)
    assert t.loads(0) is None
    with pytest.raises(ValueError):
        t.update(1, np.zeros((3, 4)))


# ---------------------------------------------------------------------------
# model plumbing: return_load variants
# ---------------------------------------------------------------------------

def test_decode_step_return_load_shapes_and_parity():
    cfg, params = _setup()
    n_moe = transformer.num_moe_layers(cfg)
    E = cfg.moe.num_experts
    cache = transformer.init_cache(params, cfg, 1, 16, jnp.float32)
    toks = jnp.array([[3]], jnp.int32)
    lg0, c0 = transformer.decode_step(params, cfg, CTX, cache, toks)
    lg1, c1, load = transformer.decode_step(params, cfg, CTX, cache, toks,
                                            return_load=True)
    assert load.shape == (n_moe, E)
    assert np.asarray(load).sum() > 0           # top-k tokens routed
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
    for a, b in zip(jax.tree_util.tree_leaves(c0),
                    jax.tree_util.tree_leaves(c1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_chunk_return_load_parity():
    cfg, params = _setup()
    n_moe = transformer.num_moe_layers(cfg)
    E = cfg.moe.num_experts
    seg = jnp.array([[1, 2, 3, 4]], jnp.int32)
    lg0, c0 = engine.prefill_chunk(params, cfg, CTX, None, seg, 16)
    lg1, c1, load = engine.prefill_chunk(params, cfg, CTX, None, seg, 16,
                                         return_load=True)
    assert load.shape == (n_moe, E)
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
    seg2 = jnp.array([[5, 6]], jnp.int32)
    lg2, c2, load2 = engine.prefill_chunk(params, cfg, CTX, c1, seg2, 16,
                                          return_load=True)
    assert load2.shape == (n_moe, E)
    lg3, _ = engine.prefill_chunk(params, cfg, CTX, c0, seg2, 16)
    np.testing.assert_array_equal(np.asarray(lg2), np.asarray(lg3))


def test_masked_decode_full_mask_bitwise_and_nonmember_frozen():
    cfg, params = _setup()
    S = 3
    one = transformer.init_cache(params, cfg, 1, 16, jnp.float32)
    cache = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (S,) + l.shape),
                         one)
    toks = jnp.asarray(np.arange(1, S + 1).reshape(S, 1, 1), jnp.int32)
    base = jax.jit(jax.vmap(
        lambda c, t: transformer.decode_step(params, cfg, CTX, c, t),
        in_axes=(0, 0)))
    lg0, c0 = base(cache, toks)
    masked = engine.get_decode_step_masked(cfg, CTX)
    lg1, c1, load = masked(params, cache, toks,
                           jnp.ones((S,), bool))
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
    for a, b in zip(jax.tree_util.tree_leaves(c0),
                    jax.tree_util.tree_leaves(c1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # partial mask: members bitwise those of the full wave, non-member
    # cache entries and load rows untouched/zero
    lg2, c2, load2 = masked(params, cache, toks,
                            jnp.array([True, False, True]))
    np.testing.assert_array_equal(np.asarray(lg2)[0], np.asarray(lg0)[0])
    np.testing.assert_array_equal(np.asarray(lg2)[2], np.asarray(lg0)[2])
    for a, b in zip(jax.tree_util.tree_leaves(c2),
                    jax.tree_util.tree_leaves(cache)):
        np.testing.assert_array_equal(np.asarray(a)[1], np.asarray(b)[1])
    np.testing.assert_array_equal(np.asarray(load2)[1], 0.0)


def test_router_probe_shapes():
    cfg, params = _setup()
    n_moe = transformer.num_moe_layers(cfg)
    E = cfg.moe.num_experts
    probe = engine.get_router_probe(cfg, CTX)
    counts = np.asarray(probe(params, jnp.arange(1, 6, dtype=jnp.int32)))
    assert counts.shape == (5, n_moe, E)
    np.testing.assert_allclose(counts.sum(-1),
                               np.full((5, n_moe), cfg.moe.top_k))


# ---------------------------------------------------------------------------
# residency manager
# ---------------------------------------------------------------------------

def test_moe_layer_refs_cover_all_moe_layers():
    for arch in (ARCH, "deepseek-mini-16l", "jamba-1.5-large-398b"):
        cfg = registry()[arch].reduced()
        refs = residency.moe_layer_refs(cfg)
        assert len(refs) == transformer.num_moe_layers(cfg), arch
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        for head, i, p in refs:                 # every ref resolves to a
            ffn = params[head][i]["ffn"]        # routed-expert param dict
            assert "w1" in ffn and "router" in ffn, (arch, head, i, p)


def test_offload_restore_roundtrip_bitwise():
    cfg, params = _setup()
    E = cfg.moe.num_experts
    n_moe = transformer.num_moe_layers(cfg)
    flat0 = [np.asarray(l) for l in jax.tree_util.tree_leaves(params)]
    res = residency.ExpertResidency(params, cfg, capacity=2)
    p1 = res.offload_cold(params)
    assert res.offloads == (E - 2) * n_moe
    # the original params object is untouched (functional updates)
    for a, b in zip(flat0, jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # offloaded rows are zero on device
    head, i, p = res.refs[0]
    w1 = np.asarray(p1[head][i]["ffn"]["w1"])
    row = w1[p, E - 1] if p is not None else w1[E - 1]
    np.testing.assert_array_equal(row, 0.0)
    # restore-all round-trips to the construction-time bits exactly
    p2 = res.ensure(p1, [(j, e) for j in range(n_moe) for e in range(E)])
    for a, b in zip(flat0, jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_residency_missing_and_heat_eviction():
    cfg, params = _setup()
    E = cfg.moe.num_experts
    n_moe = transformer.num_moe_layers(cfg)
    res = residency.ExpertResidency(params, cfg, capacity=2)
    p = res.offload_cold(params)
    act = np.zeros((n_moe, E), bool)
    act[0, E - 1] = True
    assert res.missing(act) == [(0, E - 1)]
    p = res.ensure(p, res.missing(act), demand=True)
    assert res.demand_restores == 1 and res.missing(act) == []
    assert res.hwm_experts == 3                  # transiently over capacity
    # heat: expert E-1 hot, expert 0 cold -> eviction drops 0 first
    heat = np.zeros((n_moe, E))
    heat[:, E - 1] = 10.0
    res.note(heat)
    p = res.evict_to_capacity(p)
    assert all(len(s) == 2 for s in res.resident)
    assert (E - 1) in res.resident[0] and 0 not in res.resident[0]


def test_always_resident_never_evicted():
    cfg, params = _setup()
    E = cfg.moe.num_experts
    n_moe = transformer.num_moe_layers(cfg)
    always = [frozenset({E - 1})] * n_moe
    res = residency.ExpertResidency(params, cfg, capacity=2,
                                    always_resident=always)
    p = res.offload_cold(params)
    assert all(E - 1 in s for s in res.resident)
    # even with every other expert hotter, the replicated expert survives
    heat = np.ones((n_moe, E)) * 10.0
    heat[:, E - 1] = 0.0
    res.note(heat)
    pred = np.zeros((n_moe, E), bool)
    pred[:, 0] = True
    p = res.prefetch(p, pred)
    assert all(E - 1 in s for s in res.resident)
    with pytest.raises(ValueError):
        residency.ExpertResidency(params, cfg, capacity=1,
                                  always_resident=[frozenset({0, 1})] * n_moe)


def test_always_resident_sets_from_placements():
    from repro.core.placement import PlacementSpec
    E = 4
    ident = PlacementSpec.identity(E, 1)
    repl = PlacementSpec(num_experts=E, num_peers=1,
                         slot_to_expert=(0, 1, 2, 3, 2))
    sets = residency.always_resident_sets((ident, repl), 2, E)
    assert sets == [frozenset(), frozenset({2})]
    assert residency.always_resident_sets(None, 2, E) == [frozenset()] * 2
    with pytest.raises(ValueError):
        residency.always_resident_sets((ident,), 2, E)


# ---------------------------------------------------------------------------
# scheduler: wave formation + end-to-end parity
# ---------------------------------------------------------------------------

def _base_scfg(**kw):
    return ServeConfig(max_slots=4, cache_len=32, prefill_chunk=8, **kw)


def test_expert_aware_rejects_dense_arch():
    cfg = registry()["llama3.2-3b"].reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="dense"):
        ContinuousBatchingScheduler(params, cfg, CTX,
                                    _base_scfg(expert_batching=True))


def test_grouped_fifo_default_outputs_bitwise_identical():
    """The tentpole invariant: wave composition is a pure scheduling choice
    — greedy-grouped, FIFO-capped, residency-tiered and default full waves
    all emit identical tokens for every request."""
    cfg, params = _setup()
    outs = []
    for kw in ({},
               {"wave_size": 2},
               {"wave_size": 2, "expert_batching": True},
               {"wave_size": 2, "expert_batching": True,
                "resident_experts": 2},
               {"expert_batching": True, "resident_experts": 2,
                "probe_router": True}):
        sched = ContinuousBatchingScheduler(params, cfg, CTX,
                                            _base_scfg(**kw))
        outs.append(_drive(sched, _trace()))
    assert all(o == outs[0] for o in outs[1:])
    assert all(len(v) == 5 for v in outs[0].values())


def test_paged_expert_modes_bitwise_identical():
    cfg, params = _setup()
    outs = []
    scheds = []
    for kw in ({},
               {"wave_size": 2, "expert_batching": True},
               {"wave_size": 2, "expert_batching": True,
                "resident_experts": 2},
               {"expert_batching": True, "resident_experts": 2,
                "prefix_cache": True}):
        sched = PagedScheduler(params, cfg, CTX,
                               _base_scfg(page_size=8, **kw))
        outs.append(_drive(sched, _trace()))
        scheds.append(sched)
    assert all(o == outs[0] for o in outs[1:])
    # paged == monolithic too
    mono = ContinuousBatchingScheduler(params, cfg, CTX, _base_scfg())
    assert _drive(mono, _trace()) == outs[0]
    m = scheds[2].metrics(1.0)
    assert m["requeues"] == 0 and len(scheds[2].shed) == 0
    assert m["residency"]["restores"] >= m["residency"]["demand_restores"]


def test_starvation_guard_forces_inclusion():
    """A resident whose predicted expert set is disjoint from everyone
    else's would lose every greedy tie; the age bound must force it in.
    The greedy seed already takes the longest-waiting resident, so with
    4 residents and wave_size 2 nobody naturally waits more than 2 waves
    — max_wave_wait=1 puts the guard ahead of that natural rotation."""
    cfg, params = _setup()
    E = cfg.moe.num_experts
    scfg = _base_scfg(wave_size=2, expert_batching=True, max_wave_wait=1)
    sched = ContinuousBatchingScheduler(params, cfg, CTX, scfg)
    out = _drive(sched, _trace(n=4, gen=12))
    n_moe = transformer.num_moe_layers(cfg)
    # pin EMAs: slots 0-2 share experts {0,1}, the victim owns {2,3} —
    # then run pure decode waves and watch the guard fire
    sched.reset()
    for r in _trace(n=4, gen=12):
        sched.submit(r)
    while len(sched.active) < 4:
        sched.step(0.0)
    rids = [sched.active[s].rid for s in sorted(sched.active)]
    shared = np.zeros((n_moe, E))
    shared[:, :2] = 5.0
    loner = np.zeros((n_moe, E))
    loner[:, 2:4] = 5.0
    for rid in rids[:3]:
        for _ in range(8):
            sched.telemetry.update(rid, shared)
    for _ in range(8):
        sched.telemetry.update(rids[3], loner)
    victim = [r for r in sched.active.values() if r.rid == rids[3]][0]
    before = len(victim.out)
    sched.forced_includes = 0
    for i in range(2 * (scfg.max_wave_wait + 1)):
        if not sched.active:
            break
        sched.step(float(i + 1))
    assert sched.forced_includes > 0
    assert len(victim.out) > before or victim.state == "finished"
    # and everyone still finishes with the no-guard-needed outputs
    while sched.queue or sched.active or sched._prefilling is not None:
        sched.step(99.0)
    assert {r.rid: list(r.out) for r in sched.finished} == out


def test_wave_metrics_reported():
    cfg, params = _setup()
    sched = ContinuousBatchingScheduler(
        params, cfg, CTX,
        _base_scfg(wave_size=2, expert_batching=True, resident_experts=2))
    _drive(sched, _trace())
    m = sched.metrics(1.0)
    for key in ("expert_waves", "mean_distinct_experts",
                "mean_wave_occupancy", "forced_includes", "prefetch_hits",
                "prefetch_misses", "demand_reruns", "residency"):
        assert key in m, key
    assert m["expert_waves"] > 0
    assert 0 < m["mean_distinct_experts"] <= cfg.moe.num_experts
    assert 0 < m["mean_wave_occupancy"] <= 2
    assert m["residency"]["resident_experts_hwm"] >= 2
    # default scheduler reports zeroed counters, no residency block
    plain = ContinuousBatchingScheduler(params, cfg, CTX, _base_scfg())
    _drive(plain, _trace())
    mp = plain.metrics(1.0)
    assert mp["expert_waves"] == 0 and "residency" not in mp


def test_admission_parity_when_residency_off():
    """expert_batching alone must not change the admission math."""
    cfg, params = _setup()
    a = ContinuousBatchingScheduler(params, cfg, CTX, _base_scfg())
    b = ContinuousBatchingScheduler(
        params, cfg, CTX, _base_scfg(wave_size=2, expert_batching=True))
    for n in (1, 2, 4):
        assert a.modeled_bytes(n) == b.modeled_bytes(n)
        assert a._admissible(n) == b._admissible(n)
    # residency on: strictly cheaper per-request model
    c = ContinuousBatchingScheduler(
        params, cfg, CTX, _base_scfg(resident_experts=2))
    assert c.modeled_bytes(2) < a.modeled_bytes(2)


def test_residency_admits_more_at_equal_budget():
    cfg, params = _setup()
    kw = dict(cache_len=64, decode_tokens=8, prefill_tokens=8,
              dtype_bytes=2)
    lo = mm.serving_peak_bytes(cfg, requests=2, **kw)
    hi = mm.serving_peak_bytes(cfg, requests=3, **kw)
    hw = dataclasses.replace(GPU_64G, hbm_bytes=(lo + hi) / 2, alpha=1.0)
    full = ContinuousBatchingScheduler(
        params, cfg, CTX,
        ServeConfig(max_slots=8, cache_len=64, prefill_chunk=8, hw=hw))
    res = ContinuousBatchingScheduler(
        params, cfg, CTX,
        ServeConfig(max_slots=8, cache_len=64, prefill_chunk=8, hw=hw,
                    resident_experts=2, prefetch_experts=1))
    o_full = _drive(full, _trace(n=8))
    o_res = _drive(res, _trace(n=8))
    assert o_full == o_res                       # outputs bitwise
    assert len(res.finished) == 8                # zero accepted lost
    assert res.max_occupancy > full.max_occupancy
    assert res.modeled_peak <= hw.alpha * hw.hbm_bytes


def test_probe_router_output_invariance():
    """The probe only seeds prefetch predictions; turning it on/off cannot
    change a single emitted token, only the demand-restore traffic."""
    cfg, params = _setup()
    kw = dict(expert_batching=True, resident_experts=2)
    a = ContinuousBatchingScheduler(params, cfg, CTX, _base_scfg(**kw))
    b = ContinuousBatchingScheduler(
        params, cfg, CTX, _base_scfg(probe_router=True, **kw))
    assert _drive(a, _trace()) == _drive(b, _trace())
