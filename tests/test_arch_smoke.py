"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model<=512, <=4 experts) runs one forward and one
train step on CPU, asserting output shapes and finiteness; plus one decode
step for every arch (all 10 have a decoder)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, registry
from repro.core.moe import DistContext
from repro.data.pipeline import SyntheticLMData
from repro.models import transformer
from repro.training.step import init_train_state, make_train_step

ARCHS = sorted(registry())
CTX = DistContext()


def _batch(cfg, B=2, S=32):
    data = SyntheticLMData(cfg, S, B, seed=1)
    return {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, stats = transformer.forward(params, cfg, CTX, batch)
    S = batch["labels"].shape[1]
    assert logits.shape == (2, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(stats["aux_loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_config(arch).reduced()
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, CTX, lr=1e-3))
    batch = _batch(cfg)
    state2, metrics = step(state, batch)
    assert int(state2.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params)[:5],
                        jax.tree.leaves(state2.params)[:5]))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    enc_out = None
    if cfg.encoder_layers:
        frames = jnp.zeros((2, cfg.encoder_seq, cfg.d_model))
        enc_out = transformer.encode(params, cfg, frames, CTX)
    cache = transformer.init_cache(params, cfg, 2, 16, jnp.float32,
                                   enc_out=enc_out)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = transformer.decode_step(params, cfg, CTX, cache, tok)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["pos"]) == 1


def test_loss_decreases_on_tiny_moe():
    """End-to-end learning signal: a few steps reduce CE on synthetic data."""
    from repro.training.trainer import Trainer
    cfg = get_config("mixtral-8x7b").reduced()
    tr = Trainer(cfg, CTX, seq_len=64, global_batch=4, lr=2e-3, use_mact=False)
    tr.fit(10)
    first3 = np.mean([r["ce"] for r in tr.log[:3]])
    last3 = np.mean([r["ce"] for r in tr.log[-3:]])
    assert last3 < first3


def test_assignment_coverage():
    """All 10 assigned architectures (plus the paper's two) are registered,
    across the 6 required family kinds, and the 4 input shapes exist."""
    reg = registry()
    assigned = ["jamba-1.5-large-398b", "starcoder2-3b", "mixtral-8x7b",
                "yi-9b", "whisper-small", "llama4-scout-17b-a16e",
                "internvl2-76b", "llama3.2-3b", "mamba2-130m", "gemma3-27b"]
    for a in assigned:
        assert a in reg, a
    assert {reg[a].family for a in assigned} == {
        "hybrid", "dense", "moe", "audio", "vlm", "ssm"}
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
