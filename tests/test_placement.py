"""Telemetry-driven expert placement + hot-expert replication
(docs/DESIGN.md §Placement): solver invariants, hysteresis, the replica
memory term, EP bit-parity on a mesh, and migration/checkpoint round-trips.

Multi-device tests run in a SUBPROCESS that sets
--xla_force_host_platform_device_count (same rule as test_distributed.py)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import HardwareProfile
from repro.core import memory_model as mm
from repro.core import placement as plc
from repro.core.mact import MACTController
from repro.core.memory_model import Parallelism
from repro.core.moe import DistContext
from repro.core.placement import PlacementSpec
from repro.core.telemetry import LoadTelemetry
from repro.training.trainer import Trainer

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 4, timeout: int = 600) -> str:
    src = (f"import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={devices}'\n"
           + textwrap.dedent(body))
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


# ---------------------------------------------------------------------------
# telemetry: restore guard + imbalance signal
# ---------------------------------------------------------------------------

def test_bad_restore_leaves_live_ema_untouched():
    # regression: load_state_dict used to assign steps/ema before validating,
    # so a bad checkpoint clobbered the warm EMA it then refused to replace
    t = LoadTelemetry(num_layers=2, num_experts=3)
    warm = np.arange(6, dtype=np.float64).reshape(2, 3)
    t.update(warm)
    with pytest.raises(ValueError):
        t.load_state_dict({"steps": 99, "ema": np.ones((4, 4)).tolist()})
    assert t.steps == 1
    assert np.array_equal(t.loads, warm)
    # a valid restore still lands
    t.load_state_dict({"steps": 7, "ema": (warm * 2).tolist()})
    assert t.steps == 7 and np.array_equal(t.loads, warm * 2)


def test_imbalance_peak_over_mean():
    t = LoadTelemetry(num_layers=3, num_experts=4)
    assert t.imbalance() is None
    t.update([[1, 1, 1, 1], [8, 0, 0, 0], [0, 0, 0, 0]])
    imb = t.imbalance()
    assert np.allclose(imb, [1.0, 4.0, 1.0])   # all-zero layer reports 1.0


# ---------------------------------------------------------------------------
# PlacementSpec: shape, validation, derived tables
# ---------------------------------------------------------------------------

def test_identity_spec_properties():
    s = PlacementSpec.identity(8, 4)
    assert s.total_slots == 8 and s.slots_per_peer == 2
    assert s.replica_slots == 0 and s.is_identity
    s.validate()
    assert np.array_equal(s.replica_counts(), np.ones(8))
    with pytest.raises(ValueError):
        PlacementSpec.identity(6, 4)


def test_validate_rejects_malformed_specs():
    with pytest.raises(ValueError):   # slots not divisible by peers
        PlacementSpec(4, 2, (0, 1, 2, 3, 0)).validate()
    with pytest.raises(ValueError):   # duplicate expert on one peer
        PlacementSpec(4, 2, (0, 0, 2, 3)).validate()
    with pytest.raises(ValueError):   # expert 3 unplaced
        PlacementSpec(4, 2, (0, 1, 2, 0)).validate()
    with pytest.raises(ValueError):   # fewer slots than e_local
        PlacementSpec(8, 2, (0, 1)).validate()


def test_peer_loads_identity_matches_reshape_sum():
    s = PlacementSpec.identity(8, 4)
    load = np.arange(8, dtype=np.float64) + 1
    assert np.array_equal(s.peer_loads(load), load.reshape(4, 2).sum(1))
    with pytest.raises(ValueError):
        s.peer_loads(np.ones(5))


HOT = [100, 1, 1, 1, 1, 1, 1, 1]          # one dominant expert, E=8


def test_expert_slot_table_splits_replicas_evenly():
    s = plc.plan_placement(HOT, 4, replicas=1)
    assert s.replica_counts()[0] >= 2          # hot expert got replicated
    table = s.expert_slot_table()
    E, R = table.shape
    for e in range(E):
        slots, counts = np.unique(table[e], return_counts=True)
        assert np.all(np.asarray(s.slot_to_expert)[slots] == e)
        assert counts.max() - counts.min() == 0    # exact round-robin
    # predicted per-peer load splits the hot expert's column
    assert plc.bottleneck(s, HOT) < 100


def test_place_expert_idx_identity_and_even_split():
    import jax.numpy as jnp
    ident = PlacementSpec.identity(4, 2)
    idx = jnp.zeros((16, 2), jnp.int32)
    assert plc.place_expert_idx(idx, None) is idx
    assert plc.place_expert_idx(idx, ident) is idx
    s = plc.plan_placement(HOT, 4, replicas=1)
    slots = np.asarray(plc.place_expert_idx(idx, s))       # all route expert 0
    hosts = [i for i, e in enumerate(s.slot_to_expert) if e == 0]
    counts = np.bincount(slots.reshape(-1), minlength=s.total_slots)
    assert sorted(np.nonzero(counts)[0]) == sorted(hosts)
    assert counts[hosts].max() - counts[hosts].min() <= 1  # even up to T%R
    # same input -> same mapping (pure function of flat position)
    assert np.array_equal(slots, np.asarray(plc.place_expert_idx(idx, s)))


# ---------------------------------------------------------------------------
# solver: LPT, replication, hysteresis
# ---------------------------------------------------------------------------

def test_lpt_beats_identity_when_hot_experts_collide():
    # identity co-locates experts 0 and 1 on peer 0 -> bottleneck 150
    load = [100, 50, 1, 1, 1, 1, 1, 1]
    ident = PlacementSpec.identity(8, 4)
    s = plc.plan_placement(load, 4)
    s.validate()
    assert s.total_slots == 8                  # pure permutation
    assert plc.bottleneck(s, load) < plc.bottleneck(ident, load)
    assert plc.bottleneck(s, load) <= 101 + 1e-9   # LPT optimum here


def test_replication_cuts_below_single_expert_floor():
    # one expert dominates: no permutation helps (floor = 100), only replicas
    load = [100, 1, 1, 1, 1, 1, 1, 1]
    perm = plc.plan_placement(load, 4)
    rep = plc.plan_placement(load, 4, replicas=1)
    rep.validate()
    assert rep.total_slots == 8 + 4
    assert rep.replica_counts()[0] >= 2        # replicas went to the hot expert
    assert plc.bottleneck(perm, load) >= 100
    assert plc.bottleneck(rep, load) < 100
    with pytest.raises(ValueError):
        plc.plan_placement(load, 4, replicas=-1)
    with pytest.raises(ValueError):
        plc.plan_placement(load, 3)            # E % P != 0


def test_hysteresis_keeps_identity_on_balanced_load():
    loads = np.ones((3, 8))
    out = plc.choose_placements(loads, 3, 4)
    assert all(p.is_identity for p in out)


def test_hysteresis_holds_incumbent_within_band():
    ident = PlacementSpec.identity(8, 4)
    skew = np.asarray([[100, 50, 1, 1, 1, 1, 1, 1]])
    # big win: adopted
    adopted = plc.choose_placements(skew, 1, 4, current=(ident,))
    assert not adopted[0].is_identity
    # marginal win (within 10% band): incumbent survives
    mild = np.asarray([[10, 9.8, 10, 9.9, 10, 9.7, 10, 9.9]])
    held = plc.choose_placements(mild, 1, 4, current=(ident,))
    assert held[0] == ident
    # re-planning the adopted layout under the same load is a fixed point
    again = plc.choose_placements(skew, 1, 4, current=adopted)
    assert again == adopted


def test_choose_placements_cold_start_and_shape_guard():
    out = plc.choose_placements(None, 2, 4, num_experts=8)
    assert all(p.is_identity for p in out) and len(out) == 2
    cur = (plc.plan_placement([100, 50, 1, 1, 1, 1, 1, 1], 4),) * 2
    assert plc.choose_placements(None, 2, 4, num_experts=8, current=cur) == cur
    with pytest.raises(ValueError):
        plc.choose_placements(np.ones((3, 8)), 2, 4)
    with pytest.raises(ValueError):
        plc.choose_placements(None, 2, 4)      # num_experts required


def test_migrated_slots_accounting():
    ident = PlacementSpec.identity(8, 4)
    assert plc.migrated_slots(None, ident) == 0        # cold start: no moves
    assert plc.migrated_slots(ident, ident) == 0
    perm = PlacementSpec(8, 4, (1, 0, 2, 3, 4, 5, 6, 7))
    assert plc.migrated_slots(ident, perm) == 2
    rep = plc.plan_placement([100, 1, 1, 1, 1, 1, 1, 1], 4, replicas=1)
    # every fresh replica slot counts as moved (it receives a weight copy)
    assert plc.migrated_slots(rep, rep) == 0
    assert plc.migrated_slots(None, rep) >= rep.num_peers * rep.replica_slots


# ---------------------------------------------------------------------------
# MACT + memory model pricing
# ---------------------------------------------------------------------------

def _mact(**kw) -> MACTController:
    hw = HardwareProfile("test", hbm_bytes=1e8, peak_flops=1, hbm_bw=1,
                        ici_bw=1, alpha=0.9)
    return MACTController(get_config("deepseek-mini-8l").reduced(),
                          Parallelism(e=1, b=1), hw, seq_len=128,
                          bins=(1, 2, 4, 8), static_override=0.0, **kw)


def test_observed_s_pp_through_placement_map():
    mact = _mact()
    load = np.asarray([10.0, 10.0, 0.1, 0.1])
    ident = PlacementSpec.identity(4, 2)
    assert mact.observed_s_pp(load, ep_size=2) == \
        mact.observed_s_pp(load, placement=ident) == 20.0
    balanced = plc.plan_placement(load, 2)     # pairs a hot with a cold expert
    assert mact.observed_s_pp(load, placement=balanced) == pytest.approx(10.1)


def test_replica_weight_bytes_monotone_and_prices_budget():
    cfg = get_config("deepseek-mini-8l").reduced()
    par = Parallelism(e=2, b=1)
    assert mm.replica_weight_bytes(cfg, 0, par) == 0.0
    b1 = mm.replica_weight_bytes(cfg, 1, par)
    b2 = mm.replica_weight_bytes(cfg, 2, par)
    assert 0 < b1 < b2 and b2 == pytest.approx(2 * b1)
    # the replica term comes off the Eq. 8 budget...
    m0, m1 = _mact(), _mact(replica_slots=1)
    assert m1.s_prime_max() < m0.s_prime_max()
    # ...and onto the serving peak
    base = dict(requests=2, cache_len=64, decode_tokens=2)
    assert (mm.serving_peak_bytes(cfg, **base, replica_weight_bytes=1e6)
            == pytest.approx(mm.serving_peak_bytes(cfg, **base) + 1e6))


def test_placed_layer_gets_cheaper_or_equal_schedule():
    mact = _mact()
    E = mact.cfg.moe.num_experts
    # hot pair on one peer under identity; balanced placement splits them
    load = np.zeros((1, E))
    load[0, :2] = mact.s_prime_max() * 0.9
    balanced = plc.plan_placement(load[0], 2)
    plain = mact.choose_layer_schedules(load, 1, ep_size=2)
    placed = mact.choose_layer_schedules(load, 1, ep_size=2,
                                         placements=(balanced,))
    assert placed[0].chunks <= plain[0].chunks
    # identity placement vector must not change the plan at all
    ident = (PlacementSpec.identity(E, 2),)
    assert mact.choose_layer_schedules(load, 1, ep_size=2,
                                       placements=ident) == plain


# ---------------------------------------------------------------------------
# trainer: replan cadence, cache keys, checkpoint round-trip
# ---------------------------------------------------------------------------

def _trainer(**kw) -> Trainer:
    kw.setdefault("mact_ep_view", 2)
    return Trainer(get_config("deepseek-mini-8l").reduced(), DistContext(),
                   seq_len=32, global_batch=2, lr=1e-3,
                   use_placement=True, **kw)


def test_trainer_adopts_placement_and_composite_key():
    tr = _trainer(placement_replicas=1)
    E = tr.cfg.moe.num_experts
    key0 = tr._next_schedule_key()
    assert tr._with_placements(key0) == key0       # cold start: identity, bare
    skew = np.tile([100.0, 50.0, 1.0, 1.0][:E], (tr._n_moe, 1))
    tr.telemetry.update(skew)
    key1 = tr._next_schedule_key()
    full = tr._with_placements(key1)
    assert full != key1 and full[0] == key1
    assert all(isinstance(p, PlacementSpec) for p in full[1])
    assert any(not p.is_identity for p in full[1])
    rec = tr.placement_trace[-1]
    assert rec["migrated_slots"] > 0 and rec["migrated_bytes"] > 0
    assert max(rec["imbalance"]) > 1.0
    # identical compiled step reused for the same composite key
    fn = tr._compiled(full)
    assert tr._compiled(tr._with_placements(tr._next_schedule_key())) is fn


def test_trainer_respects_replan_interval():
    tr = _trainer(replan_interval=2)
    E = tr.cfg.moe.num_experts
    tr._next_schedule_key()                        # cold start plan (age 1)
    tr.telemetry.update(np.tile([100.0, 50.0] + [1.0] * (E - 2),
                                (tr._n_moe, 1)))
    tr._next_schedule_key()                        # age 1 < 2: no replan yet
    assert all(p.is_identity for p in tr._placements)
    assert len(tr.placement_trace) == 1
    tr._next_schedule_key()                        # age 2: replan fires
    assert len(tr.placement_trace) == 2
    assert any(not p.is_identity for p in tr._placements)


def test_trainer_disabled_or_indivisible_is_none():
    tr = _trainer()
    tr.use_placement = False
    assert tr.choose_placements() is None
    tr2 = _trainer(mact_ep_view=3)                 # E=4 not divisible by 3
    assert tr2.choose_placements() is None
    assert tr2._with_placements((1, 1)) == (1, 1)


def test_placement_checkpoint_round_trip():
    tr = _trainer(placement_replicas=1)
    E = tr.cfg.moe.num_experts
    tr.telemetry.update(np.tile([100.0, 50.0] + [1.0] * (E - 2),
                                (tr._n_moe, 1)))
    tr._next_schedule_key()
    assert any(not p.is_identity for p in tr._placements)
    extra = tr._runtime_extra()
    tr2 = _trainer(placement_replicas=1)
    tr2._apply_extra(extra)
    assert tr2._placements == tr._placements
    assert tr2._placement_age == tr._placement_age
    # a resumed replan from the warm state is a no-op (stable fixed point)
    tr2._placement_age = tr2.replan_interval
    tr2._next_schedule_key()
    assert tr2._placements == tr._placements


# ---------------------------------------------------------------------------
# EP numerics on a mesh (subprocess)
# ---------------------------------------------------------------------------

def test_ep_placement_bit_parity_forward_and_grads():
    """Identity and permutation placements are bitwise-identical to the
    unplaced EP path — output, loss, and every grad leaf — because per-row
    expert math is unchanged; only which peer runs it moves.  Replication
    keeps forward/loss/router/x grads bitwise too; expert WEIGHT grads
    accumulate replica partial-sums in a different order, so those three
    leaves are equal only to float-reassociation tolerance."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.core import placement as plc
        from repro.core.placement import PlacementSpec
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64)
        params = M.init_moe(jax.random.PRNGKey(0), 32, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        def run(placement):
            ctx = M.DistContext(mesh=mesh, moe_chunks=2,
                                moe_strategy="ep_shardmap",
                                placement=placement)
            def loss(p, xx):
                y, s = M.moe_ffn(p, xx, cfg, ctx)
                return (y ** 2).sum(), (y, s)
            with set_mesh(mesh):
                (l, (y, s)), g = jax.jit(jax.value_and_grad(
                    loss, argnums=(0, 1), has_aux=True))(params, x)
            return l, y, s, g
        l0, y0, s0, g0 = run(None)
        specs = {
          "identity": PlacementSpec.identity(8, 4),
          "permutation": PlacementSpec(8, 4, (3, 5, 0, 6, 1, 7, 2, 4)),
          "replicated": plc.plan_placement(
              [100, 50, 1, 1, 1, 1, 1, 1], 4, replicas=1),
        }
        flat0 = jax.tree_util.tree_flatten_with_path(g0)[0]
        for name, spec in specs.items():
            l1, y1, s1, g1 = run(spec)
            np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1),
                                          err_msg=name)
            assert float(l0) == float(l1), name
            assert float(s1["drops"]) == 0.0, name
            np.testing.assert_array_equal(np.asarray(s0["load"]),
                                          np.asarray(s1["load"]), err_msg=name)
            replicated = spec.replica_slots > 0
            for (path, a), b in zip(flat0, jax.tree.leaves(g1)):
                leaf = jax.tree_util.keystr(path)
                reassoc = replicated and any(w in leaf
                                             for w in ("w1", "w2", "w3")) \
                    and "router" not in leaf
                if reassoc:   # replica partial-sums re-ordered the reduction
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=1e-6, atol=1e-5,
                                               err_msg=f"{name} {leaf}")
                else:
                    np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                                  err_msg=f"{name} {leaf}")
        print("PLACEMENT-PARITY OK")
    """, devices=4)
    assert "PLACEMENT-PARITY OK" in out


def test_ep_placement_all_to_one_routing_round_trip():
    """Worst-case skew: every token routes to experts {0, 1}, which identity
    co-locates on peer 0.  A planned placement separates and replicates them;
    the result must still be bitwise-identical with zero drops, and repeat
    runs identical (the replica split is deterministic)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.core import placement as plc
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
        params = M.init_moe(jax.random.PRNGKey(0), 16, cfg)
        # force the router: zero weights -> uniform scores -> top-k
        # tie-breaks to experts (0, 1) for EVERY token
        params["router"]["w"] = jnp.zeros((16, 8), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
        T = 2 * 16
        load = np.zeros(8); load[0] = load[1] = T
        spec = plc.plan_placement(load, 4, replicas=1)
        assert not spec.is_identity
        assert plc.bottleneck(spec, load) < plc.bottleneck(
            plc.PlacementSpec.identity(8, 4), load)
        def run(placement):
            ctx = M.DistContext(mesh=mesh, moe_chunks=2,
                                moe_strategy="ep_shardmap",
                                placement=placement)
            with set_mesh(mesh):
                y, s = jax.jit(lambda p, xx: M.moe_ffn(p, xx, cfg, ctx))(params, x)
            return np.asarray(y), s
        y0, s0 = run(None)
        assert np.asarray(s0["load"])[0] == T     # the skew really happened
        y1, s1 = run(spec)
        y2, _ = run(spec)
        np.testing.assert_array_equal(y0, y1)
        np.testing.assert_array_equal(y1, y2)     # deterministic split
        assert float(s1["drops"]) == 0.0
        np.testing.assert_array_equal(np.asarray(s0["load"]),
                                      np.asarray(s1["load"]))
        print("ALL-TO-ONE OK")
    """, devices=4)
    assert "ALL-TO-ONE OK" in out


def test_migration_then_step_equals_cold_start_on_mesh():
    """A trainer that replans mid-run (identity -> placed, i.e. after a
    weight migration) must produce the same compiled step as a fresh trainer
    cold-started directly at the new placement: stepping identical state on
    identical data is bitwise-equal."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from dataclasses import replace
        from repro.compat import set_mesh
        from repro.configs import get_config
        from repro.core.moe import DistContext
        from repro.training.step import init_train_state
        from repro.training.trainer import Trainer
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced()
        cfg = replace(cfg, moe=replace(cfg.moe, num_experts=8))
        ctx = DistContext(mesh=mesh, moe_chunks=2, moe_strategy="ep_shardmap")
        kw = dict(seq_len=32, global_batch=4, lr=1e-3, use_mact=False,
                  use_placement=True, placement_replicas=1)
        skew = None
        def make():
            tr = Trainer(cfg, ctx, **kw)
            return tr, np.tile([100.0, 50.0] + [1.0] * 6, (tr._n_moe, 1))
        # trainer A: one step at identity, then replan + migrate
        trA, skew = make()
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        batch = trA.data.batch_at(0)
        with set_mesh(mesh):
            k0 = trA._with_placements(trA._next_schedule_key())
            s0, m0 = trA._compiled(k0)(state, batch)
            trA.telemetry.update(skew)
            kA = trA._with_placements(trA._next_schedule_key())
            assert kA != k0 and any(not p.is_identity for p in trA._placements)
            sA, mA = trA._compiled(kA)(s0, batch)
        # trainer B: cold start straight at the same placement
        trB, _ = make()
        trB.telemetry.update(skew)
        with set_mesh(mesh):
            kB = trB._with_placements(trB._next_schedule_key())
            assert trB._placements == trA._placements
            sB, mB = trB._compiled(kB)(s0, batch)
        assert float(mA["loss"]) == float(mB["loss"])
        for a, b in zip(jax.tree.leaves(sA), jax.tree.leaves(sB)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("MIGRATE==COLD OK", float(mA["loss"]))
    """, devices=4)
    assert "MIGRATE==COLD OK" in out
