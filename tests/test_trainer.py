"""Trainer-level integration: MACT in the loop, checkpoint/resume, schedules."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpointing import latest_step, restore, save
from repro.configs import get_config
from repro.configs.base import HardwareProfile
from repro.core.moe import DistContext
from repro.training.step import init_train_state
from repro.training.trainer import Trainer

TIGHT = HardwareProfile("tight", hbm_bytes=2e6, peak_flops=1, hbm_bw=1,
                        ici_bw=1, alpha=0.9)


def test_mact_switches_bins_under_pressure():
    cfg = get_config("deepseek-mini-8l").reduced()
    tr = Trainer(cfg, DistContext(), seq_len=128, global_batch=4, lr=1e-3,
                 use_mact=True, hw=TIGHT, static_override=0.0,
                 mact_ep_view=cfg.moe.num_experts)
    tr.fit(6)
    assert len(set(tr.chunk_trace)) >= 1
    assert all(c in (1, 2, 4, 8) for c in tr.chunk_trace)
    # at most len(bins) distinct compiled steps ever exist
    assert len(tr._steps) <= 4


def test_trainer_checkpoints_and_resumes(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    tr = Trainer(cfg, DistContext(), seq_len=32, global_batch=2, lr=1e-3,
                 checkpoint_dir=str(tmp_path), checkpoint_every=2)
    state = tr.fit(4)
    step = latest_step(str(tmp_path))
    assert step in (2, 4)
    like = init_train_state(jax.random.PRNGKey(0), cfg)
    restored = restore(str(tmp_path), step, like)
    assert int(np.asarray(restored.step)) == step
    # resume continues without error and advances
    tr2 = Trainer(cfg, DistContext(), seq_len=32, global_batch=2, lr=1e-3)
    state2 = tr2.fit(2, state=restored)
    assert int(state2.step) == step + 2


def test_fixed_chunks_without_mact():
    cfg = get_config("mixtral-8x7b").reduced()
    ctx = DistContext(moe_chunks=4)
    tr = Trainer(cfg, ctx, seq_len=64, global_batch=2, lr=1e-3, use_mact=False)
    tr.fit(3)
    assert tr.chunk_trace == [4, 4, 4]


def test_loss_free_bias_updates_in_train_loop():
    base = get_config("deepseek-mini-8l").reduced()
    cfg = dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, loss_free_bias=True,
                                      bias_update_rate=0.01))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    from repro.training.step import make_train_step
    from repro.data.pipeline import SyntheticLMData
    import jax.numpy as jnp
    step = jax.jit(make_train_step(cfg, DistContext(), lr=1e-3))
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLMData(cfg, 32, 2).batch_at(0).items()}
    state2, _ = step(state, batch)
    before = [np.asarray(l) for p, l in
              jax.tree_util.tree_flatten_with_path(state.params)[0]
              if "bias" in str(p) and "router" in str(p)]
    after = [np.asarray(l) for p, l in
             jax.tree_util.tree_flatten_with_path(state2.params)[0]
             if "bias" in str(p) and "router" in str(p)]
    assert any((a != b).any() for a, b in zip(before, after))
