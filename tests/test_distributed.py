"""Multi-device tests.  Each test runs in a SUBPROCESS that sets
--xla_force_host_platform_device_count (the main pytest process must keep the
single real device per the dry-run isolation rule)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 600) -> str:
    src = (f"import os\n"
           f"os.environ['XLA_FLAGS'] = "
           f"'--xla_force_host_platform_device_count={devices}'\n"
           + textwrap.dedent(body))
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_ep_shardmap_equals_tp_path():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64)
        params = M.init_moe(jax.random.PRNGKey(0), 32, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        ctx_ep = M.DistContext(mesh=mesh, moe_chunks=2, moe_strategy="ep_shardmap")
        with set_mesh(mesh):
            y_ep, s_ep = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, ctx_ep))(params, x)
            g_ep = jax.jit(jax.grad(lambda p: M.moe_ffn(p, x, cfg, ctx_ep)[0].sum()))(params)
        y_tp, s_tp = M.moe_ffn(params, x, cfg, M.DistContext(moe_chunks=2))
        g_tp = jax.grad(lambda p: M.moe_ffn(p, x, cfg, M.DistContext(moe_chunks=2))[0].sum())(params)
        assert np.abs(np.asarray(y_ep) - np.asarray(y_tp)).max() < 1e-5
        assert float(s_ep["drops"]) == 0.0
        np.testing.assert_array_equal(np.asarray(s_ep["load"]), np.asarray(s_tp["load"]))
        errs = [np.abs(np.asarray(a) - np.asarray(b)).max()
                for a, b in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_tp))]
        assert max(errs) < 1e-4, errs
        print("EP==TP OK")
    """, devices=4)
    assert "EP==TP OK" in out


def test_ep_chunk_invariance_on_mesh():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
        params = M.init_moe(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        with set_mesh(mesh):
            outs = []
            for c in (1, 2, 4):
                ctx = M.DistContext(mesh=mesh, moe_chunks=c, moe_strategy="ep_shardmap")
                y, _ = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, ctx))(params, x)
                outs.append(np.asarray(y))
        assert np.abs(outs[0] - outs[1]).max() < 1e-5
        assert np.abs(outs[0] - outs[2]).max() < 1e-5
        print("CHUNK-INVARIANT OK")
    """, devices=8)
    assert "CHUNK-INVARIANT OK" in out


def test_ep_pipelined_schedule_on_mesh():
    """The wave-pipelined FCDA schedule (pipeline_chunks=2) matches the
    sequential loop bit-for-bit on a real multi-device mesh — values, stats
    and gradients — with remat on and off."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32)
        params = M.init_moe(jax.random.PRNGKey(0), 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        with set_mesh(mesh):
            for remat in (True, False):
                ctx0 = M.DistContext(mesh=mesh, moe_chunks=4, remat_chunks=remat,
                                     moe_strategy="ep_shardmap")
                ctx1 = M.DistContext(mesh=mesh, moe_chunks=4, remat_chunks=remat,
                                     pipeline_chunks=2, moe_strategy="ep_shardmap")
                y0, s0 = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, ctx0))(params, x)
                y1, s1 = jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, ctx1))(params, x)
                assert np.abs(np.asarray(y0) - np.asarray(y1)).max() < 1e-6
                np.testing.assert_array_equal(np.asarray(s0["load"]), np.asarray(s1["load"]))
                assert float(s1["drops"]) == 0.0
                g0 = jax.jit(jax.grad(lambda p: M.moe_ffn(p, x, cfg, ctx0)[0].sum()))(params)
                g1 = jax.jit(jax.grad(lambda p: M.moe_ffn(p, x, cfg, ctx1)[0].sum()))(params)
                errs = [np.abs(np.asarray(a) - np.asarray(b)).max()
                        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1))]
                assert max(errs) < 1e-5, (remat, errs)
        print("PIPELINE-EP OK")
    """, devices=8)
    assert "PIPELINE-EP OK" in out


def test_full_train_step_on_mesh():
    """A whole MoE train step (MoE EP + TP attention + sharded batch) runs
    and produces finite loss on a 2x4 mesh."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from dataclasses import replace
        from repro.configs import get_config
        from repro.launch import dryrun_lib as lib
        from repro.configs.base import InputShape
        from repro.training.step import init_train_state, make_train_step
        from repro.data.pipeline import SyntheticLMData
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = replace(get_config("mixtral-8x7b").reduced(),
                      moe=replace(get_config("mixtral-8x7b").reduced().moe,
                                  num_experts=4))
        shape = InputShape("t", 32, 4, "train")
        cfg, ctx = lib.build_context(cfg, shape, mesh, chunks=2)
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        data = SyntheticLMData(cfg, 32, 4)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        with set_mesh(mesh):
            step = jax.jit(make_train_step(cfg, ctx, lr=1e-3))
            state, m = step(state, batch)
            state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
        print("MESH TRAIN OK", float(m["loss"]))
    """, devices=8)
    assert "MESH TRAIN OK" in out


def test_dryrun_small_mesh_lowers_and_compiles():
    """The dry-run machinery end-to-end on a small mesh for one arch/shape
    per mode (train/prefill/decode)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.launch import dryrun_lib as lib
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((2, 2), ("data", "model"))
        for arch, shape in [("mixtral-8x7b", "train_4k"),
                            ("gemma3-27b", "prefill_32k"),
                            ("mamba2-130m", "decode_32k")]:
            # full configs on 4 devices: lower only (compiling is the sweep's job)
            lowered, meta = lib.lower_combo(arch, shape, mesh)
            txt = lowered.as_text()
            assert "main" in txt
            print("LOWERED", arch, shape)
        print("DRYRUN-SMALL OK")
    """, devices=4, timeout=900)
    assert "DRYRUN-SMALL OK" in out


def test_multipod_mesh_axes():
    out = run_py("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.devices.shape == (16, 16) and m1.axis_names == ("data", "model")
        assert m2.devices.shape == (2, 16, 16) and m2.axis_names == ("pod", "data", "model")
        print("MESH OK")
    """, devices=512)
    assert "MESH OK" in out


def test_ragged_ep_equals_per_expert_ep():
    """The MegaBlocks-style ragged buffers (+ Pallas interpret kernels) give
    identical outputs/grads to the per-expert buffer EP path on a mesh."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import set_mesh
        from repro.core import moe as M
        from repro.configs.base import MoEConfig
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64)
        params = M.init_moe(jax.random.PRNGKey(0), 32, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        ctxs = {
          "ep": M.DistContext(mesh=mesh, moe_chunks=2, moe_strategy="ep_shardmap"),
          "ragged": M.DistContext(mesh=mesh, moe_chunks=2,
                                  moe_strategy="ep_shardmap", moe_ragged=True),
          "ragged_pallas": M.DistContext(mesh=mesh, moe_chunks=2,
                                         moe_strategy="ep_shardmap",
                                         moe_ragged=True, use_pallas=True,
                                         pallas_interpret=True),
        }
        ys = {}
        with set_mesh(mesh):
            for name, ctx in ctxs.items():
                y, s = jax.jit(lambda p, x, c=ctx: M.moe_ffn(p, x, cfg, c))(params, x)
                ys[name] = np.asarray(y)
                assert float(s["drops"]) == 0.0, name
            g1 = jax.jit(jax.grad(lambda p: M.moe_ffn(p, x, cfg, ctxs["ragged_pallas"])[0].sum()))(params)
        g2 = jax.grad(lambda p: M.moe_ffn(p, x, cfg, M.DistContext(moe_chunks=2))[0].sum())(params)
        assert np.abs(ys["ragged"] - ys["ep"]).max() < 1e-5
        assert np.abs(ys["ragged_pallas"] - ys["ep"]).max() < 1e-5
        errs = [np.abs(np.asarray(a) - np.asarray(b)).max()
                for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2))]
        assert max(errs) < 1e-4, errs
        print("RAGGED-EP OK")
    """, devices=4)
    assert "RAGGED-EP OK" in out
