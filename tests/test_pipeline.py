"""Pipelined FCDA schedule (docs/DESIGN.md §Pipeline): chunked_pipeline ≡
chunked_map (values, grads, stats contract), the extended memory model's
pipeline-depth term, and MACT's joint (chunk bin, depth) selection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import GPU_64G, get_config
from repro.configs.base import MoEConfig
from repro.core import memory_model as mm
from repro.core import moe as M
from repro.core.chunking import ChunkStages, chunked_map, chunked_pipeline, compose
from repro.core.mact import MACTController
from repro.core.moe import DistContext

CFG = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64)
CAP_CFG = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                    capacity_mode="capacity", capacity_factor=0.5)


# ---------------------------------------------------------------------------
# chunking-level: synthetic stages
# ---------------------------------------------------------------------------

def _toy_stages(w1, w2):
    """Stage split with a permutation through the middle (order-sensitive:
    any chunk mis-sequencing scrambles the output)."""
    def dispatch(xc):
        idx = jnp.argsort(xc[:, 0])
        return {"x": xc[idx] * 2.0, "idx": idx,
                "load": jnp.histogram(xc[:, 0], bins=4, range=(-3, 3))[0]}

    def compute(st):
        return {"h": jax.nn.silu(st["x"] @ w1), "idx": st["idx"],
                "load": st["load"]}

    def combine(st):
        y = (st["h"] @ w2)[jnp.argsort(st["idx"])]
        return y, {"load": st["load"].astype(jnp.float32),
                   "aux": (st["h"] ** 2).mean()}

    return ChunkStages(dispatch, compute, combine)


@pytest.fixture(scope="module")
def toy():
    k1, k2, kx = jax.random.split(jax.random.PRNGKey(0), 3)
    w1 = jax.random.normal(k1, (8, 16)) * 0.3
    w2 = jax.random.normal(k2, (16, 8)) * 0.3
    x = jax.random.normal(kx, (64, 8))
    return _toy_stages(w1, w2), x, (w1, w2)


@pytest.mark.parametrize("c", [2, 4, 8])
@pytest.mark.parametrize("remat", [True, False])
def test_pipeline_matches_map(toy, c, remat):
    stages, x, _ = toy
    y0, s0 = chunked_map(compose(stages), x, c, remat=remat)
    for depth in (2, c):
        y1, s1 = chunked_pipeline(stages, x, c, depth=depth, remat=remat)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
        np.testing.assert_allclose(np.asarray(s0["load"]),
                                   np.asarray(s1["load"]))
        np.testing.assert_allclose(float(s0["aux"]), float(s1["aux"]),
                                   rtol=1e-6)


def test_pipeline_gradients_match_map(toy):
    stages, x, (w1, w2) = toy

    def loss_map(x):
        y, s = chunked_map(compose(stages), x, 4, remat=True)
        return (y ** 2).sum() + s["aux"]

    def loss_pipe(x):
        y, s = chunked_pipeline(stages, x, 4, depth=2, remat=True)
        return (y ** 2).sum() + s["aux"]

    g0, g1 = jax.grad(loss_map)(x), jax.grad(loss_pipe)(x)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-5)


def test_pipeline_depth_fallbacks(toy):
    stages, x, _ = toy
    y0, _ = chunked_map(compose(stages), x, 4)
    # depth 1 and depth-not-dividing fall back to the sequential schedule
    for depth in (1, 3):
        y1, _ = chunked_pipeline(stages, x, 4, depth=depth)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
    # depth > chunks clamps to chunks
    y2, _ = chunked_pipeline(stages, x, 2, depth=8)
    y3, _ = chunked_map(compose(stages), x, 2)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y3), atol=1e-6)
    with pytest.raises(ValueError):
        chunked_pipeline(stages, x, 4, depth=0)
    with pytest.raises(ValueError):
        chunked_pipeline(stages, jnp.zeros((10, 3)), 3)


# ---------------------------------------------------------------------------
# EP path on a 1-device mesh: the real stage split, in-process
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ep_setup():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = M.init_moe(jax.random.PRNGKey(0), 32, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    return mesh, params, x


def _run(mesh, params, x, cfg, **ctx_kw):
    ctx = DistContext(mesh=mesh, moe_strategy="ep_shardmap", **ctx_kw)
    from repro.compat import set_mesh
    with set_mesh(mesh):
        return jax.jit(lambda p, x: M.moe_ffn(p, x, cfg, ctx))(params, x)


@pytest.mark.parametrize("c", [2, 4, 8])
@pytest.mark.parametrize("remat", [True, False])
def test_ep_pipeline_parity(ep_setup, c, remat):
    mesh, params, x = ep_setup
    y0, s0 = _run(mesh, params, x, CFG, moe_chunks=c, remat_chunks=remat)
    y1, s1 = _run(mesh, params, x, CFG, moe_chunks=c, remat_chunks=remat,
                  pipeline_chunks=2)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s0["load"]),
                                  np.asarray(s1["load"]))
    assert float(s0["drops"]) == float(s1["drops"]) == 0.0
    np.testing.assert_allclose(float(s0["aux_loss"]), float(s1["aux_loss"]),
                               rtol=1e-6)


def test_ep_pipeline_parity_capacity_mode(ep_setup):
    mesh, params, x = ep_setup
    y0, s0 = _run(mesh, params, x, CAP_CFG, moe_chunks=4)
    y1, s1 = _run(mesh, params, x, CAP_CFG, moe_chunks=4, pipeline_chunks=2)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s0["load"]),
                                  np.asarray(s1["load"]))
    assert float(s0["drops"]) == float(s1["drops"]) > 0   # baseline drops
    np.testing.assert_allclose(float(s0["aux_loss"]), float(s1["aux_loss"]),
                               rtol=1e-6)


@pytest.mark.parametrize("c", [2, 8])
def test_ep_pipeline_gradient_parity(ep_setup, c):
    mesh, params, x = ep_setup
    from repro.compat import set_mesh

    def loss(p, ctx):
        return M.moe_ffn(p, x, CFG, ctx)[0].sum()

    ctx0 = DistContext(mesh=mesh, moe_strategy="ep_shardmap", moe_chunks=c)
    ctx1 = DistContext(mesh=mesh, moe_strategy="ep_shardmap", moe_chunks=c,
                       pipeline_chunks=2)
    with set_mesh(mesh):
        g0 = jax.jit(jax.grad(lambda p: loss(p, ctx0)))(params)
        g1 = jax.jit(jax.grad(lambda p: loss(p, ctx1)))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_pipeline_matches_dense_oracle(ep_setup):
    mesh, params, x = ep_setup
    y, _ = _run(mesh, params, x, CFG, moe_chunks=4, pipeline_chunks=2)
    yd, _ = M.moe_ffn(params, x, CFG, DistContext(moe_strategy="dense"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=1e-5)


# ---------------------------------------------------------------------------
# memory model: the pipeline-depth term
# ---------------------------------------------------------------------------

def test_activation_bytes_pipeline_term():
    cfg = get_config("deepseek-mini-16l")
    dims = mm.LayerDims.from_config(cfg)
    par = mm.Parallelism(t=1, p=4, c=1, e=32, d=1, b=1)
    base = mm.activation_bytes(dims, 4096, 6e5, par, chunks=8)
    two = mm.activation_bytes(dims, 4096, 6e5, par, chunks=8,
                              pipeline_depth=2)
    # depth-2 at c chunks keeps exactly the memory of depth-1 at c/2 chunks
    half = mm.activation_bytes(dims, 4096, 6e5, par, chunks=4)
    assert two > base
    assert np.isclose(two, half, rtol=1e-12)
    # live chunks cap at the chunk count (depth > c adds nothing more)
    capped = mm.activation_bytes(dims, 4096, 6e5, par, chunks=2,
                                 pipeline_depth=8)
    flat = mm.activation_bytes(dims, 4096, 6e5, par, chunks=2,
                               pipeline_depth=2)
    assert np.isclose(capped, flat, rtol=1e-12)


def test_optimal_chunks_with_depth():
    assert mm.optimal_chunks(1000, 600) == 2
    assert mm.optimal_chunks(1000, 600, pipeline_depth=2) == 4
    # never fewer chunks than the depth (all-live degenerate case)
    assert mm.optimal_chunks(10, 600, pipeline_depth=2) == 2
    assert mm.optimal_chunks(1000, 0, pipeline_depth=2) == 1 << 30


# ---------------------------------------------------------------------------
# MACT: joint (chunk bin, pipeline depth) selection
# ---------------------------------------------------------------------------

PAPER_PAR = mm.Parallelism(t=1, p=4, c=1, e=32, d=1, b=1)


@pytest.fixture(scope="module")
def mact():
    return MACTController(get_config("deepseek-mini-16l"), PAPER_PAR, GPU_64G,
                          seq_len=4096, static_override=43e9)


def test_mact_picks_depth2_when_extra_copy_fits(mact):
    # paper's observed distribution: c*=2 sequential; the depth-2 schedule
    # needs twice the chunks — a bin covers that, so MACT pipelines
    s_pp = 5.97e5
    assert mact.optimal_c(s_pp) == 2
    load = np.zeros(32)
    load[0] = s_pp                    # hottest device sees s_pp
    b, depth = mact.choose_schedule(load, ep_size=32)
    assert depth == 2
    assert b >= mm.optimal_chunks(s_pp, mact.s_prime_max(), pipeline_depth=2)
    assert mact.history[-1]["depth"] == 2


def test_mact_refuses_depth2_when_extra_copy_does_not_fit(mact):
    # s'' at 5x s'_max: sequential needs c=5 (bin 8 covers), but depth-2
    # needs c=10 > max bin — MACT must fall back to the sequential schedule
    s_pp = 5.0 * mact.s_prime_max()
    load = np.zeros(32)
    load[0] = s_pp
    b2, depth = mact.choose_schedule(load, ep_size=32)
    assert depth == 1
    assert b2 == 8
    # and the fallback is exactly what the sequential-only API picks
    assert mact.choose(load, ep_size=32) == b2


def test_mact_cold_start_is_admissible(mact):
    # cold start plans for the worst case s' -> e*s*k; whatever (bin, depth)
    # it picks must satisfy the extended Eq. 9 bound at that depth
    b, depth = mact.choose_schedule()
    wc = mm.worst_case_s_prime(4096, PAPER_PAR, mact.dims.topk)
    assert b >= mm.optimal_chunks(wc, mact.s_prime_max(),
                                  pipeline_depth=depth)


def test_memory_report_depth_term(mact):
    seq = mact.memory_report(5.97e5, chunks=4)
    pipe = mact.memory_report(5.97e5, chunks=4, pipeline_depth=2)
    assert pipe["activation_gb"] > seq["activation_gb"]
    assert pipe["pipeline_depth"] == 2


def test_observed_s_pp_rejects_indivisible_load(mact):
    with pytest.raises(ValueError, match="does not divide"):
        mact.observed_s_pp(np.ones(33), ep_size=32)
    # divisible load reshapes to per-device sums
    load = np.arange(64, dtype=np.float64)
    got = mact.observed_s_pp(load, ep_size=32)
    assert got == load.reshape(32, 2).sum(axis=1).max()


def test_trainer_schedule_is_sequential_without_mesh():
    from repro.training.trainer import Trainer
    cfg = get_config("deepseek-mini-8l").reduced()
    tr = Trainer(cfg, DistContext(), seq_len=64, global_batch=2, lr=1e-3)
    chunks, depth = tr.choose_schedule()
    assert depth == 1                 # local path has no all-to-all to overlap
    assert chunks in tr.mact_bins
