"""Pallas dispatch/combine kernels vs the jnp references (interpret mode):
forward bit-for-bit under exact arithmetic, VJP vs autodiff'd jnp path, and
gradient parity of the full MoE layer (ragged custom VJP + dispatch/combine
custom VJP) against the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core import moe as M
from repro.kernels import dispatch_pallas as dp
from repro.kernels import ops, ref


def _exact_case(seed, T=24, K=2, E=4, d=16, bm=8):
    """Inputs whose products/sums are exact in float32, so parity between
    kernel and reference is bit-for-bit regardless of FMA contraction."""
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.permutation(E)[:K] for _ in range(T)]).astype(np.int32)
    x = jnp.asarray(rng.integers(-8, 8, (T, d)), jnp.float32)
    w = jnp.asarray(2.0 ** rng.integers(-2, 2, (T, K)), jnp.float32)
    R = T * K + E * bm
    R = -(-R // bm) * bm
    plan = dsp.make_ragged_plan(jnp.asarray(idx), E, R, bm)
    return x, w, plan, R


@pytest.mark.parametrize("seed", range(4))
def test_scatter_kernel_bitexact(seed):
    x, w, plan, R = _exact_case(seed)
    K = plan.slots.shape[1]
    pos = dsp.invert_slots(plan.slots, R)
    src = jnp.where(pos >= 0, pos // K, -1)
    out_k = dp.scatter_rows(x, src, plan.total_rows, interpret=True)
    out_r = ref.scatter_rows_ref(x, src, plan.total_rows)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    # and matches the production jnp scatter path
    np.testing.assert_array_equal(
        np.asarray(out_k), np.asarray(dsp.scatter_rows_flat(x, plan.slots, R)))


@pytest.mark.parametrize("seed", range(4))
def test_gather_kernel_bitexact(seed):
    x, w, plan, R = _exact_case(seed)
    K = plan.slots.shape[1]
    pos = dsp.invert_slots(plan.slots, R)
    src = jnp.where(pos >= 0, pos // K, -1)
    buf = dp.scatter_rows(x, src, plan.total_rows, interpret=True)
    out_k = dp.gather_combine(buf, plan.slots, w, interpret=True)
    out_r = ref.gather_combine_ref(buf, plan.slots, w)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(
        np.asarray(out_k),
        np.asarray(dsp.gather_rows_flat(buf, plan.slots, w)))


def test_scatter_predication_skips_blocks_past_total_rows():
    """Garbage in src past total_rows must not leak into the buffer."""
    x, w, plan, R = _exact_case(0)
    K = plan.slots.shape[1]
    pos = dsp.invert_slots(plan.slots, R)
    src = jnp.where(pos >= 0, pos // K, -1)
    tr = int(plan.total_rows)
    bm = 8
    # poison src in the dead region ON a block boundary past total_rows
    dead_start = -(-tr // bm) * bm
    if dead_start < R:
        src = src.at[dead_start:].set(0)
        out = dp.scatter_rows(x, src, tr, interpret=True)
        assert (np.asarray(out)[dead_start:] == 0).all()


@pytest.mark.parametrize("seed", range(3))
def test_dispatch_combine_vjp_matches_jnp(seed):
    """grad through the Pallas custom-VJP pair == grad through the plain
    jnp scatter/gather (autodiff) for x AND combine weights."""
    rng = np.random.default_rng(seed)
    T, K, E, d, bm = 16, 2, 4, 8, 4
    idx = np.stack([rng.permutation(E)[:K] for _ in range(T)]).astype(np.int32)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w = jnp.asarray(rng.random((T, K)), jnp.float32)
    R = T * K + E * bm
    R = -(-R // bm) * bm
    plan = dsp.make_ragged_plan(jnp.asarray(idx), E, R, bm)

    def loss(x, w, use_pallas):
        buf = ops.dispatch_rows(x, plan.slots, R, total_rows=plan.total_rows,
                                use_pallas=use_pallas, interpret=use_pallas,
                                block_m=bm)
        y = ops.combine_rows(buf * 2.0, plan.slots, w,
                             use_pallas=use_pallas, interpret=use_pallas,
                             block_t=bm)
        return (y ** 2).sum()

    gp = jax.grad(lambda x, w: loss(x, w, True), argnums=(0, 1))(x, w)
    gj = jax.grad(lambda x, w: loss(x, w, False), argnums=(0, 1))(x, w)
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def _uneven_router(params, E):
    """Bias the router so expert loads are strongly uneven."""
    w = np.array(params["router"]["w"])
    w[:, 0] += 2.0  # expert 0 hoovers up most tokens
    params["router"]["w"] = jnp.asarray(w)
    return params


@pytest.mark.parametrize("top_k", [1, 2])
def test_moe_grad_parity_vs_dense_oracle(top_k):
    """grad of the full layer through the ragged custom VJP + the new
    dispatch/combine custom VJP (EP on a 1x1 mesh, Pallas interpret) matches
    the dense oracle, under deliberately uneven expert loads."""
    cfg = MoEConfig(num_experts=4, top_k=top_k, d_ff_expert=32)
    params = M.init_moe(jax.random.PRNGKey(0), 16, cfg)
    params = _uneven_router(params, cfg.num_experts)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    ctx_pallas = M.DistContext(mesh=mesh, moe_chunks=2,
                               moe_strategy="ep_shardmap", moe_ragged=True,
                               use_pallas=True, pallas_interpret=True)
    ctx_dense = M.DistContext(moe_strategy="dense")

    def loss(p, ctx):
        y, _ = M.moe_ffn(p, x, cfg, ctx)
        return (y ** 2).sum()

    g1 = jax.grad(lambda p: loss(p, ctx_pallas))(params)
    g2 = jax.grad(lambda p: loss(p, ctx_dense))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@pytest.mark.parametrize("top_k", [1, 2])
def test_ragged_ffn_vjp_vs_dense_oracle(top_k):
    """grad of the ragged custom VJP (_ragged_ffn_kernel) alone vs the dense
    einsum oracle on the same routed layout, uneven loads, interpret mode."""
    rng = np.random.default_rng(0)
    T, E, d, f, bm = 32, 4, 16, 32, 8
    K = top_k
    # uneven: most tokens on expert 0
    idx = np.where(rng.random((T, K)) < 0.7, 0,
                   rng.integers(0, E, (T, K))).astype(np.int32)
    if K == 2:  # keep the two picks distinct
        idx[:, 1] = (idx[:, 0] + 1 + idx[:, 1] % (E - 1)) % E
    R = T * K + E * bm
    R = -(-R // bm) * bm
    plan = dsp.make_ragged_plan(jnp.asarray(idx), E, R, bm)
    x = jnp.asarray(rng.standard_normal((T, d)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((E, d, f)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((E, f, d)) * 0.1, jnp.float32)

    def loss(x, w1, w3, w2, use_pallas):
        buf = ops.dispatch_rows(x, plan.slots, R, total_rows=plan.total_rows,
                                use_pallas=use_pallas, interpret=use_pallas)
        h = ops.ragged_expert_ffn(buf, w1, w3, w2, plan.block_to_expert,
                                  plan.total_rows, block_m=bm,
                                  use_pallas=use_pallas, interpret=use_pallas)
        y = ops.combine_rows(h, plan.slots, use_pallas=use_pallas,
                             interpret=use_pallas)
        return (y ** 2).sum()

    gp = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2, 3))(
        x, w1, w3, w2)
    gd = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2, 3))(
        x, w1, w3, w2)
    for a, b in zip(gp, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-4)
