"""Validate the theoretical memory model against the paper's Table 4 and the
MACT equations (Eq. 8-9), plus hypothesis property checks."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import GPU_64G, TPU_V5E, get_config
from repro.core import memory_model as mm
from repro.core.mact import MACTController

# Paper §5 experimental setup: t=1, p=4, e=32, d=1, c=1, b=1, s=4096, bf16.
PAPER_PAR = mm.Parallelism(t=1, p=4, c=1, e=32, d=1, b=1)
# docs/DESIGN.md calibration: the s'' behind the paper's 22.9 GB activation figure.
CALIBRATED_S_PP = 5.97e5


@pytest.fixture(scope="module")
def model_i():
    return get_config("deepseek-mini-16l")


def test_paper_reduction_ratios(model_i):
    """Table 4: c=8 -> -83.84%, MACT c=2 -> -48.03% activation memory.
    Our model reproduces both within 2.5 points (paper omits h_d/k_a/e_n)."""
    dims = mm.LayerDims.from_config(model_i)
    base = mm.activation_bytes(dims, 4096, CALIBRATED_S_PP, PAPER_PAR, chunks=1)
    red2 = 1 - mm.activation_bytes(dims, 4096, CALIBRATED_S_PP, PAPER_PAR,
                                   chunks=2) / base
    red8 = 1 - mm.activation_bytes(dims, 4096, CALIBRATED_S_PP, PAPER_PAR,
                                   chunks=8) / base
    assert abs(red2 - 0.4803) < 0.025, red2
    assert abs(red8 - 0.8384) < 0.025, red8


def test_paper_activation_magnitude(model_i):
    """Method 1 activation ~22.9 GB (we land within 15% with MHA-for-MLA)."""
    dims = mm.LayerDims.from_config(model_i)
    act = mm.activation_bytes(dims, 4096, CALIBRATED_S_PP, PAPER_PAR, chunks=1)
    assert 19e9 < act < 26e9, act / 1e9


def test_mact_reproduces_paper_chunk_choice(model_i):
    """With the paper's measured static memory (43 GB) on 64 GB GPUs, MACT
    derives c*=2 for the observed distribution — exactly Table 4 Method 3."""
    mact = MACTController(model_i, PAPER_PAR, GPU_64G, seq_len=4096,
                          static_override=43e9)
    c = mact.optimal_c(CALIBRATED_S_PP)
    assert c == 2
    assert mact.snap(c) == 2


def test_mact_cold_start_is_conservative(model_i):
    mact = MACTController(model_i, PAPER_PAR, GPU_64G, seq_len=4096,
                          static_override=43e9)
    cold = mact.choose()            # worst case s' -> e*s*k
    informed = mact.snap(mact.optimal_c(CALIBRATED_S_PP))
    assert cold >= informed


def test_eq8_inverts_eq2(model_i):
    """s'_max is exactly the s' at which Eq. 2 meets the budget (Eq. 3)."""
    dims = mm.LayerDims.from_config(model_i)
    static = 43e9
    smax = mm.s_prime_max(dims, 4096, PAPER_PAR, GPU_64G, static)
    act = mm.activation_bytes(dims, 4096, smax, PAPER_PAR, chunks=1)
    assert math.isclose(static + act, GPU_64G.alpha * GPU_64G.hbm_bytes,
                        rel_tol=1e-6)


def test_worst_case_s_prime(model_i):
    wc = mm.worst_case_s_prime(4096, PAPER_PAR, topk=8)
    assert wc == 32 * 4096 * 8      # e * s * k (b=1)


def test_static_memory_model_vs_paper(model_i):
    """Eq. 1 static memory: our param-count model lands in the right decade
    and Model I > Model II (can't invert exactly — MLA internals unknown)."""
    s16 = mm.static_bytes(model_i, PAPER_PAR)
    s8 = mm.static_bytes(get_config("deepseek-mini-8l"), PAPER_PAR)
    assert 30e9 < s16 < 90e9
    assert s8 < s16


def test_snap_picks_covering_bin(model_i):
    mact = MACTController(model_i, PAPER_PAR, GPU_64G, seq_len=4096,
                          static_override=43e9)
    assert mact.snap(1) == 1
    assert mact.snap(3) == 4
    assert mact.snap(8) == 8
    assert mact.snap(100) == 8       # none covers -> largest bin


@given(s_pp=st.floats(1, 1e7), chunks=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_activation_monotonicity(s_pp, chunks):
    """More chunks never increases the modeled activation; more received
    tokens never decreases it."""
    cfg = get_config("deepseek-mini-16l")
    dims = mm.LayerDims.from_config(cfg)
    a1 = mm.activation_bytes(dims, 4096, s_pp, PAPER_PAR, chunks=chunks)
    a2 = mm.activation_bytes(dims, 4096, s_pp, PAPER_PAR, chunks=chunks + 1)
    a3 = mm.activation_bytes(dims, 4096, s_pp * 2, PAPER_PAR, chunks=chunks)
    assert a2 <= a1 + 1e-6
    assert a3 >= a1 - 1e-6


@given(s_pp=st.floats(1e3, 1e7))
@settings(max_examples=30, deadline=None)
def test_eq9_chunk_count_sufficient(s_pp):
    """The chunk count from Eq. 9 always brings the per-chunk token count
    under s'_max (the defining property of MACT)."""
    cfg = get_config("deepseek-mini-16l")
    mact = MACTController(cfg, PAPER_PAR, GPU_64G, seq_len=4096,
                          static_override=43e9)
    smax = mact.s_prime_max()
    c = mm.optimal_chunks(s_pp, smax)
    if c < (1 << 30):
        assert s_pp / c <= smax + 1e-6
        if c > 1:                    # and c is minimal
            assert s_pp / (c - 1) > smax


def test_params_active_vs_total():
    cfg = get_config("mixtral-8x7b")
    total = mm.total_params(cfg)
    active = mm.active_params(cfg)
    assert 40e9 < total < 52e9       # Mixtral ~47B
    assert 10e9 < active < 16e9      # ~13B active
