"""Pallas kernel validation: interpret-mode vs the pure-jnp oracle, swept
over shapes and dtypes (+ hypothesis property sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.grouped_mlp import grouped_matmul, grouped_swiglu
from repro.kernels.tiling import pick_block as _pick_block
from repro.kernels.ops import expert_ffn

SHAPES = [
    (1, 8, 16, 8),
    (2, 32, 64, 32),
    (4, 128, 128, 256),
    (3, 64, 96, 48),      # non-power-of-two
    (2, 256, 512, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(key, shape, dtype, scale=0.5):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_grouped_matmul_matches_ref(shape, dtype):
    E, M, K, N = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = _rand(k1, (E, M, K), dtype)
    w = _rand(k2, (E, K, N), dtype, 0.1)
    out = grouped_matmul(x, w, interpret=True, block_m=32, block_n=32, block_k=32)
    expect = ref.grouped_matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_grouped_swiglu_matches_ref(shape, dtype):
    E, M, K, N = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = _rand(k1, (E, M, K), dtype)
    w1 = _rand(k2, (E, K, N), dtype, 0.1)
    w3 = _rand(k3, (E, K, N), dtype, 0.1)
    out = grouped_swiglu(x, w1, w3, interpret=True, block_m=32, block_n=32,
                         block_k=32)
    expect = ref.grouped_swiglu_ref(x, w1, w3)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_expert_ffn_pallas_path_full():
    E, C, d, f = 2, 64, 32, 48
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = _rand(ks[0], (E, C, d), jnp.float32)
    w1 = _rand(ks[1], (E, d, f), jnp.float32, 0.1)
    w3 = _rand(ks[2], (E, d, f), jnp.float32, 0.1)
    w2 = _rand(ks[3], (E, f, d), jnp.float32, 0.1)
    out = expert_ffn(x, w1, w3, w2, use_pallas=True, interpret=True)
    expect = ref.expert_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_expert_ffn_batched_leading_dims():
    B, E, C, d, f = 3, 2, 16, 8, 12
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _rand(ks[0], (B, E, C, d), jnp.float32)
    w1 = _rand(ks[1], (E, d, f), jnp.float32, 0.1)
    w3 = _rand(ks[2], (E, d, f), jnp.float32, 0.1)
    w2 = _rand(ks[3], (E, f, d), jnp.float32, 0.1)
    out = expert_ffn(x, w1, w3, w2, use_pallas=True, interpret=True)
    expect = ref.expert_ffn_ref(x, w1, w3, w2)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@given(e=st.integers(1, 4), m=st.sampled_from([8, 16, 24, 64]),
       k=st.sampled_from([8, 32, 40]), n=st.sampled_from([8, 16, 56]))
@settings(max_examples=12, deadline=None)
def test_grouped_matmul_property(e, m, k, n):
    key = jax.random.PRNGKey(m * 1000 + k * 10 + n)
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (e, m, k), jnp.float32)
    w = _rand(k2, (e, k, n), jnp.float32, 0.1)
    out = grouped_matmul(x, w, interpret=True, block_m=8, block_n=8, block_k=8)
    np.testing.assert_allclose(out, ref.grouped_matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_pick_block_divides():
    for dim in (8, 24, 100, 128, 1000):
        for pref in (8, 32, 128):
            b = _pick_block(dim, pref)
            assert dim % b == 0 and 1 <= b <= max(pref, 1)


def test_choose_block_pads_primes():
    """Regression: pick_block degenerates to 1-wide tiles on prime dims past
    the preferred block; choose_block keeps the full block and pads."""
    from repro.kernels.tiling import choose_block
    for dim in (131, 257, 1009):
        assert _pick_block(dim, 128) == 1          # the old degenerate pick
        c = choose_block(dim, 128)
        assert c.block == 128 and c.padded % 128 == 0 and c.padded >= dim
        assert c.grid == c.padded // 128
    # aligned dims stay unpadded (zero overhead on the common case)
    assert choose_block(256, 128) == (128, 256)
    assert choose_block(24, 128) == (24, 24)
    with pytest.raises(ValueError):
        choose_block(0, 128)


@pytest.mark.parametrize("m,k,n", [(13, 29, 257), (16, 131, 37)])
def test_grouped_kernels_prime_dims(m, k, n):
    """Prime/odd M, K, N: the padded-tile path (explicit small blocks force
    padding on every dim) still matches the reference exactly."""
    key = jax.random.PRNGKey(m + k + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (2, m, k), jnp.float32)
    w1 = _rand(k2, (2, k, n), jnp.float32, 0.1)
    w3 = _rand(k3, (2, k, n), jnp.float32, 0.1)
    out = grouped_matmul(x, w1, block_m=8, block_n=8, block_k=8,
                         interpret=True)
    np.testing.assert_allclose(out, ref.grouped_matmul_ref(x, w1),
                               rtol=1e-5, atol=1e-5)
    out = grouped_swiglu(x, w1, w3, block_m=8, block_n=8, block_k=8,
                         interpret=True)
    np.testing.assert_allclose(out, ref.grouped_swiglu_ref(x, w1, w3),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ragged (MegaBlocks-style) kernels
# ---------------------------------------------------------------------------

from repro.core import dispatch as dsp
from repro.kernels.ops import ragged_expert_ffn
from repro.kernels.ragged_mlp import ragged_matmul, ragged_swiglu


def _ragged_setup(T=37, K=2, E=4, d=16, f=24, bm=8, seed=0):
    key = jax.random.PRNGKey(seed)
    idx = jnp.stack([jax.random.permutation(jax.random.fold_in(key, i), E)[:K]
                     for i in range(T)]).astype(jnp.int32)
    x = jax.random.normal(jax.random.fold_in(key, 99), (T, d))
    R = -(-(T * K + E * bm) // bm) * bm
    plan = dsp.make_ragged_plan(idx, E, R, bm)
    buf = dsp.scatter_rows_flat(x, plan.slots, R)
    ks = jax.random.split(key, 3)
    w1 = jax.random.normal(ks[0], (E, d, f)) * 0.1
    w3 = jax.random.normal(ks[1], (E, d, f)) * 0.1
    w2 = jax.random.normal(ks[2], (E, f, d)) * 0.1
    return plan, buf, w1, w3, w2, idx, x


def test_ragged_matmul_matches_ref():
    plan, buf, w1, _, _, _, _ = _ragged_setup()
    out = ragged_matmul(buf, w1, plan.block_to_expert, plan.total_rows,
                        block_m=8, interpret=True)
    expect = ref.ragged_matmul_ref(buf, w1, plan.block_to_expert,
                                   plan.total_rows)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_ragged_swiglu_matches_ref():
    plan, buf, w1, w3, _, _, _ = _ragged_setup(seed=1)
    out = ragged_swiglu(buf, w1, w3, plan.block_to_expert, plan.total_rows,
                        block_m=8, interpret=True)
    expect = ref.ragged_swiglu_ref(buf, w1, w3, plan.block_to_expert,
                                   plan.total_rows)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_ragged_kernels_prime_dims():
    """Prime hidden/ffn dims on the ragged layout: K/N pad, R stays plan-
    aligned, results match the reference."""
    plan, buf, w1, w3, _, _, _ = _ragged_setup(d=17, f=37, seed=4)
    out = ragged_matmul(buf, w1, plan.block_to_expert, plan.total_rows,
                        block_m=8, block_n=8, block_k=8, interpret=True)
    np.testing.assert_allclose(
        out, ref.ragged_matmul_ref(buf, w1, plan.block_to_expert,
                                   plan.total_rows), rtol=1e-5, atol=1e-5)
    out = ragged_swiglu(buf, w1, w3, plan.block_to_expert, plan.total_rows,
                        block_m=8, block_n=8, block_k=8, interpret=True)
    np.testing.assert_allclose(
        out, ref.ragged_swiglu_ref(buf, w1, w3, plan.block_to_expert,
                                   plan.total_rows), rtol=1e-5, atol=1e-5)


def test_ragged_ffn_equals_per_expert_path():
    plan, buf, w1, w3, w2, idx, x = _ragged_setup(seed=2)
    T = x.shape[0]
    h = ragged_expert_ffn(buf, w1, w3, w2, plan.block_to_expert,
                          plan.total_rows, block_m=8, use_pallas=True,
                          interpret=True)
    y_ragged = dsp.gather_rows_flat(h, plan.slots, jnp.ones(idx.shape))
    plan_d = dsp.make_plan(idx, 4, T)
    buf_d = dsp.scatter_rows(x, plan_d, 4, T)
    y_dense = dsp.gather_rows(ref.expert_ffn_ref(buf_d, w1, w3, w2), plan_d,
                              jnp.ones(idx.shape))
    np.testing.assert_allclose(np.asarray(y_ragged), np.asarray(y_dense),
                               rtol=1e-5, atol=1e-5)


def test_ragged_custom_vjp_matches_ref_grads():
    plan, buf, w1, w3, w2, _, _ = _ragged_setup(seed=3)

    def loss(b, w1, w3, w2, pallas):
        return ragged_expert_ffn(b, w1, w3, w2, plan.block_to_expert,
                                 plan.total_rows, block_m=8,
                                 use_pallas=pallas, interpret=True).sum()

    g_ref = jax.grad(loss, argnums=(0, 1, 2, 3))(buf, w1, w3, w2, False)
    g_pal = jax.grad(loss, argnums=(0, 1, 2, 3))(buf, w1, w3, w2, True)
    for a, b in zip(g_ref, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@given(t=st.integers(4, 48), e=st.integers(2, 6), k=st.integers(1, 3),
       seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_ragged_plan_properties(t, e, k, seed):
    """Blocks map to one expert each; slots unique; no drops at worst-case R."""
    k = min(k, e)
    bm = 8
    key = jax.random.PRNGKey(seed)
    idx = jnp.stack([jax.random.permutation(jax.random.fold_in(key, i), e)[:k]
                     for i in range(t)]).astype(jnp.int32)
    R = -(-(t * k + e * bm) // bm) * bm
    plan = dsp.make_ragged_plan(idx, e, R, bm)
    assert int(plan.drops) == 0
    s = np.asarray(plan.slots).reshape(-1)
    v = s[s >= 0]
    assert len(np.unique(v)) == len(v)
    b2e = np.asarray(plan.block_to_expert)
    for slot, ee in zip(s, np.asarray(idx).reshape(-1)):
        assert b2e[slot // bm] == ee
    assert int(plan.total_rows) % bm == 0
    assert int(np.asarray(plan.load).sum()) == t * k


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

from repro.configs.base import AttentionSpec
from repro.kernels.flash_attention import flash_attention
from repro.models.attention import attention


def _fold(x):
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _unfold(x, B, H):
    BH, S, hd = x.shape
    return x.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("mode,kwargs,spec", [
    ("causal", dict(causal=True), AttentionSpec(kind="full")),
    ("window", dict(causal=True, window=16),
     AttentionSpec(kind="window", window=16)),
    ("cross", dict(causal=False), AttentionSpec(kind="full")),
])
def test_flash_attention_matches_blocked_jnp(mode, kwargs, spec):
    B, S, H, hd = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = _unfold(flash_attention(_fold(q), _fold(k), _fold(v),
                                  interpret=True, block_q=16, block_kv=16,
                                  **kwargs), B, H)
    expect = attention(q, k, v, spec, causal=kwargs.get("causal", True),
                       block_q=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(8, 32), (64, 8), (64, 64)])
def test_flash_attention_block_shape_invariance(bq, bk):
    B, S, H, hd = 1, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = _unfold(flash_attention(_fold(q), _fold(k), _fold(v), causal=True,
                                  interpret=True, block_q=bq, block_kv=bk),
                  B, H)
    expect = attention(q, k, v, AttentionSpec(kind="full"), block_q=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
