"""Recovery paths: fault injection, the OOM degradation ladder,
crash-consistent checkpoint/resume, and the serving shed/requeue
invariants (docs/DESIGN.md §Resilience)."""

import os

import jax
import numpy as np
import pytest

from repro import checkpointing
from repro.configs import get_config
from repro.core.chunking import ScheduleSpec
from repro.core.moe import DistContext
from repro.core.telemetry import LoadTelemetry
from repro.runtime.faults import (FaultInjector, FaultSpec, SimulatedCrash,
                                  SimulatedOOM, parse_spec)
from repro.runtime.guard import (FULL_REMAT, DegradationLadder, OOMGuard,
                                 ServingGuard, is_oom_error)
from repro.training.step import init_train_state
from repro.training.trainer import Trainer


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# -- fault injector ----------------------------------------------------------

def test_parse_spec_grammar():
    specs = parse_spec("oom@3,burst@2x1.5,ckpt_truncate@4*2")
    assert [(s.kind, s.at, s.magnitude, s.times) for s in specs] == [
        ("oom", 3, 2.0, 1), ("burst", 2, 1.5, 1), ("ckpt_truncate", 4, 2.0, 2)]
    with pytest.raises(ValueError):
        parse_spec("oom")                      # missing @step
    with pytest.raises(ValueError):
        FaultSpec(kind="nonsense", at=0)


def test_injector_fires_once_then_disarms():
    inj = FaultInjector.from_string("oom@3")
    inj.maybe_fail_step(2)                     # not armed yet
    with pytest.raises(SimulatedOOM):
        inj.maybe_fail_step(3)
    inj.maybe_fail_step(3)                     # fired out
    inj.maybe_fail_step(7)
    assert inj.fired == [("oom", 3)]


def test_injector_burst_factor_consistent():
    inj = FaultInjector.from_string("burst@2x3.0")
    assert inj.burst_factor(1) == 1.0
    assert inj.burst_factor(2) == 3.0          # one armed burst, one factor
    assert inj.burst_factor(2) == 1.0


def test_is_oom_error_classification():
    assert is_oom_error(SimulatedOOM())
    assert is_oom_error(MemoryError("boom"))
    assert is_oom_error(RuntimeError("RESOURCE_EXHAUSTED: out of memory"))
    assert not is_oom_error(ValueError("shape mismatch"))
    assert not is_oom_error(SimulatedCrash("died"))


# -- degradation ladder ------------------------------------------------------

SPACE = tuple(ScheduleSpec(b, d) for b in (1, 2, 4, 8) for d in (1, 2)
              if b >= d and b % d == 0)


def test_ladder_rungs_strictly_more_conservative():
    lad = DegradationLadder(SPACE)
    assert lad.rungs_after((2, 2)) == [(2, 1), (4, 1), (8, 1), (FULL_REMAT, 8)]
    assert lad.rungs_after((8, 1)) == [(FULL_REMAT, 8)]
    assert lad.rungs_after((FULL_REMAT, 8)) == []
    # a per-layer vector escalates from its least-chunked layer
    vec = (ScheduleSpec(2, 1), ScheduleSpec(4, 2))
    assert lad.rungs_after(vec)[0] == (2, 1)


def test_guard_escalates_then_succeeds():
    g = OOMGuard(DegradationLadder(SPACE), max_retries=3)
    seen = []

    def attempt(k):
        seen.append(k)
        if len(seen) < 3:
            raise SimulatedOOM("test")
        return "ok"

    result, used = g.run((2, 2), attempt, step=0)
    assert result == "ok" and used == (4, 1)
    assert [e["failed"] for e in g.escalations] == [(2, 2), (2, 1)]


def test_guard_bounded_retries_then_raises():
    g = OOMGuard(DegradationLadder(SPACE), max_retries=2)

    def always_oom(k):
        raise SimulatedOOM("test")

    with pytest.raises(RuntimeError, match="ladder exhausted"):
        g.run((1, 1), always_oom, step=0)
    assert len(g.escalations) == 3             # first try + 2 retries


def test_guard_propagates_non_oom():
    g = OOMGuard(DegradationLadder(SPACE))

    def crash(k):
        raise SimulatedCrash("host died")

    with pytest.raises(SimulatedCrash):
        g.run((1, 1), crash, step=0)
    assert g.escalations == []


# -- crash-consistent checkpointing ------------------------------------------

def _tree(step=3):
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.float32(step)}


def test_checkpoint_checksum_detects_truncation(tmp_path):
    d = str(tmp_path)
    checkpointing.save(d, 2, _tree())
    checkpointing.save(d, 4, _tree())
    assert checkpointing.latest_step(d) == 4
    payload = os.path.join(d, "step_00000004.npz")
    with open(payload, "r+b") as f:
        f.truncate(os.path.getsize(payload) // 2)
    ok, reason = checkpointing.verify(d, 4)
    assert not ok and "checksum" in reason
    # the torn save is skipped, not returned
    assert checkpointing.valid_steps(d) == [2]
    assert checkpointing.latest_step(d) == 2


def test_checkpoint_missing_manifest_is_invalid(tmp_path):
    d = str(tmp_path)
    checkpointing.save(d, 2, _tree())
    os.remove(os.path.join(d, "step_00000002.json"))
    assert checkpointing.latest_step(d) is None


def test_restore_validates_structure(tmp_path):
    d = str(tmp_path)
    checkpointing.save(d, 1, _tree())
    restored = checkpointing.restore(d, 1, _tree())
    assert np.array_equal(restored["w"], _tree()["w"])
    with pytest.raises(ValueError, match="leaves"):
        checkpointing.restore(d, 1, {"w": np.zeros((2, 3), np.float32)})
    with pytest.raises(ValueError, match="treedef"):
        checkpointing.restore(
            d, 1, {"w": np.zeros((2, 3), np.float32),
                   "c": np.float32(0)})      # same leaf count, different tree


def test_checkpoint_extra_roundtrip(tmp_path):
    d = str(tmp_path)
    extra = {"telemetry": {"steps": 3, "ema": [[1.0, 2.0]]},
             "mact_headroom": 0.3}
    checkpointing.save(d, 1, _tree(), extra=extra)
    assert checkpointing.load_extra(d, 1) == extra


def test_telemetry_state_roundtrip():
    t = LoadTelemetry(2, 3, decay=0.5)
    t.update(np.ones((2, 3)))
    t.update(np.full((2, 3), 3.0))
    t2 = LoadTelemetry(2, 3, decay=0.5)
    t2.load_state_dict(t.state_dict())
    assert t2.steps == 2
    assert np.array_equal(t2.loads, t.loads)
    with pytest.raises(ValueError):
        LoadTelemetry(4, 4).load_state_dict(t.state_dict())


# -- trainer recovery paths --------------------------------------------------

CFG = get_config("deepseek-mini-8l").reduced()
TRAIN_KW = dict(seq_len=32, global_batch=2, lr=1e-3)


def test_injected_oom_walks_ladder_and_completes():
    inj = FaultInjector.from_string("oom@2")
    tr = Trainer(CFG, DistContext(), injector=inj, **TRAIN_KW)
    state = tr.fit(4)
    assert int(state.step) == 4
    assert len(tr.guard.escalations) == 1
    assert tr.log[2]["oom_retries"] == 1
    assert tr.chunk_trace[2] > tr.chunk_trace[1]   # escalated = deeper chunks
    assert all(r["oom_retries"] <= tr.max_oom_retries for r in tr.log)


def test_oom_audit_widens_headroom_on_underprediction():
    inj = FaultInjector.from_string("oom@1")
    tr = Trainer(CFG, DistContext(), injector=inj, **TRAIN_KW)
    before = tr.mact_headroom
    tr.fit(3)
    # the model said (1,1) fit, the step OOMed anyway: plan wider
    assert tr.headroom_widenings and tr.mact_headroom > before
    assert tr.guard.audits[0]["modeled_fits"] is True


def test_repeated_oom_reaches_full_remat_floor():
    inj = FaultInjector(specs=[FaultSpec(kind="oom", at=1, times=4)])
    tr = Trainer(CFG, DistContext(), injector=inj, **TRAIN_KW)
    state = tr.fit(2)
    assert int(state.step) == 2
    failed = [e["failed"] for e in tr.guard.escalations]
    assert len(failed) == 4 and failed[-1] == (8, 1)
    # the step that survived ran the full-recompute floor schedule
    assert (FULL_REMAT, 8) in tr._steps


def test_ladder_exhaustion_raises():
    inj = FaultInjector(specs=[FaultSpec(kind="oom", at=1, times=99)])
    tr = Trainer(CFG, DistContext(), injector=inj, max_oom_retries=2,
                 **TRAIN_KW)
    with pytest.raises(RuntimeError, match="ladder exhausted"):
        tr.fit(3)


def test_kill_and_resume_bit_parity(tmp_path):
    kw = dict(adaptive_mact=True, replan_interval=2, checkpoint_every=2,
              **TRAIN_KW)
    # run A: uninterrupted to step 6
    state_a = Trainer(CFG, DistContext(), checkpoint_dir=str(tmp_path / "a"),
                      **kw).fit(6)
    # run B: killed at step 4, resumed to 6
    inj = FaultInjector.from_string("crash@4")
    with pytest.raises(SimulatedCrash):
        Trainer(CFG, DistContext(), checkpoint_dir=str(tmp_path / "b"),
                injector=inj, **kw).fit(6)
    tr = Trainer(CFG, DistContext(), checkpoint_dir=str(tmp_path / "b"),
                 resume=True, **kw)
    state_b = tr.fit(6)
    assert tr.resumed_from == 4
    assert int(state_b.step) == 6
    assert _leaves_equal(state_a, state_b)


def test_resume_skips_truncated_checkpoint(tmp_path):
    d = str(tmp_path)
    inj = FaultInjector.from_string("ckpt_truncate@4")
    Trainer(CFG, DistContext(), checkpoint_dir=d, checkpoint_every=2,
            injector=inj, **TRAIN_KW).fit(6)
    assert checkpointing.valid_steps(d) == [2, 4]   # step-6 save was torn
    tr = Trainer(CFG, DistContext(), checkpoint_dir=d, resume=True,
                 **TRAIN_KW)
    state = tr.fit(6)
    assert tr.resumed_from == 4 and int(state.step) == 6


def test_resume_with_nothing_to_do(tmp_path):
    d = str(tmp_path)
    Trainer(CFG, DistContext(), checkpoint_dir=d, checkpoint_every=2,
            **TRAIN_KW).fit(4)
    tr = Trainer(CFG, DistContext(), checkpoint_dir=d, resume=True,
                 **TRAIN_KW)
    state = tr.fit(4)                         # already at the target
    assert int(state.step) == 4 and tr.log == []


# -- serving shed / requeue invariants ---------------------------------------

@pytest.fixture(scope="module")
def serve_setup():
    from repro.models import transformer
    cfg = get_config("mixtral-8x7b").reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg, DistContext()


def _serve_trace(cfg, n=4, gen=5):
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(0)
    return [Request(rid=i, tokens=rng.integers(0, cfg.vocab_size,
                                               16).astype(np.int32),
                    max_new_tokens=gen, arrival=0.0) for i in range(n)]


def test_decode_fault_requeues_without_loss(serve_setup):
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)
    params, cfg, ctx = serve_setup
    scfg = ServeConfig(max_slots=2, cache_len=32, prefill_chunk=8)
    ref_sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
    ref_sched.run(_serve_trace(cfg))
    ref = {r.rid: list(r.out) for r in ref_sched.finished}

    inj = FaultInjector.from_string("oom@3")
    sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg, injector=inj)
    m = sched.run(_serve_trace(cfg))
    got = {r.rid: list(r.out) for r in sched.finished}
    assert m["faults"] == 1 and m["requeues"] >= 1
    # zero accepted-request loss, and greedy outputs unchanged by the fault
    assert set(sched.admission_order) == set(got)
    assert got == ref
    assert all(r.requeues <= 1 or r.pending_token == -1
               for r in sched.finished)


def test_deadline_expiry_sheds_waiting_with_retry_after(serve_setup):
    from repro.serving.scheduler import (SHED, ContinuousBatchingScheduler,
                                         ServeConfig)
    params, cfg, ctx = serve_setup
    scfg = ServeConfig(max_slots=1, cache_len=32, prefill_chunk=8,
                       deadline_s=0.0)        # nothing waits, ever
    sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
    m = sched.run(_serve_trace(cfg))
    assert m["shed"] >= 1
    for r in sched.shed:
        assert r.state == SHED and not r.accepted
        assert r.retry_after is not None and r.retry_after >= 1.0


def test_overload_bound_sheds_at_submit(serve_setup):
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)
    params, cfg, ctx = serve_setup
    scfg = ServeConfig(max_slots=1, cache_len=32, prefill_chunk=8,
                       max_waiting=1)
    sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
    for req in _serve_trace(cfg, n=4):
        sched.submit(req)
    assert len(sched.queue) <= 1 + 1          # bound + the one being admitted
    assert len(sched.shed) >= 2


def test_accepted_requests_are_deadline_exempt(serve_setup):
    """A requeued (accepted) request older than the deadline still runs —
    the no-accepted-loss invariant beats the admission deadline."""
    from repro.serving.scheduler import (SHED, WAITING,
                                         ContinuousBatchingScheduler,
                                         ServeConfig)
    params, cfg, ctx = serve_setup
    scfg = ServeConfig(max_slots=2, cache_len=32, prefill_chunk=8,
                       deadline_s=0.5)
    sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg)
    fresh, requeued = _serve_trace(cfg, n=2)
    sched.submit(fresh, now=0.0)
    sched.submit(requeued, now=0.0)
    requeued.accepted = True              # as _requeue_active leaves it
    sched._expire_deadlines(now=10.0)     # both far past the deadline
    assert fresh.state == SHED and fresh.retry_after >= 1.0
    assert requeued.state == WAITING
    assert [r.rid for r in sched.queue] == [requeued.rid]
    assert not any(r.accepted for r in sched.shed)


# -- paged serving: faults mid-preemption / mid-CoW (docs/DESIGN.md §Paging) --

def _paged_drained(sched):
    """Allocator is consistent and fully drains once the trie lets go."""
    sched.pool.alloc.audit()
    if sched.trie is not None:
        sched.trie.clear()
    for key in sched.pool.alloc.spaces:
        assert sched.pool.alloc.allocated(key) == 0, f"space {key} leaked"


def _mono_reference(serve_setup, trace_fn):
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeConfig)
    params, cfg, ctx = serve_setup
    scfg = ServeConfig(max_slots=4, cache_len=96, prefill_chunk=8)
    sched = ContinuousBatchingScheduler(params, cfg, ctx, scfg,
                                        key=jax.random.PRNGKey(1))
    sched.run(trace_fn())
    return {r.rid: list(r.out) for r in sched.finished}


def test_fault_mid_preemption_leaves_allocator_consistent(serve_setup):
    """An injected fault that fires inside the preemption spill — after the
    host copy, before any reference drops — aborts the spill with the
    victim still resident, the allocator intact, and zero accepted loss;
    the preemption retries once the injector disarms."""
    import dataclasses
    import types

    from repro.configs.base import GPU_64G
    from repro.core import memory_model as mm
    from repro.serving.paged_scheduler import PagedScheduler
    from repro.serving.scheduler import Request, ServeConfig

    params, cfg, ctx = serve_setup

    def trace():
        rng = np.random.default_rng(5)
        mk = lambda i, gen, prio: Request(  # noqa: E731
            rid=i, tokens=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            max_new_tokens=gen, arrival=0.0, priority=prio)
        return [mk(0, 12, 0), mk(1, 4, 1), mk(2, 4, 1), mk(3, 4, 1)]

    scfg0 = ServeConfig(max_slots=4, cache_len=32, prefill_chunk=8,
                        page_size=8, preemption=True)
    probe = PagedScheduler(params, cfg, ctx, scfg0, key=jax.random.PRNGKey(1))
    per_req = probe.pool.ops.worst_case_bytes(16 + 12)
    base = mm.serving_paged_peak_bytes(cfg, page_bytes=0, decode_tokens=4,
                                       prefill_tokens=8)
    hw = dataclasses.replace(GPU_64G, hbm_bytes=base + 2.2 * per_req,
                             alpha=1.0)
    scfg = dataclasses.replace(scfg0, hw=hw)

    # dry run: record the scheduler step of the first preemption
    preempt_steps = []
    dry = PagedScheduler(params, cfg, ctx, scfg, key=jax.random.PRNGKey(1))
    orig = PagedScheduler._preempt

    def rec(self, victim):
        preempt_steps.append(self.steps)
        return orig(self, victim)

    dry._preempt = types.MethodType(rec, dry)
    dm = dry.run(trace())
    assert dm["preemptions"] >= 1 and preempt_steps

    # armed run: the OOM lands exactly at the "preempt_spill" fault point
    inj = FaultInjector.from_string(f"oom@{preempt_steps[0]}")
    sched = PagedScheduler(params, cfg, ctx, scfg,
                           key=jax.random.PRNGKey(1), injector=inj)
    m = sched.run(trace())
    assert m["faults"] >= 1                     # the spill aborted once
    assert m["preemptions"] >= 1                # and succeeded on retry
    assert m["requests"] == 4 and m["shed"] == 0
    got = {r.rid: list(r.out) for r in sched.finished}
    assert got == _mono_reference(serve_setup, trace)
    _paged_drained(sched)


def test_fault_mid_cow_fork_no_loss(serve_setup):
    """An injected fault at the CoW fork point — a ring write cursor
    re-entering a prefix-shared page — fires before any bookkeeping
    mutates: the wave requeues its requests, the allocator stays
    consistent, and the replayed run matches the unfaulted tokens."""
    from repro.serving.paged_scheduler import PagedScheduler
    from repro.serving.scheduler import Request, ServeConfig

    params, cfg, ctx = serve_setup

    def trace():
        rng = np.random.default_rng(7)
        stem = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
        # rid 0 registers the prompt; rid 1 adopts it and generates past
        # the window-64 ring, wrapping into the adopted pages
        return [Request(rid=0, tokens=stem.copy(), max_new_tokens=4,
                        arrival=0.0),
                Request(rid=1, tokens=stem.copy(), max_new_tokens=40,
                        arrival=0.0)]

    scfg = ServeConfig(max_slots=4, cache_len=96, prefill_chunk=8,
                       page_size=8, prefix_cache=True)

    # dry run: record which scheduler step reaches the CoW fork
    cow_steps = []
    dry = PagedScheduler(params, cfg, ctx, scfg, key=jax.random.PRNGKey(1))
    dry.pool.ops.fault_hook = lambda where: cow_steps.append(
        (dry.steps, where))
    dry.run(trace())
    hits = [s for s, where in cow_steps if where == "cow_fork"]
    assert hits, "trace never reached a CoW fork — scenario regressed"

    inj = FaultInjector.from_string(f"oom@{hits[0]}")
    sched = PagedScheduler(params, cfg, ctx, scfg,
                           key=jax.random.PRNGKey(1), injector=inj)
    m = sched.run(trace())
    assert m["faults"] == 1 and m["requeues"] >= 1
    assert m["requests"] == 2
    got = {r.rid: list(r.out) for r in sched.finished}
    assert got == _mono_reference(serve_setup, trace)
    _paged_drained(sched)


def test_paged_chaos_run_keeps_all_accepted(serve_setup):
    """Repeated wave faults with prefix cache + preemption enabled: every
    accepted request still finishes with unfaulted-identical tokens and
    the allocator drains clean."""
    from repro.serving.paged_scheduler import PagedScheduler
    from repro.serving.scheduler import Request, ServeConfig

    params, cfg, ctx = serve_setup

    def trace():
        rng = np.random.default_rng(3)
        stem = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        out = []
        for i in range(5):
            tail = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
            out.append(Request(rid=i, tokens=np.concatenate([stem, tail]),
                               max_new_tokens=5, arrival=0.0))
        return out

    scfg = ServeConfig(max_slots=3, cache_len=96, prefill_chunk=8,
                       page_size=8, prefix_cache=True, preemption=True)
    inj = FaultInjector(specs=[FaultSpec(kind="oom", at=4),
                               FaultSpec(kind="oom", at=9)])
    sched = PagedScheduler(params, cfg, ctx, scfg,
                           key=jax.random.PRNGKey(1), injector=inj)
    m = sched.run(trace())
    assert m["faults"] == 2 and m["requests"] == 5
    assert set(r.rid for r in sched.finished) == set(range(5))
    got = {r.rid: list(r.out) for r in sched.finished}
    assert got == _mono_reference(serve_setup, trace)
    _paged_drained(sched)
