"""Single-sort dispatch planner: exactly ONE stable argsort per chunk on the
EP path, with plans equivalent to the old two-sort construction
(make_plan on the device key + make_ragged_plan on the received rows)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch as dsp


def _count_sorts(fn, *args):
    """Number of `sort` primitives anywhere in fn's jaxpr (argsort lowers to
    sort; cumsum/scatter/searchsorted do not)."""
    n = 0

    def walk(jaxpr):
        nonlocal n
        for eq in jaxpr.eqns:
            if eq.primitive.name == "sort":
                n += 1
            for sub in eq.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return n


def _distinct_topk(rng, T, E, K):
    return np.stack([rng.permutation(E)[:K] for _ in range(T)]).astype(np.int32)


def test_planner_is_single_sort():
    """The whole per-chunk planning chain — sender plan AND both receiver
    plans — contains exactly one sort; the old pair contained two."""
    T, E, P, K = 16, 8, 4, 2
    e_local = E // P
    cap_send = T * min(K, e_local)
    idx = jnp.asarray(_distinct_topk(np.random.default_rng(0), T, E, K))
    counts = jnp.ones((P, e_local), jnp.int32)
    eid = jnp.zeros((P * cap_send,), jnp.int32)

    def new_path(idx, counts, eid):
        up = dsp.make_unified_plan(idx, E, P, cap_send=cap_send)
        pr = dsp.recv_ragged_plan(counts, eid, 256, 8)
        pe = dsp.recv_expert_plan(counts, eid, 64)
        return up, pr, pe

    def old_path(idx, eid):
        p1 = dsp.make_plan(idx // e_local, P, cap_send)
        p2 = dsp.make_ragged_plan(eid[:, None], e_local, 256, 8)
        return p1, p2

    assert _count_sorts(new_path, idx, counts, eid) == 1
    assert _count_sorts(old_path, idx, eid) == 2


@pytest.mark.parametrize("seed", range(8))
def test_send_plan_equivalent_to_make_plan(seed):
    rng = np.random.default_rng(seed)
    T = int(rng.integers(1, 33))
    E = int(rng.choice([2, 4, 8]))
    P = int(rng.choice([p for p in (1, 2, 4) if E % p == 0]))
    K = int(rng.integers(1, min(4, E) + 1))
    e_local = E // P
    idx = _distinct_topk(rng, T, E, K)
    cap_send = T * min(K, e_local)

    up = dsp.make_unified_plan(jnp.asarray(idx), E, P, cap_send=cap_send,
                               cap_expert=T)
    old = dsp.make_plan(jnp.asarray(idx) // e_local, P, cap_send)

    # same drops (0 at dropless capacity), same per-peer loads
    assert int(up.drops) == int(old.drops) == 0
    np.testing.assert_array_equal(np.asarray(up.peer_load),
                                  np.asarray(old.load))
    # same grouping: every token-slot lands in its target peer's block
    slots = np.asarray(up.send_slots)
    assert (slots // cap_send == idx // e_local).all()
    # no slot collisions
    flat = slots.reshape(-1)
    assert len(np.unique(flat[flat >= 0])) == (flat >= 0).sum()
    # the expert-layout read-out is IDENTICAL to the old expert-key plan
    # (same sort key, same tie-breaking)
    olde = dsp.make_plan(jnp.asarray(idx), E, T)
    np.testing.assert_array_equal(np.asarray(up.expert_slots),
                                  np.asarray(olde.slots))
    np.testing.assert_array_equal(np.asarray(up.expert_load),
                                  np.asarray(olde.load))
    # counts matrix == per-(peer, local expert) demand
    cnt = np.zeros((P, e_local), np.int64)
    for e in idx.reshape(-1):
        cnt[e // e_local, e % e_local] += 1
    np.testing.assert_array_equal(np.asarray(up.counts), cnt)


@pytest.mark.parametrize("seed", range(8))
def test_recv_plans_equivalent_to_ragged_plan(seed):
    """Receiver-side plans built from the counts matrix (no sort) are
    equivalent to make_plan/make_ragged_plan over the received rows."""
    rng = np.random.default_rng(100 + seed)
    P = int(rng.choice([1, 2, 4]))
    e_local = int(rng.choice([1, 2, 4]))
    cap_send = int(rng.integers(2, 12))
    # received blocks: per-source expert-sorted prefix (the sender invariant)
    recv_eid = np.full((P, cap_send), -1, np.int32)
    counts = np.zeros((P, e_local), np.int32)
    for p in range(P):
        n = int(rng.integers(0, cap_send + 1))
        eids = np.sort(rng.integers(0, e_local, n)).astype(np.int32)
        recv_eid[p, :n] = eids
        for e in eids:
            counts[p, e] += 1
    flat_eid = recv_eid.reshape(-1)
    valid = flat_eid >= 0

    # ragged layout vs make_ragged_plan
    bm = 4
    R = P * cap_send + e_local * bm
    R = -(-R // bm) * bm
    new = dsp.recv_ragged_plan(jnp.asarray(counts), jnp.asarray(flat_eid),
                               R, bm)
    old = dsp.make_ragged_plan(
        jnp.asarray(np.where(valid, flat_eid, e_local)[:, None]), e_local, R,
        bm, valid=jnp.asarray(valid[:, None]))
    np.testing.assert_array_equal(np.asarray(new.load), np.asarray(old.load))
    assert int(new.total_rows) == int(old.total_rows)
    np.testing.assert_array_equal(np.asarray(new.block_to_expert),
                                  np.asarray(old.block_to_expert))
    assert int(new.drops) == int(old.drops) == 0
    s = np.asarray(new.slots).reshape(-1)
    assert ((s >= 0) == valid).all()
    assert len(np.unique(s[s >= 0])) == (s >= 0).sum()
    # every valid row lands inside its expert's aligned span
    starts = np.concatenate([[0], np.cumsum(-(-counts.sum(0) // bm) * bm)])
    for r in np.flatnonzero(valid):
        e = flat_eid[r]
        assert starts[e] <= s[r] < starts[e + 1]

    # per-expert (E_local, cap) layout
    cap = P * cap_send
    pe = dsp.recv_expert_plan(jnp.asarray(counts), jnp.asarray(flat_eid), cap)
    np.testing.assert_array_equal(np.asarray(pe.load), counts.sum(0))
    assert int(pe.drops) == 0
    se = np.asarray(pe.slots).reshape(-1)
    assert ((se >= 0) == valid).all()
    assert (se[valid] // cap == flat_eid[valid]).all()
    assert len(np.unique(se[valid])) == valid.sum()


def test_capacity_drop_counts_match_old_path():
    """Under an undersized capacity the drop COUNTS match the two-sort path
    (which token-slots drop may differ — both clip per group)."""
    rng = np.random.default_rng(7)
    T, E, P, K = 32, 8, 4, 2
    e_local = E // P
    idx = _distinct_topk(rng, T, E, K)
    cap_send = 6
    up = dsp.make_unified_plan(jnp.asarray(idx), E, P, cap_send=cap_send)
    old = dsp.make_plan(jnp.asarray(idx) // e_local, P, cap_send)
    assert int(up.drops) == int(old.drops) > 0
    # counts reflect the post-clip packing, bounded by cap_send per peer
    assert (np.asarray(up.counts).sum(1) <= cap_send).all()
    assert np.asarray(up.counts).sum() == T * K - int(up.drops)


def test_roundtrip_through_unified_plan():
    """scatter -> gather through the unified expert layout reproduces k*x
    with unit weights (identity experts)."""
    rng = np.random.default_rng(3)
    T, E, K = 24, 4, 2
    idx = _distinct_topk(rng, T, E, K)
    x = jnp.asarray(rng.standard_normal((T, 8)), jnp.float32)
    up = dsp.make_unified_plan(jnp.asarray(idx), E, 1, cap_expert=T)
    plan = dsp.DispatchPlan(up.expert_slots, up.expert_load, up.drops_expert)
    buf = dsp.scatter_rows(x, plan, E, T)
    y = dsp.gather_rows(buf, plan, jnp.ones((T, K), jnp.float32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * K, atol=1e-5)
