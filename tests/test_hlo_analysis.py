"""Unit tests for the scan-aware HLO analyzer (launch/hlo_analysis.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _analyse(f, *args):
    txt = jax.jit(f).lower(*args).compile().as_text()
    return H.analyse_module(txt)


def test_flops_single_matmul():
    x = jnp.ones((64, 128))
    w = jnp.ones((128, 32))
    r = _analyse(lambda a, b: a @ b, x, w)
    assert r["flops"] == 2 * 64 * 128 * 32


def test_flops_scan_weighted_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y @ w
    x = jnp.ones((32, 32))
    r = _analyse(f, x, jnp.ones((32, 32)))
    assert r["flops"] == 2 * 32 ** 3 * 8        # 7 in-loop + 1 outside


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=2)
        return y
    r = _analyse(f, jnp.ones((16, 16)), jnp.ones((16, 16)))
    assert r["flops"] == 2 * 16 ** 3 * 6        # 2 x 3 matmuls


def test_shape_bytes():
    assert H.shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert H.shape_bytes("(f32[4,4], s32[2])") == 64 + 8
    assert H.shape_bytes("pred[3]") == 3


def test_collectives_counted_with_trips():
    import os
    import subprocess
    import sys
    import textwrap
    # needs >1 device -> subprocess
    src = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_analysis as H
        from repro.compat import set_mesh
        mesh = jax.make_mesh((4,), ('m',))
        def f(x):
            def body(c, _):
                s = jax.lax.with_sharding_constraint(c.sum(0, keepdims=True),
                                                     NamedSharding(mesh, P()))
                return c + s, None
            y, _ = jax.lax.scan(body, x, None, length=5)
            return y.sum()
        xs = jax.ShapeDtypeStruct((16, 64), jnp.float32,
                                  sharding=NamedSharding(mesh, P('m', None)))
        with set_mesh(mesh):
            txt = jax.jit(f).lower(xs).compile().as_text()
        r = H.analyse_module(txt)
        print('COLL', r['collective_total'])
        assert r['collective_total'] > 0
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=300, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr
    assert "COLL" in out.stdout
