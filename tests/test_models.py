"""Model-substrate unit tests: attention variants, SSD, caches, enc-dec."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import AttentionSpec, SSMSpec
from repro.core.moe import DistContext
from repro.models import ssm as ssm_mod
from repro.models import transformer
from repro.models.attention import attention, decode_attention, repeat_kv

CTX = DistContext()


def _qkv(S=64, B=2, H=4, KH=2, hd=8, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KH, hd))
    v = jax.random.normal(ks[2], (B, S, KH, hd))
    return q, k, v


def _naive(q, k, v, causal=True, window=0, chunk=0):
    B, S, H, hd = q.shape
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) / np.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    if chunk:
        m &= (kpos // chunk) == (qpos // chunk)
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def test_full_causal_matches_naive():
    q, k, v = _qkv()
    out = attention(q, k, v, AttentionSpec(kind="full"), block_q=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_naive(q, k, v)),
                               atol=1e-5)


@pytest.mark.parametrize("window", [8, 16, 48])
def test_window_matches_naive(window):
    q, k, v = _qkv()
    out = attention(q, k, v, AttentionSpec(kind="window", window=window),
                    block_q=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, window=window)), atol=1e-5)


def test_chunked_matches_naive():
    q, k, v = _qkv()
    out = attention(q, k, v, AttentionSpec(kind="chunked", window=16),
                    block_q=8)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, chunk=16)), atol=1e-5)


def test_non_causal_cross():
    q, k, v = _qkv()
    out = attention(q, k, v, AttentionSpec(kind="full"), causal=False)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_naive(q, k, v, causal=False)), atol=1e-5)


def test_block_size_invariance():
    q, k, v = _qkv()
    a = attention(q, k, v, AttentionSpec(kind="full"), block_q=8)
    b = attention(q, k, v, AttentionSpec(kind="full"), block_q=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_decode_attention_matches_last_row():
    q, k, v = _qkv(S=32)
    full = attention(q, k, v, AttentionSpec(kind="full"), block_q=8)
    dec = decode_attention(q[:, -1:], k, v,
                           jnp.full((2,), 32, jnp.int32),
                           AttentionSpec(kind="full"))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

SPEC = SSMSpec(state_dim=16, head_dim=8, expand=2, conv_width=4, chunk=8)


def test_ssd_chunk_invariance():
    params = ssm_mod.init_ssm(jax.random.PRNGKey(0), 32, SPEC)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    y1 = ssm_mod.apply_ssm(params, x, SPEC)
    y2 = ssm_mod.apply_ssm(params, x, dataclasses.replace(SPEC, chunk=16))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_ssd_decode_consistency():
    params = ssm_mod.init_ssm(jax.random.PRNGKey(0), 32, SPEC)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 33, 32)) * 0.5
    y_full, st_full = ssm_mod.apply_ssm(params, x, SPEC, return_state=True)
    _, st = ssm_mod.apply_ssm(params, x[:, :-1], SPEC, return_state=True)
    y_dec, st2 = ssm_mod.decode_ssm(params, x[:, -1:], st, SPEC)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2.ssm), np.asarray(st_full.ssm),
                               atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """SSD chunked algorithm == step-by-step recurrence."""
    params = ssm_mod.init_ssm(jax.random.PRNGKey(0), 16, SPEC)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 16)) * 0.5
    y_ssd = ssm_mod.apply_ssm(params, x, SPEC)
    state = ssm_mod.init_state(1, 16, SPEC, x.dtype)
    ys = []
    for t in range(12):
        yt, state = ssm_mod.decode_ssm(params, x[:, t:t + 1], state, SPEC)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ssd), np.asarray(y_rec), atol=1e-4)


# ---------------------------------------------------------------------------
# full-model decode == forward (incl. period-scan path), all families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x7b",
                                  "jamba-1.5-large-398b", "mamba2-130m",
                                  "gemma3-27b", "whisper-small",
                                  "internvl2-76b"])
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(registry()[arch].reduced(), num_layers=4)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    enc_out = None
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, cfg.encoder_seq, cfg.d_model))
        enc_out = transformer.encode(params, cfg, batch["frames"], CTX)
    if cfg.num_patch_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.num_patch_tokens, cfg.d_model))
    full, _ = transformer.forward(params, cfg, CTX, batch)
    cache = transformer.init_cache(params, cfg, B, S + cfg.num_patch_tokens,
                                   jnp.float32, enc_out=enc_out)
    step = jax.jit(lambda c, t: transformer.decode_step(params, cfg, CTX, c, t))
    if cfg.num_patch_tokens:
        pytest.skip("patch positions enter via embeddings; decode tested via "
                    "token tail elsewhere")
    logits = None
    for i in range(S):
        logits, cache = step(cache, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_remat_policies_same_loss():
    from repro.training.step import loss_fn
    cfg = registry()["mixtral-8x7b"].reduced()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                     cfg.vocab_size),
    }
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    losses = []
    for policy in ("none", "full", "memfine"):
        c = dataclasses.replace(cfg, remat_policy=policy)
        losses.append(float(loss_fn(params, c, CTX, batch)[0]))
    assert max(losses) - min(losses) < 1e-5


def test_prefix_layers_decode_matches_forward():
    """ModelConfig.prefix (unrolled leading layers + scanned body, the
    DeepSeek-mini layout) is consistent between forward and decode."""
    base = registry()["deepseek-mini-8l"]
    cfg = dataclasses.replace(
        base.reduced(), prefix=base.reduced().pattern[:1], num_layers=5)
    assert cfg.num_periods == 2 and len(cfg.prefix) == 1
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = transformer.forward(params, cfg, CTX, {"tokens": toks})
    cache = transformer.init_cache(params, cfg, B, S, jnp.float32)
    logits = None
    for i in range(S):
        logits, cache = transformer.decode_step(params, cfg, CTX, cache,
                                                toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
