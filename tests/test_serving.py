"""Serving-stack tests: single-pass prefill parity, chunked prefill,
compiled-step caching, continuous-batching scheduler invariants, and the
serving memory model (docs/DESIGN.md §Serving)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import (AttentionSpec, HardwareProfile, LayerSpec,
                                ModelConfig)
from repro.core import memory_model as mm
from repro.core.chunking import chunk_spans
from repro.core.moe import DistContext
from repro.models import blocks, transformer
from repro.serving import engine
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ServeConfig)

CTX = DistContext()

PREFILL_ARCHS = [
    ("llama3.2-3b", 24),            # full attention, linear cache
    ("mixtral-8x7b", 24),           # windowed attention + MoE
    ("mixtral-8x7b", 96),           # ring wrap: prompt > window (64)
    ("gemma3-27b", 96),             # window + full mix, ring wrap
    ("mamba2-130m", 24),            # SSM state + conv tail
    ("jamba-1.5-large-398b", 24),   # hybrid mamba/attention
    ("whisper-small", 24),          # enc-dec: cross-attention caches
]


def _setup(arch, S, seed=0, B=2, layers=None):
    cfg = registry()[arch].reduced()
    if layers:
        cfg = dataclasses.replace(cfg, num_layers=layers)
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (B, cfg.encoder_seq, cfg.d_model))
    return cfg, params, batch


# ---------------------------------------------------------------------------
# cache layout: bit-identical to the replay writes (unit level)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,window,S", [
    ("full", 0, 24), ("window", 16, 12), ("window", 16, 40),
    ("chunked", 16, 40)])
def test_build_attn_cache_matches_replay_writes(kind, window, S):
    """Given the same K/V, the single-pass layout equals the decode path's
    token-by-token ring/linear writes bit for bit — wraps included."""
    spec = LayerSpec(attn=AttentionSpec(kind=kind, window=window))
    cache_len = max(S, 48)
    Sc = blocks.cache_len(spec, cache_len)
    B, KH, hd = 2, 2, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, KH, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, hd))
    ref = {"k": jnp.zeros((B, Sc, KH, hd)), "v": jnp.zeros((B, Sc, KH, hd))}
    ring = kind in ("window", "chunked") and window and Sc == window
    for pos in range(S):
        w = pos % Sc if ring else pos
        ref = {"k": jax.lax.dynamic_update_slice_in_dim(
                    ref["k"], k[:, pos:pos + 1], w, axis=1),
               "v": jax.lax.dynamic_update_slice_in_dim(
                    ref["v"], v[:, pos:pos + 1], w, axis=1)}
    got = blocks.build_attn_cache(k, v, spec, cache_len, jnp.float32)
    np.testing.assert_array_equal(np.asarray(got["k"]), np.asarray(ref["k"]))
    np.testing.assert_array_equal(np.asarray(got["v"]), np.asarray(ref["v"]))


@pytest.mark.parametrize("chunk", [4, 8])
def test_write_attn_cache_matches_replay_writes(chunk):
    """Chunked extension writes land exactly where decode writes land."""
    spec = LayerSpec(attn=AttentionSpec(kind="window", window=16))
    B, KH, hd, S = 1, 2, 4, 40
    k = jax.random.normal(jax.random.PRNGKey(0), (B, S, KH, hd))
    v = jax.random.normal(jax.random.PRNGKey(1), (B, S, KH, hd))
    ref = blocks.build_attn_cache(k, v, spec, S, jnp.float32)
    got = {"k": jnp.zeros_like(ref["k"]), "v": jnp.zeros_like(ref["v"])}
    for start, stop in chunk_spans(S, chunk):
        got = blocks.write_attn_cache(got, k[:, start:stop], v[:, start:stop],
                                      start, spec)
    np.testing.assert_array_equal(np.asarray(got["k"]), np.asarray(ref["k"]))
    np.testing.assert_array_equal(np.asarray(got["v"]), np.asarray(ref["v"]))


def test_slot_positions_ring_and_linear():
    win = LayerSpec(attn=AttentionSpec(kind="window", window=4))
    full = LayerSpec(attn=AttentionSpec(kind="full"))
    np.testing.assert_array_equal(
        np.asarray(blocks.slot_positions(win, 4, 6)), [4, 5, 2, 3])
    np.testing.assert_array_equal(
        np.asarray(blocks.slot_positions(win, 4, 0)), [-1, -1, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(blocks.slot_positions(full, 4, 2)), [0, 1, -1, -1])


# ---------------------------------------------------------------------------
# single-pass prefill vs replay (full model)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,S", PREFILL_ARCHS,
                         ids=[f"{a}-S{s}" for a, s in PREFILL_ARCHS])
def test_prefill_matches_replay(arch, S):
    """One forward pass produces the replay's cache: same structure, same
    pos, bit-identical leaves wherever the layer inputs are bit-identical
    (period 0 = layer stack entry 0), and <= 1e-5 everywhere else (deeper
    layers' inputs differ only by replay's decode-attention vs forward's
    blocked-attention rounding of the residual stream)."""
    cfg, params, batch = _setup(arch, S)
    cache_len = S + 8
    lr, cr = engine.prefill_replay(params, cfg, CTX, batch, cache_len)
    lp, cp = engine.prefill(params, cfg, CTX, batch, cache_len)
    assert jax.tree.structure(cr) == jax.tree.structure(cp)
    assert int(cp["pos"]) == int(cr["pos"]) == S
    for a, b in zip(jax.tree.leaves(cr), jax.tree.leaves(cp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


def test_prefill_first_layer_bit_identical():
    """Layer 0 sees bit-identical inputs on both paths, so its K/V cache —
    ring layout included — must match the replay bit for bit."""
    cfg, params, batch = _setup("mixtral-8x7b", 96)   # window 64: wraps
    _, cr = engine.prefill_replay(params, cfg, CTX, batch, 104)
    _, cp = engine.prefill(params, cfg, CTX, batch, 104)
    # reduced mixtral unrolls both layers into "rem"; index 0 = layer 0
    for name in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(cr["rem"][0]["attn"][name]),
            np.asarray(cp["rem"][0]["attn"][name]))


def test_prefill_logits_match_forward():
    cfg, params, batch = _setup("mixtral-8x7b", 32)
    logits, _ = transformer.forward(params, cfg, CTX, batch)
    lp, _ = engine.prefill(params, cfg, CTX, batch, 40)
    np.testing.assert_allclose(np.asarray(lp[:, 0]), np.asarray(logits[:, -1]),
                               atol=1e-5)


def test_prefill_prefix_layers():
    """ModelConfig.prefix (unrolled leading layers + scanned body) caches
    consistently on the single-pass path."""
    base = registry()["deepseek-mini-8l"]
    cfg = dataclasses.replace(
        base.reduced(), prefix=base.reduced().pattern[:1], num_layers=5)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    lr, cr = engine.prefill_replay(params, cfg, CTX, {"tokens": toks}, 24)
    lp, cp = engine.prefill(params, cfg, CTX, {"tokens": toks}, 24)
    assert jax.tree.structure(cr) == jax.tree.structure(cp)
    for a, b in zip(jax.tree.leaves(cr), jax.tree.leaves(cp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


def test_prefill_rejects_overlong_prompt():
    cfg, params, batch = _setup("llama3.2-3b", 24)
    with pytest.raises(ValueError, match="exceeds"):
        engine.prefill(params, cfg, CTX, batch, 16)


# ---------------------------------------------------------------------------
# chunked prefill (extend_step)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,S,chunk", [
    ("mixtral-8x7b", 96, 16),       # ring wraps mid-extension
    ("gemma3-27b", 48, 8),
    ("jamba-1.5-large-398b", 48, 16)])
def test_chunked_prefill_matches_single_pass(arch, S, chunk):
    cfg, params, batch = _setup(arch, S)
    cache_len = S + 8
    lf, cf = engine.prefill(params, cfg, CTX, batch, cache_len)
    lc, cc = engine.prefill_chunked(params, cfg, CTX, batch["tokens"],
                                    cache_len, chunk)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lc), atol=2e-4)
    for a, b in zip(jax.tree.leaves(cf), jax.tree.leaves(cc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
    # and decode continues identically from either cache
    nxt = jnp.full((2, 1), 7, jnp.int32)
    l1, _ = transformer.decode_step(params, cfg, CTX, cf, nxt)
    l2, _ = transformer.decode_step(params, cfg, CTX, cc, nxt)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)


def test_chunked_prefill_rejects_overlong_prompt():
    """Chunk write positions are traced, so the extend path cannot detect a
    linear-cache overflow itself — the host-side guard must."""
    cfg, params, batch = _setup("llama3.2-3b", 24)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        engine.prefill_chunked(params, cfg, CTX, batch["tokens"], 16, 8)


def test_chunk_spans():
    assert chunk_spans(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert chunk_spans(8, 8) == [(0, 8)]
    with pytest.raises(ValueError):
        chunk_spans(8, 0)


# ---------------------------------------------------------------------------
# compiled-step caching + generate regression
# ---------------------------------------------------------------------------

def test_generate_temperature_without_key():
    """Regression: temperature > 0 with key=None crashed on
    jax.random.split(None); now defaults to a seeded key."""
    cfg, params, batch = _setup("mamba2-130m", 8)
    out = engine.generate(params, cfg, CTX, batch, steps=4, cache_len=16,
                          temperature=0.8)
    assert out.shape == (2, 4)
    out2 = engine.generate(params, cfg, CTX, batch, steps=4, cache_len=16,
                           temperature=0.8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_compiled_steps_cached_across_calls():
    """prefill/generate must reuse one compiled step per (cfg, ctx) instead
    of re-wrapping jax.jit per invocation."""
    cfg, params, batch = _setup("llama3.2-3b", 8)
    engine.clear_step_cache()
    assert engine.get_decode_step(cfg, CTX) is engine.get_decode_step(cfg, CTX)
    engine.generate(params, cfg, CTX, batch, steps=2, cache_len=16)
    n = engine.step_cache_info()["entries"]
    engine.generate(params, cfg, CTX, batch, steps=2, cache_len=16)
    engine.prefill(params, cfg, CTX, batch, 16)
    assert engine.step_cache_info()["entries"] == n


# ---------------------------------------------------------------------------
# serving memory model
# ---------------------------------------------------------------------------

def test_decode_cache_bytes_window_bounded():
    cfg = registry()["mixtral-8x7b"]                 # every layer window 4096
    assert (mm.decode_cache_bytes(cfg, 32_768)
            == mm.decode_cache_bytes(cfg, 4096))
    assert (mm.decode_cache_bytes(cfg, 2048)
            < mm.decode_cache_bytes(cfg, 4096))
    full = registry()["llama3.2-3b"]                 # full attention: linear
    assert mm.decode_cache_bytes(full, 32_768) > mm.decode_cache_bytes(full, 4096)


def test_serving_peak_monotone_and_fits():
    cfg = registry()["mixtral-8x7b"].reduced()
    kw = dict(cache_len=128, decode_tokens=4, prefill_tokens=32)
    b1 = mm.serving_peak_bytes(cfg, requests=1, **kw)
    b2 = mm.serving_peak_bytes(cfg, requests=2, **kw)
    assert b2 > b1 > mm.serve_weight_bytes(cfg)
    hw = HardwareProfile("t", hbm_bytes=(b1 + b2) / 2, peak_flops=1,
                         hbm_bw=1, ici_bw=1, alpha=1.0)
    assert mm.serving_fits(cfg, hw, requests=1, **kw)
    assert not mm.serving_fits(cfg, hw, requests=2, **kw)


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------

def _mini_serving(max_slots=2, n_requests=5, hw=None, seed=0,
                  prefill_chunk=8):
    cfg = registry()["mixtral-8x7b"].reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 8)))
            for i in range(n_requests)]
    kw = {} if hw is None else {"hw": hw}
    scfg = ServeConfig(max_slots=max_slots, cache_len=32,
                       prefill_chunk=prefill_chunk, **kw)
    sched = ContinuousBatchingScheduler(params, cfg, CTX, scfg)
    return sched, reqs


def test_scheduler_join_evict_invariants():
    sched, reqs = _mini_serving(max_slots=2, n_requests=5)
    m = sched.run(reqs)
    assert m["requests"] == 5
    assert sched.max_occupancy <= 2
    assert sched.admission_order == [0, 1, 2, 3, 4]       # FIFO
    for r in sched.finished:
        assert r.state == "finished"
        assert len(r.out) == r.max_new_tokens
        assert r.t_done is not None and r.t_done >= r.arrival
    assert not sched.active and not sched.queue
    assert sorted(sched.free_slots) == [0, 1]             # all slots released
    assert m["modeled_peak_bytes"] <= m["budget_bytes"]


def test_scheduler_admission_refusal_under_budget():
    """A budget that fits one resident request but not two must cap
    occupancy at 1 — requests queue and drain as slots free."""
    cfg = registry()["mixtral-8x7b"].reduced()
    kw = dict(cache_len=32, decode_tokens=2, prefill_tokens=8, dtype_bytes=2)
    b1 = mm.serving_peak_bytes(cfg, requests=1, **kw)
    b2 = mm.serving_peak_bytes(cfg, requests=2, **kw)
    hw = HardwareProfile("t", hbm_bytes=(b1 + b2) / 2, peak_flops=1,
                         hbm_bw=1, ici_bw=1, alpha=1.0)
    sched, reqs = _mini_serving(max_slots=2, n_requests=4, hw=hw)
    m = sched.run(reqs)
    assert m["requests"] == 4                              # all still served
    assert sched.max_occupancy == 1                        # admission capped
    assert m["modeled_peak_bytes"] <= m["budget_bytes"]


def test_scheduler_rejects_never_admissible_request():
    cfg = registry()["mixtral-8x7b"].reduced()
    tiny = HardwareProfile("t", hbm_bytes=1e3, peak_flops=1, hbm_bw=1,
                           ici_bw=1, alpha=1.0)
    sched, reqs = _mini_serving(hw=tiny)
    with pytest.raises(ValueError, match="never be admitted"):
        sched.submit(reqs[0])
    with pytest.raises(ValueError, match="exceeds cache_len"):
        sched.submit(Request(rid=9, tokens=np.zeros(30, np.int32),
                             max_new_tokens=10))


def test_scheduler_greedy_matches_generate():
    """Every request through the slot map — joins mid-flight, slot reuse
    after eviction included — reproduces its solo engine.generate output
    token for token."""
    sched, reqs = _mini_serving(max_slots=2, n_requests=4, prefill_chunk=16)
    sched.run(reqs)
    for req in reqs:
        out = engine.generate(sched.params, sched.cfg, CTX,
                              {"tokens": jnp.asarray(req.tokens)[None]},
                              steps=req.max_new_tokens, cache_len=32)
        assert req.out == out[0].tolist()


def test_scheduler_chunked_prefill_interleaves():
    """Prompts longer than one chunk take multiple scheduler steps and
    still serve correctly."""
    sched, _ = _mini_serving(prefill_chunk=4)
    req = Request(rid=0, tokens=np.arange(16, dtype=np.int32) % 100,
                  max_new_tokens=3)
    m = sched.run([req])
    assert m["prefill_chunks"] == 4                        # 16 tokens / 4
    assert len(req.out) == 3


def test_scheduler_peak_counts_same_step_finishers():
    """Occupancy is measured at admission, so a request that installs and
    finishes within one step still registers in the reported peak."""
    sched, _ = _mini_serving(prefill_chunk=16)
    req = Request(rid=0, tokens=np.zeros(8, np.int32), max_new_tokens=1)
    sched.run([req])
    assert sched.max_occupancy == 1
    assert sched.modeled_peak >= sched.modeled_bytes(requests=1)


def test_scheduler_rejects_encoder_archs():
    cfg = registry()["whisper-small"].reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="encoder"):
        ContinuousBatchingScheduler(params, cfg, CTX, ServeConfig())
