"""CLI launcher smoke tests (subprocess, real argv paths)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=600):
    out = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, timeout=timeout, env=ENV, cwd=REPO)
    assert out.returncode == 0, f"{args}\n{out.stdout}\n{out.stderr}"
    return out.stdout


def test_train_cli_smoke():
    out = _run(["repro.launch.train", "--arch", "llama3.2-3b", "--smoke",
                "--steps", "3", "--seq-len", "32", "--global-batch", "2",
                "--no-mact"])
    assert "final loss" in out


def test_train_cli_with_mact_and_chunks():
    out = _run(["repro.launch.train", "--arch", "mixtral-8x7b", "--smoke",
                "--steps", "2", "--seq-len", "32", "--global-batch", "2",
                "--chunks", "2", "--no-mact", "--remat", "full"])
    assert "final loss" in out


def test_train_cli_adaptive_mact():
    out = _run(["repro.launch.train", "--arch", "mixtral-8x7b", "--smoke",
                "--steps", "3", "--seq-len", "32", "--global-batch", "2",
                "--adaptive-mact", "--replan-interval", "2",
                "--mact-hysteresis", "0.1"])
    assert "final loss" in out
    assert "adaptive layer schedules" in out


def test_serve_cli_smoke():
    out = _run(["repro.launch.serve", "--arch", "gemma3-27b", "--smoke",
                "--requests", "3", "--arrival-rate", "8",
                "--prompt-lens", "8,16", "--gen", "2,4",
                "--prefill-chunk", "8"])
    assert "tok/s" in out
    assert "modeled peak" in out


def test_dryrun_cli_tiny():
    out = _run(["repro.launch.dryrun", "--arch", "mamba2-130m",
                "--shape", "long_500k", "--out", "/tmp/dryrun_test"],
               timeout=900)
    assert "[ok]" in out
