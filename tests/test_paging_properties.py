"""Stateful property tests for the paged serving memory path.

The paging bookkeeping (serving/paging.py) is pure host-side Python, so it
gets the strongest harness in the repo: seeded random walks over the full
operation alphabet — alloc / free / CoW fork / prefix adopt / trie
register / preempt-spill / restore — cross-checked after EVERY operation
against an independent reference model (refcounts recomputed from scratch
by walking request tables and trie pins) plus the allocator's own audit
(allocated + free == total, no double free, no ref < 1, reserved pages
never handed out).

The driver is hand-rolled rather than hypothesis-based so the walks run
everywhere (conftest.py skips @given tests when hypothesis is absent);
failures shrink by greedy op-deletion and report the minimal sequence.
"""

import collections
import random

import pytest

from repro.serving.paging import (RESERVED_PAGES, STATE_SPACE,
                                  AllocatorCorruption, Group, PageAllocator,
                                  PagesExhausted, PageTableOps, PrefixTrie,
                                  prefix_align, space_key)

LIN = Group(length=16, ring=False)      # 4 blocks @ page 4
RING = Group(length=8, ring=True)       # 2 blocks @ page 4
PAGE = 4


# ---------------------------------------------------------------------------
# driver: applies concrete ops, checks invariants after every one
# ---------------------------------------------------------------------------

class Driver:
    """Holds the system under test plus everything needed to recompute its
    expected refcounts from first principles."""

    def __init__(self, groups=(LIN, RING), kv_pages=(10, 6), state_blocks=5,
                 trie=False, align=None):
        self.groups = list(groups)
        self.alloc = PageAllocator()
        for g, n in zip(self.groups, kv_pages):
            self.alloc.add_space(space_key(g), n, page_bytes=float(PAGE))
        self.alloc.add_space(STATE_SPACE, state_blocks, page_bytes=1.0)
        self.ops = PageTableOps(self.alloc, self.groups, PAGE,
                                state_bytes=1.0)
        self.trie = (PrefixTrie(self.ops, align or PAGE, max_nodes=6)
                     if trie else None)
        self.requests = {}              # rid -> RequestPages
        self.prompts = {}               # rid -> tuple of token ids
        self.spills = {}                # rid -> {"mask": ..., "state": bool}

    # -- independent reference model ----------------------------------------

    def expected_refs(self):
        exp = collections.Counter()
        for rp in self.requests.values():
            for g in self.groups:
                for p in rp.tables[g]:
                    if p is not None:
                        exp[(space_key(g), p)] += 1
            if rp.state_block is not None:
                exp[(STATE_SPACE, rp.state_block)] += 1
        if self.trie is not None:
            def walk(level):
                for node in level.values():
                    for g, pages in node.pages.items():
                        for p in pages:
                            exp[(space_key(g), p)] += 1
                    walk(node.children)
            walk(self.trie.root)
        return exp

    def check(self):
        self.alloc.audit()
        exp = self.expected_refs()
        for key, sp in self.alloc.spaces.items():
            want = {p: c for (k, p), c in exp.items() if k == key}
            assert dict(sp.ref) == want, (
                f"space {key}: allocator refs {dict(sp.ref)} != "
                f"ownership count {want}")
            for p in sp.ref:
                assert p >= RESERVED_PAGES
        for rp in self.requests.values():
            for g in self.groups:
                for b in rp.shared[g]:
                    page = rp.tables[g][b]
                    assert page is not None
                    assert self.alloc.refcount(space_key(g), page) >= 1
        # private_bytes mirrors exclusively-owned pages exactly
        for rp in self.requests.values():
            want = 0.0
            for g in self.groups:
                pb = self.alloc.spaces[space_key(g)].page_bytes
                want += sum(pb for b, p in enumerate(rp.tables[g])
                            if p is not None and b not in rp.shared[g])
            if rp.state_block is not None:
                want += 1.0
            assert rp.private_bytes == want, (
                f"private_bytes {rp.private_bytes} != owned {want}")

    # -- op application (unknown rids / full spaces are benign no-ops) -------

    def apply(self, op):
        name, args = op[0], op[1:]
        try:
            getattr(self, "op_" + name)(*args)
        except PagesExhausted:
            pass                         # exhaustion must leave it consistent

    def op_new(self, rid):
        if rid not in self.requests and rid not in self.spills:
            self.requests[rid] = self.ops.new_request()

    def op_state(self, rid):
        if rid in self.requests:
            self.ops.alloc_state(self.requests[rid])

    def op_block(self, rid, gi, b):
        if rid in self.requests:
            g = self.groups[gi]
            if b < g.blocks(PAGE):
                self.ops.ensure_block(self.requests[rid], g, b)

    def op_cow(self, rid, gi, b):
        if rid in self.requests:
            g = self.groups[gi]
            if b < g.blocks(PAGE):
                self.ops.ensure_writable(self.requests[rid], g, b)

    def op_fork(self, dst, src):
        """CoW fork: a fresh request adopts every mapped block of ``src``
        (what a prefix hit does, without the trie)."""
        if src not in self.requests or dst in self.requests \
                or dst in self.spills:
            return
        rp = self.ops.new_request()
        self.requests[dst] = rp
        for g in self.groups:
            for b, p in enumerate(self.requests[src].tables[g]):
                if p is not None:
                    self.ops.adopt_shared(rp, g, b, p)

    def op_release(self, rid):
        if rid in self.requests:
            self.ops.release(self.requests.pop(rid))

    def op_spill(self, rid):
        if rid in self.requests:
            rp = self.requests.pop(rid)
            self.spills[rid] = {
                "mask": {g: [p is not None for p in rp.tables[g]]
                         for g in self.groups},
                "state": rp.state_block is not None}
            self.ops.release(rp)

    def op_restore(self, rid):
        if rid not in self.spills:
            return
        saved = self.spills[rid]
        rp = self.ops.new_request()
        try:
            for g in self.groups:
                for b, had in enumerate(saved["mask"][g]):
                    if had:
                        self.ops.ensure_block(rp, g, b)
            if saved["state"]:
                self.ops.alloc_state(rp)
        except PagesExhausted:
            self.ops.release(rp)         # failed restore frees the partial rp
            raise
        del self.spills[rid]
        self.requests[rid] = rp

    def teardown(self):
        """Release everything; the allocator must drain to fully free."""
        for rid in list(self.requests):
            self.op_release(rid)
            self.check()
        if self.trie is not None:
            self.trie.clear()
            self.check()
        for key, sp in self.alloc.spaces.items():
            assert not sp.ref, f"space {key} leaked {dict(sp.ref)}"
            assert len(sp.free) == sp.total


# ---------------------------------------------------------------------------
# walk generation + greedy-deletion shrinking
# ---------------------------------------------------------------------------

OP_WEIGHTS = [("new", 4), ("state", 2), ("block", 8), ("cow", 5),
              ("fork", 3), ("release", 3), ("spill", 2), ("restore", 2)]


def _gen_ops(seed, n_ops):
    rng = random.Random(seed)
    names = [n for n, w in OP_WEIGHTS for _ in range(w)]
    ops, next_rid = [], 0
    for _ in range(n_ops):
        name = rng.choice(names)
        if name == "new":
            ops.append(("new", next_rid))
            next_rid += 1
        elif name == "fork":
            ops.append(("fork", next_rid, rng.randrange(max(1, next_rid))))
            next_rid += 1
        elif name in ("block", "cow"):
            ops.append((name, rng.randrange(max(1, next_rid)),
                        rng.randrange(2), rng.randrange(4)))
        else:
            ops.append((name, rng.randrange(max(1, next_rid))))
    return ops


def _replay(ops_seq, **driver_kw):
    d = Driver(**driver_kw)
    for op in ops_seq:
        d.apply(op)
        d.check()
    d.teardown()


def _shrink(ops_seq, **driver_kw):
    """Greedy delete-one-op minimisation of a failing sequence."""
    def fails(seq):
        try:
            _replay(seq, **driver_kw)
            return False
        except (AssertionError, AllocatorCorruption):
            return True

    seq = list(ops_seq)
    changed = True
    while changed:
        changed = False
        for i in range(len(seq)):
            cand = seq[:i] + seq[i + 1:]
            if fails(cand):
                seq = cand
                changed = True
                break
    return seq


@pytest.mark.parametrize("seed", range(8))
def test_allocator_random_walk(seed):
    """No sequence of alloc/free/fork/CoW/spill/restore double-frees, leaks,
    or desyncs a refcount — and everything drains to zero at teardown."""
    ops_seq = _gen_ops(seed, n_ops=150)
    try:
        _replay(ops_seq)
    except (AssertionError, AllocatorCorruption) as exc:
        minimal = _shrink(ops_seq)
        pytest.fail(f"invariant violated: {exc}\nminimal sequence "
                    f"({len(minimal)} ops): {minimal}")


# ---------------------------------------------------------------------------
# trie-inclusive walk: register / adopt / evict interleaved with lifecycle
# ---------------------------------------------------------------------------

class TrieDriver(Driver):
    """Adds prefix-trie traffic on one linear group: admissions share
    prompt prefixes, register aligned blocks with fake snapshots, and later
    admissions adopt them."""

    ALIGN = 8                           # 2 pages per node

    def __init__(self):
        super().__init__(groups=(Group(length=32, ring=False),),
                         kv_pages=(40,), state_blocks=10, trie=True,
                         align=self.ALIGN)
        self.g = self.groups[0]

    def op_admit(self, rid, prompt):
        if rid in self.requests or rid in self.spills:
            return
        prompt = tuple(prompt)
        matched, nodes = self.trie.lookup(prompt)
        while matched >= len(prompt):
            nodes.pop()
            matched -= self.ALIGN
        rp = self.ops.new_request()
        self.requests[rid] = rp
        self.prompts[rid] = prompt
        self.trie.adopt(rp, nodes)
        for b in range((len(prompt) + PAGE - 1) // PAGE):
            self.ops.ensure_block(rp, self.g, b)
        self.ops.alloc_state(rp)
        upto = len(prompt) // self.ALIGN * self.ALIGN
        snaps = {end: f"snap@{end}" for end in
                 range(self.ALIGN, upto + 1, self.ALIGN)}
        self.trie.register(prompt, upto, rp, snaps)

    def op_evict(self):
        self.trie.evict_lru_leaf()

    def op_cow_any(self, rid, b):
        if rid in self.requests and b < self.g.blocks(PAGE):
            self.ops.ensure_writable(self.requests[rid], self.g, b)

    def check(self):
        super().check()
        # node count bookkeeping matches the walked structure, and every
        # pinned page is genuinely allocated
        n = 0
        stack = [self.trie.root]
        while stack:
            level = stack.pop()
            for node in level.values():
                n += 1
                for g, pages in node.pages.items():
                    for p in pages:
                        assert self.alloc.refcount(space_key(g), p) >= 1
                stack.append(node.children)
        assert n == self.trie.n_nodes
        assert n <= self.trie.max_nodes


def _gen_trie_ops(seed, n_ops):
    rng = random.Random(seed)
    # prompts drawn from 3 shared stems so lookups actually hit
    stems = [tuple(rng.randrange(50) for _ in range(24)) for _ in range(3)]
    ops, next_rid = [], 0
    names = (["admit"] * 6 + ["cow_any"] * 4 + ["release"] * 3 +
             ["spill"] * 2 + ["restore"] * 2 + ["evict"] * 2)
    for _ in range(n_ops):
        name = rng.choice(names)
        if name == "admit":
            stem = rng.choice(stems)
            length = rng.choice([8, 12, 16, 20, 24])
            prompt = stem[:length - 4] + tuple(
                rng.randrange(50) for _ in range(4))
            ops.append(("admit", next_rid, prompt))
            next_rid += 1
        elif name == "cow_any":
            ops.append(("cow_any", rng.randrange(max(1, next_rid)),
                        rng.randrange(8)))
        elif name == "evict":
            ops.append(("evict",))
        else:
            ops.append((name, rng.randrange(max(1, next_rid))))
    return ops


@pytest.mark.parametrize("seed", range(6))
def test_trie_random_walk(seed):
    """Prefix registration/adoption/eviction interleaved with CoW and
    preemption keeps trie pins and request refs exactly consistent."""
    ops_seq = _gen_trie_ops(seed, n_ops=120)
    d = TrieDriver()
    try:
        for op in ops_seq:
            d.apply(op)
            d.check()
        d.teardown()
    except (AssertionError, AllocatorCorruption) as exc:
        pytest.fail(f"trie walk (seed {seed}) violated an invariant: {exc}")


# ---------------------------------------------------------------------------
# deterministic edge cases the walks would only hit by luck
# ---------------------------------------------------------------------------

def test_allocator_misuse_is_corruption():
    a = PageAllocator()
    a.add_space("s", 2)
    p = a.alloc("s")
    assert p >= RESERVED_PAGES
    a.decref("s", p)
    with pytest.raises(AllocatorCorruption, match="double free"):
        a.decref("s", p)
    with pytest.raises(AllocatorCorruption, match="incref of unallocated"):
        a.incref("s", p)
    with pytest.raises(ValueError, match="already exists"):
        a.add_space("s", 2)
    a.audit()


def test_allocator_exhaustion_and_hwm():
    a = PageAllocator()
    a.add_space("s", 3, page_bytes=10.0)
    pages = [a.alloc("s") for _ in range(3)]
    with pytest.raises(PagesExhausted):
        a.alloc("s")
    a.audit()
    assert a.allocated_bytes() == 30.0
    a.decref("s", pages[0])
    assert a.allocated_bytes() == 20.0
    assert a.hwm_bytes() == 30.0          # watermark survives the free
    q = a.alloc("s")
    assert q == pages[0]                  # LIFO reuse of the freed page
    a.audit()


def test_cow_refcounts_hit_zero_exactly_at_release():
    """A page shared R ways frees exactly when the R-th owner lets go —
    no sooner (CoW forks decref but can't free a shared page) and no later
    (release drops the last ref)."""
    d = Driver()
    d.apply(("new", 0))
    d.apply(("block", 0, 0, 0))
    page = d.requests[0].tables[LIN][0]
    for rid in (1, 2):
        d.apply(("fork", rid, 0))
    assert d.alloc.refcount(space_key(LIN), page) == 3
    d.apply(("cow", 1, 0, 0))             # fork 1 copies away
    assert d.alloc.refcount(space_key(LIN), page) == 2
    d.apply(("release", 0))
    assert d.alloc.refcount(space_key(LIN), page) == 1
    d.apply(("release", 2))
    assert d.alloc.refcount(space_key(LIN), page) == 0
    d.check()
    d.apply(("release", 1))
    d.teardown()


def test_worst_case_bytes_reservation():
    ops = Driver().ops                    # LIN page_bytes 4.0, RING 4.0
    # linear 16-slot group: 10 tokens -> 3 blocks; ring 8-slot: wraps at
    # total 10 > 8 -> all 2 blocks private.  + state (1.0)
    assert ops.worst_case_bytes(10) == 3 * 4.0 + 2 * 4.0 + 1.0
    # an 8-token shared prefix discounts 2 linear blocks; the wrapped ring
    # still worst-cases to fully private
    assert ops.worst_case_bytes(10, shared_len=8) == 1 * 4.0 + 2 * 4.0 + 1.0
    # short request, no wrap: ring occupies ceil(6/4)=2 blocks anyway
    assert ops.worst_case_bytes(6) == 2 * 4.0 + 2 * 4.0 + 1.0


def test_group_block_math():
    assert RING.touched_blocks(6, 10, PAGE) == {0, 1}     # wraps 8 -> 0
    assert RING.touched_blocks(0, 20, PAGE) == {0, 1}     # >= length: all
    assert LIN.touched_blocks(4, 6, PAGE) == {1}
    assert LIN.touched_blocks(5, 5, PAGE) == set()
    assert RING.block_of(9, PAGE) == 0 and LIN.block_of(9, PAGE) == 2


def test_prefix_align_is_lcm():
    assert prefix_align(8, 8) == 8
    assert prefix_align(8, 12) == 24
    assert prefix_align(16, 8) == 16
