"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the 1 real device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""

import sys
import types

import jax
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback: when the package is absent, install a stub whose
# @given marks the property test as skipped instead of failing collection of
# the whole module (the non-property tests in those modules still run).
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _strategy(*args, **kwargs):
        return None

    def _given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed — property test skipped")(fn)
        return deco

    def _settings(*args, **kwargs):
        return lambda fn: fn

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "sampled_from", "booleans", "lists",
                  "tuples", "one_of", "just", "text", "composite"):
        setattr(_st, _name, _strategy)
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.assume = lambda *a, **k: True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
