"""FCDA correctness: chunked dispatch-compute-combine is bit-equivalent to
unchunked (Eq. 6), chunked recomputation preserves gradients (Eq. 7), and
the dispatch/combine machinery round-trips (hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.core import dispatch as dsp
from repro.core import moe as M
from repro.core.chunking import chunked_map
from repro.core.router import route

CFG = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64)


@pytest.fixture(scope="module")
def setup():
    params = M.init_moe(jax.random.PRNGKey(0), 32, CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    return params, x


@pytest.mark.parametrize("c", [2, 4, 8])
def test_forward_chunk_invariance(setup, c):
    params, x = setup
    y1, _ = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=1))
    yc, _ = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=c))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yc), atol=1e-5)


@pytest.mark.parametrize("c", [2, 8])
def test_gradient_chunk_invariance(setup, c):
    params, x = setup

    def loss(p, ctx):
        return M.moe_ffn(p, x, CFG, ctx)[0].sum()

    g1 = jax.grad(loss)(params, M.DistContext(moe_chunks=1))
    gc = jax.grad(loss)(params, M.DistContext(moe_chunks=c))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_remat_does_not_change_values(setup):
    params, x = setup
    y_r, _ = M.moe_ffn(params, x, CFG,
                       M.DistContext(moe_chunks=4, remat_chunks=True))
    y_n, _ = M.moe_ffn(params, x, CFG,
                       M.DistContext(moe_chunks=4, remat_chunks=False))
    np.testing.assert_allclose(np.asarray(y_r), np.asarray(y_n), atol=1e-6)


def test_matches_dense_oracle(setup):
    params, x = setup
    y, _ = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=2))
    yd, _ = M.moe_ffn(params, x, CFG, M.DistContext(moe_strategy="dense"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=1e-5)


def test_stats_invariant_under_chunking(setup):
    params, x = setup
    _, s1 = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=1))
    _, s4 = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=4))
    np.testing.assert_array_equal(np.asarray(s1["load"]), np.asarray(s4["load"]))
    assert float(s1["drops"]) == float(s4["drops"]) == 0.0


def test_chunked_map_rejects_indivisible():
    with pytest.raises(ValueError):
        chunked_map(lambda x: (x, {}), jnp.zeros((10, 3)), 3)


def test_capacity_mode_drops_and_fcda_does_not(setup):
    params, x = setup
    cap_cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                        capacity_mode="capacity", capacity_factor=0.5)
    _, s = M.moe_ffn(params, x, cap_cfg, M.DistContext())
    assert float(s["drops"]) > 0          # GShard-style baseline drops tokens
    _, s2 = M.moe_ffn(params, x, CFG, M.DistContext(moe_chunks=4))
    assert float(s2["drops"]) == 0        # MemFine is dropless


# ---------------------------------------------------------------------------
# dispatch/combine properties
# ---------------------------------------------------------------------------

@given(t=st.integers(1, 64), e=st.integers(1, 8), k=st.integers(1, 4),
       seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_dispatch_roundtrip_property(t, e, k, seed):
    """combine(dispatch(x)) with identity experts and uniform weights == k*x
    when capacity is dropless."""
    k = min(k, e)
    key = jax.random.PRNGKey(seed)
    kx, ki = jax.random.split(key)
    x = jax.random.normal(kx, (t, 8))
    # k distinct experts per token
    idx = jnp.stack([jax.random.permutation(jax.random.fold_in(ki, i), e)[:k]
                     for i in range(t)]).astype(jnp.int32)
    plan = dsp.make_plan(idx, e, dsp.dropless_capacity(t))
    assert int(plan.drops) == 0
    buf = dsp.scatter_rows(x, plan, e, t)
    y = dsp.gather_rows(buf, plan, jnp.ones((t, k), x.dtype))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * k, atol=1e-5)


@given(t=st.integers(1, 32), e=st.integers(2, 8), cap=st.integers(1, 8),
       seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_capacity_drop_accounting(t, e, cap, seed):
    """drops == total slots minus slots that fit under the per-group cap."""
    idx = jax.random.randint(jax.random.PRNGKey(seed), (t, 1), 0, e)
    plan = dsp.make_plan(idx.astype(jnp.int32), e, cap)
    load = np.asarray(plan.load)
    expect_drops = int(np.maximum(load - cap, 0).sum())
    assert int(plan.drops) == expect_drops
    assert int((np.asarray(plan.slots) >= 0).sum()) == t - expect_drops


@given(t=st.integers(1, 32), e=st.integers(1, 6), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_slots_are_unique_and_in_range(t, e, seed):
    idx = jax.random.randint(jax.random.PRNGKey(seed), (t, 1), 0, e)
    cap = t
    plan = dsp.make_plan(idx.astype(jnp.int32), e, cap)
    slots = np.asarray(plan.slots).reshape(-1)
    valid = slots[slots >= 0]
    assert len(np.unique(valid)) == len(valid)          # no slot collisions
    assert (valid < e * cap).all()
    groups = valid // cap
    np.testing.assert_array_equal(np.sort(groups),
                                  np.sort(np.asarray(idx).reshape(-1)))


def test_router_load_sums_to_slots(setup):
    params, x = setup
    x2 = x.reshape(-1, 32)
    r = route(params["router"], x2, CFG)
    assert int(np.asarray(r.load).sum()) == x2.shape[0] * CFG.top_k
    # weights normalised
    np.testing.assert_allclose(np.asarray(r.weights).sum(-1),
                               np.ones(x2.shape[0]), atol=1e-5)
